"""Generate the EXPERIMENTS.md §Roofline markdown table from the dry-run
artifacts and splice it in at the <!-- ROOFLINE_TABLE --> marker."""
import glob
import json
import re
import sys

sys.path.insert(0, "src")
from benchmarks.roofline import model_flops  # noqa: E402

rows = []
for fn in sorted(glob.glob("experiments/dryrun/*_16x16.json")):
    r = json.load(open(fn))
    if r.get("status") != "ok" or r["mesh"] != "16x16":
        continue
    rl = r["roofline"]
    mf = model_flops(r["arch"], r["shape"])
    compiled_global = float(rl["compute_s"]) * r["chips"] * 197e12
    rows.append((r["arch"], r["shape"], rl, mf / max(1.0, compiled_global),
                 r.get("mem_per_device", 0) / 2 ** 30))

NOTES = {
    "compute": "MXU-bound; only larger per-chip batch helps",
    "memory": "cut HBM traffic (KV/state reads dominate)",
    "collective": "reshard / overlap collectives (see §Perf)",
}
lines = [
    "| arch | shape | compute (s) | memory (s) | collective (s) | "
    "bottleneck | useful | GiB/dev | to move the dominant term |",
    "|---|---|---|---|---|---|---|---|---|",
]
for a, s, rl, ratio, mem in rows:
    dom = rl["bottleneck"]
    lines.append(
        f"| {a} | {s} | {rl['compute_s']:.2e} | {rl['memory_s']:.2e} | "
        f"{rl['collective_s']:.2e} | {dom} | {ratio:.2f} | {mem:.1f} | "
        f"{NOTES[dom]} |")
table = "\n".join(lines)

path = "EXPERIMENTS.md"
text = open(path).read()
text = re.sub(r"<!-- ROOFLINE_TABLE -->", table, text, count=1)
open(path, "w").write(text)
print(f"wrote {len(rows)} rows into {path}")
