"""BlockKVC unit + property tests (allocation invariants)."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.kvc import BlockKVC, blocks_for


def test_blocks_for():
    assert blocks_for(0, 32) == 0
    assert blocks_for(1, 32) == 1
    assert blocks_for(32, 32) == 1
    assert blocks_for(33, 32) == 2


def test_exact_allocation_and_free():
    kvc = BlockKVC(1024, block_size=32)
    assert kvc.allocate(1, 100)            # 4 blocks
    assert kvc.allocated_tokens(1) == 128
    assert kvc.free_blocks == 32 - 4
    assert kvc.free(1) == 128
    assert kvc.free_blocks == 32
    kvc.check_invariants()


def test_allocation_failure_counted():
    kvc = BlockKVC(64, block_size=32)
    assert kvc.allocate(1, 64)
    assert not kvc.allocate(2, 1)
    assert kvc.n_failures == 1
    kvc.check_invariants()


def test_reserve_watermark():
    kvc = BlockKVC(320, block_size=32, reserve_frac=0.2)   # 10 blocks, 2 res
    assert kvc.reserve_target == 2
    # GT side cannot touch the last 2 blocks
    assert kvc.allocate(1, 8 * 32)
    assert not kvc.can_allocate(32)
    # PT side can
    assert kvc.allocate_reserve(2, 1)
    assert kvc.free_reserve == 1
    # releasing the reserve charge is pure bookkeeping
    kvc.release_reserve(2)
    assert kvc.reserve_in_use == 0
    assert kvc.allocs[2].blocks == 1
    kvc.check_invariants()


def test_reserve_release_restores_watermark_pressure():
    kvc = BlockKVC(320, block_size=32, reserve_frac=0.2)
    kvc.allocate_reserve(1, 2)
    # reserve fully dipped -> GT may take everything that is left
    assert kvc.free_general == 8
    kvc.release_reserve(1)
    # watermark restored -> GT must leave 2 blocks free again
    assert kvc.free_general == 6
    kvc.check_invariants()


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "extend", "reserve",
                                           "release", "free"]),
                          st.integers(0, 19), st.integers(1, 300)),
                max_size=60))
def test_property_never_leaks_or_oversubscribes(ops):
    kvc = BlockKVC(2048, block_size=32, reserve_frac=0.1)
    for op, rid, tokens in ops:
        if op == "alloc":
            kvc.allocate(rid, tokens)
        elif op == "extend":
            kvc.extend(rid, blocks_for(tokens, 32))
        elif op == "reserve":
            kvc.allocate_reserve(rid, blocks_for(tokens, 32))
        elif op == "release":
            kvc.release_reserve(rid)
        else:
            kvc.free(rid)
        kvc.check_invariants()
    for rid in list(kvc.allocs):
        kvc.free(rid)
    kvc.check_invariants()
    assert kvc.free_blocks == kvc.total_blocks
    assert kvc.reserve_in_use == 0
