"""Detected-failure battery: the lossy transport, the heartbeat/lease
failure detector, idempotent at-least-once delivery, and the fleet-level
shed-retry tier — on both backends.

The contract under test is *detected, not declared*: the injector only
crashes/freezes instances (they fall silent) and the detector must
notice from missing heartbeats. With no fault windows the whole
substrate must be free — zero rng draws on the transport and token
streams bitwise-identical to the direct-call path.
"""
import numpy as np
import pytest

from repro.cluster import (ChaosSpecError, DetectorConfig, EngineFleet,
                           FaultEvent, FaultInjector, RecoveryConfig,
                           Transport, check_fleet_invariants,
                           parse_chaos_spec)
from repro.cluster.base import (DEAD, FailureDetector, HEALTHY,
                                InstanceBase, SUSPECT)
from repro.cluster.sim import ClusterSim
from repro.cluster.transport import BEAT, DETECTOR, SUBMIT
from repro.configs import get_config
from repro.core import predictor, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.serving import GenRequest, SamplingParams, ServingEngine


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")


def _gen_reqs(cfg, n=6, seed=5, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(8, 24)))),
        params=SamplingParams(max_new_tokens=int(rng.integers(lo, hi)),
                              temperature=0.0))
        for _ in range(n)]


def _sim_trace(n, rate=6.0, seed=0):
    reqs = traces.generate(traces.SHAREGPT, n, seed=seed, rate=rate)
    predictor.annotate(reqs, predictor.NoisyPredictor(accuracy=0.75,
                                                      seed=seed), 0.15)
    return reqs


# --------------------------------------------------------------------- #
# transport: clean pass-through, drop/dup/delay windows, retransmit
# --------------------------------------------------------------------- #
def test_transport_clean_link_zero_rng_and_fifo():
    """No active window: no rng draw at all, same-tick FIFO delivery —
    the precondition for a fault-free detector-on run to be bitwise-
    identical to the direct path."""
    tr = Transport(seed=0)
    state0 = tr.rng.bit_generator.state
    for i in range(3):
        tr.send(0, SUBMIT, f"m{i}", 1.0, dkey=(i, 1))
    got = tr.recv(0, 1.0)
    assert [m.payload for m in got] == ["m0", "m1", "m2"]
    assert tr.rng.bit_generator.state == state0
    assert tr.pending() == 0 and tr.next_time() == float("inf")
    assert (tr.n_dropped, tr.n_duplicated, tr.n_delayed) == (0, 0, 0)

    # windows exist but none is active at send time: still zero draws
    tr.add_fault(FaultEvent(t=50.0, kind="drop", target=0, duration=5.0,
                            frac=1.0))
    tr.send(0, SUBMIT, "m3", 2.0)
    assert [m.payload for m in tr.recv(0, 2.0)] == ["m3"]
    assert tr.rng.bit_generator.state == state0


def test_transport_drop_retransmits_data_but_loses_beats():
    tr = Transport(seed=0)
    tr.add_fault(FaultEvent(t=0.0, kind="drop", target=0, duration=100.0,
                            frac=1.0))
    tr.send(0, SUBMIT, "work", 1.0, dkey=(7, 1))
    assert tr.n_dropped == 1 and tr.n_retransmits == 1
    assert tr.recv(0, 1.0) == []         # lost on the wire...
    assert tr.next_time() == 1.0 + tr.retransmit_after
    (msg,) = tr.recv(0, 1.0 + tr.retransmit_after)
    assert msg.payload == "work" and msg.dkey == (7, 1)   # ...then retried

    # heartbeats are fire-and-forget: a dropped beat is simply missing
    tr.send(DETECTOR, BEAT, 0, 2.0, link=0)
    assert tr.n_dropped == 2
    assert tr.recv(DETECTOR, 1e9) == []
    assert tr.pending() == 0             # beats never count as data-plane


def test_transport_dup_copies_share_delivery_key():
    tr = Transport(seed=0)
    tr.add_fault(FaultEvent(t=0.0, kind="dup", target=1, duration=10.0,
                            frac=1.0))
    tr.send(1, SUBMIT, "x", 0.5, dkey=(9, 1))
    got = tr.recv(1, 0.5)
    assert len(got) == 2 and tr.n_duplicated == 1
    assert got[0].dkey == got[1].dkey == (9, 1)
    # an untargeted link is untouched
    tr.send(0, SUBMIT, "y", 0.5, dkey=(10, 1))
    assert len(tr.recv(0, 0.5)) == 1


def test_transport_delay_defers_and_reorders():
    tr = Transport(seed=0)
    tr.add_fault(FaultEvent(t=0.0, kind="delay", target=0, duration=2.0,
                            delay=5.0))
    tr.send(0, SUBMIT, "slow", 1.0)      # in the window: lands at t=6
    tr.send(0, SUBMIT, "fast", 3.0)      # window closed: lands at t=3
    assert tr.n_delayed == 1
    assert [m.payload for m in tr.recv(0, 3.0)] == ["fast"]
    assert tr.pending() == 1 and tr.next_time() == 6.0
    assert [m.payload for m in tr.recv(0, 6.0)] == ["slow"]


# --------------------------------------------------------------------- #
# failure detector: suspect / reinstate / dead lifecycle
# --------------------------------------------------------------------- #
def test_detector_lifecycle_suspect_reinstate_dead():
    cfg = DetectorConfig(beat_every=1.0, patience=3.0, lease=10.0)
    tr = Transport(seed=0)
    det = FailureDetector(cfg, tr)
    a, b = InstanceBase(0), InstanceBase(1)
    insts = [a, b]
    for i in (0, 1):
        tr.send(DETECTOR, BEAT, i, 0.0, link=i)
    assert det.observe(0.0, insts) == []
    assert a.health == HEALTHY and b.health == HEALTHY

    # silence past patience: both suspected (no routes, work stays put)
    assert det.observe(3.5, insts) == []
    assert a.health == SUSPECT and b.health == SUSPECT
    assert det.n_suspects == 2

    # a fresh beat inside the lease window reinstates the false suspect
    tr.send(DETECTOR, BEAT, 0, 4.0, link=0)
    assert det.observe(4.0, insts) == []
    assert a.health == HEALTHY and det.n_reinstated == 1

    # b stays silent past the lease: declared dead exactly once
    tr.send(DETECTOR, BEAT, 0, 10.0, link=0)
    assert det.observe(10.5, insts) == [1]
    assert b.health == DEAD and det.n_declared_dead == 1
    assert det.heartbeat_age(1, 10.5) == 10.5

    # DEAD is final: a fenced zombie's late beat never resurrects it
    tr.send(DETECTOR, BEAT, 1, 11.0, link=1)
    assert det.observe(11.0, insts) == []
    assert b.health == DEAD
    assert det.transitions == [
        (3.5, 0, HEALTHY, SUSPECT), (3.5, 1, HEALTHY, SUSPECT),
        (4.0, 0, SUSPECT, HEALTHY), (10.5, 1, SUSPECT, DEAD)]


def test_detector_next_deadline_strictly_past_threshold():
    """``observe`` transitions on strictly exceeded ages, so the
    advertised deadline must sit a hair past the threshold — a wake at
    exactly ``last + patience`` observes nothing and would pin the sim
    event horizon forever."""
    cfg = DetectorConfig(beat_every=1.0, patience=3.0, lease=10.0)
    det = FailureDetector(cfg, Transport(seed=0))
    inst = InstanceBase(0)
    det.last_beat[0] = 5.0
    dl = det.next_deadline([inst])
    assert dl > 8.0
    det.observe(8.0, [inst])             # exact threshold: nothing yet
    assert inst.health == HEALTHY
    det.observe(dl, [inst])              # the deadline itself does fire
    assert inst.health == SUSPECT
    assert det.next_deadline([inst]) > 15.0      # now tracking the lease
    inst.health = DEAD
    assert det.next_deadline([inst]) == float("inf")


def test_maybe_beat_periodic_silent_when_crashed_or_frozen():
    tr = Transport(seed=0)
    inst = InstanceBase(0)
    inst.maybe_beat(tr, 0.0, 1.0)
    inst.maybe_beat(tr, 0.5, 1.0)        # not due yet
    assert len(tr.recv(DETECTOR, 0.5)) == 1
    inst.maybe_beat(tr, 1.0, 1.0)
    assert len(tr.recv(DETECTOR, 1.0)) == 1
    inst.crashed = True
    inst.maybe_beat(tr, 2.0, 1.0)        # a crashed instance is silent
    inst.crashed = False
    inst.frozen_until = 9.0
    inst.maybe_beat(tr, 3.0, 1.0)        # and so is a frozen one
    assert tr.recv(DETECTOR, 1e9) == []


def test_detector_config_rejects_lease_inside_patience():
    with pytest.raises(AssertionError):
        DetectorConfig(beat_every=1.0, patience=5.0, lease=4.0)


# --------------------------------------------------------------------- #
# chaos spec: transport kinds + contradictory-clause rejection
# --------------------------------------------------------------------- #
def test_parse_chaos_spec_transport_kinds():
    evs = parse_chaos_spec("drop@10:1/0.6,dup@12:2/0.5,delay@8:0/2.5")
    assert [(e.kind, e.t, e.target) for e in evs] == [
        ("drop", 10.0, 1), ("dup", 12.0, 2), ("delay", 8.0, 0)]
    assert evs[0].frac == 0.6 and evs[1].frac == 0.5
    assert evs[2].delay == 2.5
    for bad, fragment in [
        ("drop@5:1/1.5", "drop@5:1/1.5"),    # probability out of (0, 1]
        ("dup@5:1/0", "dup@5:1/0"),
        ("delay@5:0/-1", "delay@5:0/-1"),    # non-positive latency
        ("drop@5:1/abc", "drop@5:1/abc"),
    ]:
        with pytest.raises(ChaosSpecError) as ei:
            parse_chaos_spec(bad)
        assert fragment in str(ei.value), (bad, str(ei.value))


def test_parse_chaos_spec_contradiction_names_both_clauses():
    """Two different health faults aimed at the same instance at the same
    tick contradict — injector order must not silently pick a winner, so
    the parser rejects the pair naming both clauses."""
    with pytest.raises(ChaosSpecError) as ei:
        parse_chaos_spec("kill@5:1,freeze@5:1")
    msg = str(ei.value)
    assert "kill@5:1" in msg and "freeze@5:1" in msg
    with pytest.raises(ChaosSpecError):
        parse_chaos_spec("freeze@5:1/20,slow@5:1/10x3")
    # non-contradictions parse: same kind twice, different tick/target,
    # untargeted events, and transport kinds riding health faults
    assert len(parse_chaos_spec("freeze@5:1/10,freeze@5:1/20")) == 2
    assert len(parse_chaos_spec("kill@5:1,freeze@6:1")) == 2
    assert len(parse_chaos_spec("kill@5:1,freeze@5:2")) == 2
    assert len(parse_chaos_spec("kill@5,freeze@5")) == 2
    assert len(parse_chaos_spec("kill@5:1,drop@5:1/0.5")) == 2


# --------------------------------------------------------------------- #
# ClusterSim: detected failure + idempotent delivery + shed retry
# --------------------------------------------------------------------- #
def _mk_sim(n_instances=3, scfg=None, **kw):
    cost = CostModel()
    scfg = scfg or SchedulerConfig()
    return ClusterSim(lambda i: make_econoserve(scfg, cost), cost,
                      n_instances=n_instances, router="least-kvc",
                      seed=0, **kw)


def test_sim_detector_fault_free_is_bitwise_identical():
    """Detector on, no fault windows: every completion time and token
    count matches the plain run — heartbeats and the transport judge
    must be pure bookkeeping on the clean path."""
    plain = _mk_sim().run(_sim_trace(120))
    det = _mk_sim(detector=DetectorConfig()).run(_sim_trace(120))
    assert [(r.rid, r.t_complete, r.generated) for r in plain.requests] \
        == [(r.rid, r.t_complete, r.generated) for r in det.requests]
    assert det.wall_time == plain.wall_time
    assert det.detector_transitions == []
    assert det.transport_stats == {"dropped": 0, "duplicated": 0,
                                   "delayed": 0, "retransmits": 0,
                                   "partition_lost": 0,
                                   "partition_held": 0}


def test_sim_dup_delivery_suppressed_exactly_once():
    """Satellite: an aggressive dup window over the whole arrival span —
    every duplicated submit/migration must be suppressed by the
    per-request delivery epoch at the instance boundary: no request
    completes twice, none leaks KVC."""
    cs = _mk_sim(
        detector=DetectorConfig(),
        faults=FaultInjector(schedule=[
            FaultEvent(t=0.0, kind="dup", target=-1, duration=50.0,
                       frac=1.0)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=0.5))
    res = cs.run(_sim_trace(80, rate=8.0))
    cons = res.conservation()
    assert cons["ok"], cons
    assert cons["duplicate_completions"] == 0
    assert res.n_dup_deliveries >= 1          # the window actually bit
    # judge also dups heartbeats (harmless: last-beat keeps the max), so
    # the verdict count bounds the suppressed-delivery count from above
    assert res.transport_stats["duplicated"] >= res.n_dup_deliveries
    assert cons["completed"] + cons["aborted"] == 80


def test_sim_dropped_beats_false_suspect_reinstated_without_loss():
    """A drop window long enough to breach patience but shorter than the
    lease: the instance is falsely suspected, keeps stepping, and is
    reinstated by its first post-window beat — nothing aborted."""
    cs = _mk_sim(
        detector=DetectorConfig(),
        faults=FaultInjector(schedule=[
            FaultEvent(t=2.0, kind="drop", target=0, duration=6.0,
                       frac=1.0)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=0.5))
    res = cs.run(_sim_trace(120))
    cons = res.conservation()
    assert cons["ok"] and cons["aborted"] == 0, cons
    assert res.n_false_suspects >= 1
    pairs = [(frm, to) for _, iid, frm, to in res.detector_transitions
             if iid == 0]
    assert (HEALTHY, SUSPECT) in pairs and (SUSPECT, HEALTHY) in pairs
    assert (SUSPECT, DEAD) not in pairs       # never escalated to dead


def test_sim_kill_detected_not_declared_and_recovered():
    """A kill only silences the instance (``crashed``); the detector must
    walk it HEALTHY -> SUSPECT -> DEAD on missed beats / lease expiry and
    the fleet must recover its stranded work."""
    cs = _mk_sim(
        detector=DetectorConfig(),
        faults=FaultInjector(schedule=[
            FaultEvent(t=4.0, kind="kill", target=1)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=0.5))
    res = cs.run(_sim_trace(150))
    cons = res.conservation()
    assert cons["ok"], cons
    assert res.n_recovered >= 1
    pairs = [(frm, to) for _, iid, frm, to in res.detector_transitions
             if iid == 1]
    assert pairs == [(HEALTHY, SUSPECT), (SUSPECT, DEAD)]
    # no oracle: the lease (measured from the victim's last beat, which
    # lands within one beat period of the kill) must expire first
    suspect_t, dead_t = [t for t, iid, _, to in res.detector_transitions
                         if iid == 1]
    assert 4.0 < suspect_t < dead_t
    assert dead_t >= 4.0 + 10.0 - 1.0         # lease - one beat period


def test_sim_shed_retry_rescues_on_feasible_peer():
    """Rung-4 sheds born of an asymmetric squeeze must be re-routed to
    the peer whose KVC can still fund the frozen demand — terminal shed
    only if nobody can."""
    scfg = SchedulerConfig(kvc_tokens=2048)
    cs = _mk_sim(
        n_instances=2, scfg=scfg,
        detector=DetectorConfig(),
        faults=FaultInjector(schedule=[
            FaultEvent(t=2.0, kind="squeeze", target=0, frac=0.8)]),
        recovery=RecoveryConfig(max_retries=4, backoff_base=0.5,
                                shed_retry=True))
    res = cs.run(_sim_trace(80, rate=8.0))
    cons = res.conservation()
    assert cons["ok"] and cons["aborted"] == 0, cons
    assert res.n_shed_reroutes >= 1           # the squeeze actually shed
    assert res.n_shed_rescued >= 1            # and a peer funded it
    assert res.n_shed_terminal == 0           # nothing lost for good


# --------------------------------------------------------------------- #
# EngineFleet: identity, false suspect, detected kill, shed rescue
# --------------------------------------------------------------------- #
def test_fleet_detector_fault_free_identity(tiny_cfg):
    """Acceptance: detector on, no faults — token streams bitwise-equal
    to the plain fleet, zero transport perturbations, clean audit."""
    plain = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0)
    ref_reqs = plain.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=14))

    fleet = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0,
                        detector=DetectorConfig())
    reqs = fleet.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=14))
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]
    assert fleet.detector.transitions == []
    tr = fleet.transport
    assert (tr.n_dropped, tr.n_duplicated, tr.n_delayed) == (0, 0, 0)
    assert check_fleet_invariants(fleet)["ok"]
    assert fleet.conservation()["dup_deliveries"] == 0


def test_fleet_dropped_beats_false_suspect_keeps_working(tiny_cfg):
    """Beats lost on the wire suspect a perfectly healthy instance: it
    must keep stepping its batch, take no new routes while suspected,
    and be reinstated with all work intact — streams equal fault-free."""
    fleet = EngineFleet(
        tiny_cfg, n_instances=2, router="least-kvc", seed=0,
        max_batch=4, capacity=256, rl_accuracy=1.0,
        detector=DetectorConfig(),
        faults=FaultInjector(schedule=[
            FaultEvent(t=2.0, kind="drop", target=1, duration=6.0,
                       frac=1.0)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=1.0))
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=8, lo=6, hi=14)
    ref.run(ref_reqs)

    reqs = fleet.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=14))
    assert fleet.detector.n_reinstated >= 1
    assert all(i.alive for i in fleet.instances)
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]
    cons = fleet.conservation()
    assert cons["ok"] and cons["aborted"] == 0, cons
    assert check_fleet_invariants(fleet)["ok"]


def test_fleet_kill_detected_recovers_token_equal(tiny_cfg):
    """The kill is silent (``crashed`` only); detection must declare the
    instance dead after the lease, reclaim its work, and reproduce the
    fault-free streams bit-for-bit with an exactly-once audit."""
    fleet = EngineFleet(
        tiny_cfg, n_instances=3, router="least-kvc", seed=0,
        max_batch=4, capacity=256, rl_accuracy=1.0,
        detector=DetectorConfig(),
        faults=FaultInjector(schedule=[
            FaultEvent(t=6.0, kind="kill", target=1)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=1.0))
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=8, lo=6, hi=14)
    ref.run(ref_reqs)

    reqs = fleet.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=14))
    inst = fleet.instances[1]
    assert inst.crashed and inst.health == DEAD
    pairs = [(frm, to) for _, iid, frm, to in fleet.detector.transitions
             if iid == 1]
    assert pairs == [(HEALTHY, SUSPECT), (SUSPECT, DEAD)]
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]
    cons = fleet.conservation()
    assert cons["ok"] and cons["aborted"] == 0 and cons["shed"] == 0, cons
    rep = check_fleet_invariants(fleet)
    assert rep["ok"] and rep["dup_completions"] == 0


def test_fleet_shed_retry_rescues_rung4(tiny_cfg):
    """An asymmetric squeeze sheds rung-4 ``kvc-infeasible`` requests on
    the starved instance; the fleet tier must re-route each to the peer
    whose KVC can fund it — everything completes, bitwise-equal to a
    pressure-free run."""
    scfg = SchedulerConfig(kvc_tokens=224, block_size=16, tfs=128,
                           max_model_len=128, max_batch_reqs=4)
    fleet = EngineFleet(
        tiny_cfg, n_instances=2, router="least-kvc", seed=0,
        max_batch=4, capacity=128, rl_accuracy=1.0, scheduler_cfg=scfg,
        faults=FaultInjector(schedule=[
            FaultEvent(t=3.0, kind="squeeze", target=0, frac=0.6)]),
        recovery=RecoveryConfig(max_retries=4, backoff_base=1.0,
                                shed_retry=True))
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=10, lo=8, hi=16)
    ref.run(ref_reqs)

    reqs = fleet.run(_gen_reqs(tiny_cfg, n=10, lo=8, hi=16))
    cons = fleet.conservation()
    assert cons["ok"] and cons["shed"] == 0 and cons["aborted"] == 0, cons
    assert fleet.n_shed_reroutes >= 1 and fleet.n_shed_rescued >= 1
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]
    assert check_fleet_invariants(fleet)["ok"]
