"""Pressure-proof megastep windows: when the waiting queues are certified
KVC-blocked (``BaseScheduler._admission_horizon``), the engine must keep
dispatching fused K-iteration windows — and stay bitwise drop-in for the
per-iteration path: identical token streams, completion times and
scheduler decisions, with admission happening at the exact iteration the
K=1 path would admit (EOS inside a pressure window truncates it so the
freed KVC reaches the next form_batch on time)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import SchedulerConfig
from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                           ServingEngine)

PER_ITER = EngineConfig(decode_megastep=1)
MEGA = EngineConfig(decode_megastep=8)
LEGACY = EngineConfig(async_decode=False, packed_prefill=False)


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")


def _scfg(mb=8, reserve_frac=0.0):
    # 32 blocks of 16 tokens; each request exact-allocates 8 blocks
    # (16-token prompt + 112 predicted RL), so 4 run while the rest wait
    # KVC-blocked — the saturated steady state the paper targets
    return SchedulerConfig(kvc_tokens=512, block_size=16, tfs=256,
                           max_model_len=256, max_batch_reqs=mb,
                           reserve_frac=reserve_frac, pad_ratio=0.0,
                           bucket=16)


def _workload(cfg, n=12, seed=0, rl=112, eos_token=None, temps=True):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        temp = 1.3 if (temps and i % 3 == 0) else 0.0
        reqs.append(GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, 16)),
            params=SamplingParams(max_new_tokens=rl, temperature=temp,
                                  top_k=4 if temp else 0,
                                  eos_token=eos_token)))
    return reqs


def _fingerprint(eng, reqs):
    per_req = [(g.rid, tuple(g.output), g.t_done) for g in reqs]
    s = eng.scheduler
    sched = (tuple(s.iter_completion_counts),
             tuple((r.rid, r.t_complete, r.generated, r.n_preemptions)
                   for r in s.completed),
             s.n_preempt_free, s.n_preempt_swap, s.n_underprov,
             s.n_hosted, s.n_reserve_rescues)
    return per_req, sched


def _run(cfg, ecfg, wl, scfg=None, seed=0, rl_accuracy=1.0, max_steps=4000):
    eng = ServingEngine(cfg, max_batch=8, capacity=256,
                        rl_accuracy=rl_accuracy, seed=seed,
                        scheduler_cfg=scfg or _scfg(),
                        engine_cfg=ecfg)
    reqs = wl()
    eng.run(reqs, max_steps=max_steps)
    return eng, reqs


def test_pressure_window_fuses_and_matches(cfg):
    """KVC-saturated offline workload: queues stay non-empty through most
    of the run, yet the megastep engine must fuse windows (dispatches well
    below iterations) with a fingerprint identical to per-iteration."""
    outs = []
    for ecfg in (PER_ITER, MEGA):
        eng, reqs = _run(cfg, ecfg, lambda: _workload(cfg))
        outs.append((_fingerprint(eng, reqs), eng))
    (fp1, e1), (fp8, e8) = outs
    assert fp1 == fp8
    assert e1.n_decode_dispatches == e1.decode_iters
    # the bulk of decoding happens with >= 8 requests waiting; fused
    # windows must amortize dispatches by well over 4x overall
    assert e8.n_decode_dispatches * 4 <= e8.decode_iters


def test_pressure_queues_nonempty_while_fused(cfg):
    """Drive the engine manually to prove windows fuse *while* requests
    are actually waiting (not merely after the queues drain)."""
    eng = ServingEngine(cfg, max_batch=8, capacity=256, rl_accuracy=1.0,
                        seed=0, scheduler_cfg=_scfg(), engine_cfg=MEGA)
    reqs = _workload(cfg)
    t = 0.0
    for g in reqs:
        eng.submit(g, t)
    for _ in range(40):                      # admit + settle
        t += 1.0
        eng.step(t)
    base_i, base_d = eng.decode_iters, eng.n_decode_dispatches
    qmin = 10 ** 9
    for _ in range(60):
        t += 1.0
        eng.step(t)
        s = eng.scheduler
        qmin = min(qmin, len(s.pt_queue) + len(s.gt_queue))
    assert qmin >= 1                         # pressure held throughout
    di = eng.decode_iters - base_i
    dd = eng.n_decode_dispatches - base_d
    assert dd * 4 <= di                      # windows fused under pressure
    assert eng.sync_counts["eos_flags"] == 0  # no EOS-capable requests


def test_pressure_eos_truncates_window_exactly(cfg):
    """EOS firing inside a pressure window frees KVC a waiter needs: the
    engine truncates the window at the EOS iteration, so the K=1 path's
    admission timing — and every downstream decision — is reproduced."""
    probe, preqs = _run(cfg, PER_ITER, lambda: _workload(cfg))
    greedy = [g for g in preqs if g.params.temperature == 0.0][0]
    eos = greedy.output[len(greedy.output) // 2]

    outs = []
    for ecfg in (PER_ITER, MEGA):
        eng, reqs = _run(cfg, ecfg,
                         lambda: _workload(cfg, eos_token=eos))
        outs.append((_fingerprint(eng, reqs), eng, reqs))
    assert outs[0][0] == outs[1][0]
    reqs = outs[1][2]
    assert any(len(g.output) < g.params.max_new_tokens for g in reqs)
    assert outs[1][1].n_decode_dispatches < outs[1][1].decode_iters


def test_pressure_matches_legacy_sync(cfg):
    ref, ref_reqs = _run(cfg, LEGACY, lambda: _workload(cfg, n=10, rl=64))
    eng, reqs = _run(cfg, MEGA, lambda: _workload(cfg, n=10, rl=64))
    assert _fingerprint(eng, reqs) == _fingerprint(ref, ref_reqs)
    assert eng.n_decode_dispatches < eng.decode_iters


def test_pressure_with_reserve_and_mispredict(cfg):
    """A nonzero PT reserve plus an always-wrong predictor: reserve
    rescues, under-provision preemptions and re-admissions churn the KVC
    while queues stay loaded — the horizon must stay conservative enough
    to remain bitwise-identical through all of it."""
    def run(ecfg):
        return _run(cfg, ecfg, lambda: _workload(cfg, n=10, rl=48),
                    scfg=_scfg(reserve_frac=0.10), rl_accuracy=0.0)

    e1, r1 = run(PER_ITER)
    e8, r8 = run(MEGA)
    assert _fingerprint(e8, r8) == _fingerprint(e1, r1)


def test_pressure_with_pipelining_hosting(cfg):
    """Under-predicted RLs with pipelining active (hosted GTs in lent
    spans): hosted-slot deadlines and reclaim must bound the window via
    the expiry/hosted horizons, decisions staying identical."""
    def run(ecfg):
        scfg = SchedulerConfig(kvc_tokens=768, block_size=16, tfs=256,
                               max_model_len=256, max_batch_reqs=8,
                               reserve_frac=0.05, pad_ratio=0.3, bucket=16)
        return _run(cfg, ecfg, lambda: _workload(cfg, n=10, rl=40),
                    scfg=scfg, rl_accuracy=0.5)

    e1, r1 = run(PER_ITER)
    e8, r8 = run(MEGA)
    assert _fingerprint(e8, r8) == _fingerprint(e1, r1)
