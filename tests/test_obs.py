"""Metrics plane: registry semantics, exporters, sampler zero-overhead
contract, and the deterministic drain classification it relies on.

Covers the PR-9 acceptance checklist:
  * label-set identity (same values, any kwarg order -> same child);
  * histogram bucket edges: boundary values land low-side, the +Inf
    bucket conserves the total count;
  * counter monotonicity under concurrent publishers (threads);
  * snapshot immutability (frozen at capture, unaffected by later
    publishes);
  * Prometheus text round-trip and JSON exports;
  * Chrome trace_event span construction from request timestamps;
  * enqueue-time drain classification is deterministic across repeated
    runs of the same stream (the PR-8 race this PR fixes);
  * a sampler-attached engine produces bitwise-identical tokens and
    identical sync totals to a bare one.
"""
import json
import math
import threading

import pytest

from repro.obs import (DEFAULT_BUCKETS, MetricsRegistry, MetricsSampler,
                       Snapshot, TimeSeriesLog, parse_prometheus_text,
                       publish_engine, request_trace_events,
                       to_prometheus_text, write_json_snapshot)


# --------------------------------------------------------------------- #
# registry semantics
# --------------------------------------------------------------------- #
def test_label_set_identity():
    reg = MetricsRegistry()
    fam = reg.counter("rpc_calls_total", "calls", ("method", "code"))
    a = fam.labels(method="get", code="200")
    b = fam.labels(code="200", method="get")     # kwarg order irrelevant
    assert a is b
    a.inc(3)
    assert b.value == 3.0
    c = fam.labels(method="get", code="500")
    assert c is not a and c.value == 0.0
    # label values are stringified consistently
    g = reg.gauge("inst_state", "", ("instance",))
    assert g.labels(instance=7) is g.labels(instance="7")


def test_label_validation_and_redeclare():
    reg = MetricsRegistry()
    fam = reg.counter("x_total", "", ("a",))
    with pytest.raises(ValueError):
        fam.labels(b="1")                        # undeclared label
    with pytest.raises(ValueError):
        fam.labels()                             # missing label
    # same signature: same family object; changed signature: refused
    assert reg.counter("x_total", "", ("a",)) is fam
    with pytest.raises(ValueError):
        reg.counter("x_total", "", ("a", "b"))
    with pytest.raises(ValueError):
        reg.gauge("x_total", "", ("a",))
    with pytest.raises(AssertionError):
        reg.counter("0bad", "")                  # invalid metric name


def test_counter_monotone_api():
    reg = MetricsRegistry()
    c = reg.counter("n_total", "").unlabeled
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    c.inc_to(10)
    with pytest.raises(ValueError):
        c.inc_to(9)                              # regression refused
    assert c.value == 10.0


def test_counter_monotonic_under_concurrent_publishers():
    reg = MetricsRegistry()
    child = reg.counter("hits_total", "", ("worker",)).labels(worker="w")
    n_threads, n_incs = 8, 2_000
    seen = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            seen.append(child.value)

    def writer():
        for _ in range(n_incs):
            child.inc(1)

    threads = [threading.Thread(target=writer) for _ in range(n_threads)]
    rt = threading.Thread(target=reader)
    rt.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    rt.join()
    # no lost updates, and every observed value non-decreasing
    assert child.value == n_threads * n_incs
    assert all(a <= b for a, b in zip(seen, seen[1:]))


def test_histogram_boundary_low_side_and_inf_conserved():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "", buckets=(1.0, 2.0, 5.0)).unlabeled
    h.observe(1.0)            # exactly on an edge -> low-side bucket
    h.observe(1.0000001)      # just above -> next bucket
    h.observe(5.0)            # top finite edge
    h.observe(99.0)           # overflow -> +Inf only
    v = reg.snapshot().get("lat")
    assert v.buckets == ((1.0, 1), (2.0, 2), (5.0, 3),
                         (float("inf"), 4))
    assert v.count == 4 and v.buckets[-1][1] == v.count   # +Inf conserved
    assert v.sum == pytest.approx(1.0 + 1.0000001 + 5.0 + 99.0)
    # unsorted / +Inf-containing declarations are refused or normalized
    h2 = reg.histogram("lat2", "", buckets=(5.0, 1.0, 2.0)).unlabeled
    h2.observe(1.5)
    assert reg.snapshot().get("lat2").buckets[1] == (2.0, 1)
    with pytest.raises(AssertionError):
        reg.histogram("lat3", "", buckets=(1.0, float("inf")))


def test_snapshot_immutable_and_stable():
    reg = MetricsRegistry()
    c = reg.counter("a_total", "", ("k",)).labels(k="1")
    h = reg.histogram("h", "", buckets=(1.0,)).unlabeled
    c.inc(5)
    h.observe(0.5)
    snap = reg.snapshot()
    # later publishes don't leak into the captured snapshot
    c.inc(100)
    h.observe(0.2)
    assert snap.get("a_total", k="1") == 5.0
    assert snap.get("h").count == 1
    # the snapshot's structures refuse mutation
    with pytest.raises(TypeError):
        snap.families[0].samples[0][0]["k"] = "2"
    with pytest.raises((TypeError, AttributeError)):
        snap.families[0].samples = ()
    with pytest.raises((TypeError, AttributeError)):
        snap.get("h").count = 7
    assert isinstance(snap, Snapshot)


def test_snapshot_flat_rendering():
    reg = MetricsRegistry()
    reg.counter("c_total", "", ("x",)).labels(x="a").inc(2)
    reg.gauge("g", "").unlabeled.set(1.5)
    reg.histogram("h", "", buckets=(1.0,)).unlabeled.observe(3.0)
    flat = reg.snapshot().flat()
    assert flat['c_total{x="a"}'] == 2.0
    assert flat["g"] == 1.5
    assert flat['h_bucket{le="1"}'] == 0
    assert flat['h_bucket{le="+Inf"}'] == 1
    assert flat["h_sum"] == 3.0 and flat["h_count"] == 1
    with pytest.raises(KeyError):
        reg.snapshot().get("nope")


# --------------------------------------------------------------------- #
# exporters
# --------------------------------------------------------------------- #
def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", ("code",)).labels(code="200") \
        .inc(7)
    reg.gauge("depth", "queue depth").unlabeled.set(3)
    reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)) \
        .unlabeled.observe(0.05)
    snap = reg.snapshot()
    text = to_prometheus_text(snap)
    parsed = parse_prometheus_text(text)
    assert parsed['req_total{code="200"}'] == 7.0
    assert parsed["depth"] == 3.0
    assert parsed['lat_seconds_bucket{le="+Inf"}'] == 1.0
    assert parsed["lat_seconds_count"] == 1.0
    # the parser rejects garbage rather than returning partial data
    with pytest.raises(ValueError):
        parse_prometheus_text("this is not prometheus\n")
    with pytest.raises(ValueError):
        parse_prometheus_text("")


def test_json_snapshot_and_timeseries(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("v", "").unlabeled.set(2)
    p = tmp_path / "m.json"
    write_json_snapshot(reg.snapshot(), str(p), extra={"run": "t"})
    data = json.loads(p.read_text())
    assert data["metrics"]["v"] == 2.0 and data["meta"]["run"] == "t"

    log = TimeSeriesLog()
    log.record(0.0, {"a": 1.0})
    log.record(1.0, {"a": 2.0, "b": 5.0})
    log.record_snapshot(2.0, reg.snapshot())
    out = log.to_json()["series"]
    assert out["a"] == {"t": [0.0, 1.0], "v": [1.0, 2.0]}
    assert out["b"] == {"t": [1.0], "v": [5.0]}
    assert out["v"] == {"t": [2.0], "v": [2.0]}
    q = tmp_path / "ts.json"
    log.write(str(q))
    assert json.loads(q.read_text())["series"]["a"]["v"] == [1.0, 2.0]


def test_chrome_trace_events():
    from repro.core.request import Request, State

    r = Request(rid=0, prompt_len=8, true_rl=4, arrival=1.0,
                slo_deadline=50.0)
    r.set_state(State.RUNNING_PT, 2.0)
    r.t_start_exec = 2.0
    r.t_first_token = 3.0
    r.generated = 4
    r.set_state(State.COMPLETED, 6.0)
    events = request_trace_events([r])
    phases = [(e["name"], e["ph"]) for e in events]
    assert ("queued", "X") in phases
    assert ("prefill", "X") in phases
    assert ("decode", "X") in phases
    for e in events:
        if e["ph"] == "X":
            assert e["dur"] >= 0 and e["tid"] == 0
    js = json.dumps(events)        # must be JSON-serializable as-is
    assert "traceEvents" not in js  # list form, loadable by about:tracing


# --------------------------------------------------------------------- #
# engine integration: sampler + deterministic drain classification
# --------------------------------------------------------------------- #
def _tiny_cfg():
    from repro.configs import get_config
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=32, num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=128, dtype="float32", param_dtype="float32")


def _run_stream(cfg, sampler_reg=None, seed=3, n=5):
    import numpy as np
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    eng = ServingEngine(cfg, max_batch=4, capacity=128, rl_accuracy=1.0,
                        seed=seed)
    if sampler_reg is not None:
        MetricsSampler(sampler_reg, instance="0").attach(eng)
    rng = np.random.default_rng(seed)
    reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                       params=SamplingParams(
                           max_new_tokens=int(rng.integers(4, 10)),
                           temperature=0.0))
            for _ in range(n)]
    eng.run(reqs, arrivals=[0.5 * i for i in range(n)])
    return eng, [tuple(g.output) for g in reqs]


def test_drain_classification_deterministic():
    """The PR-8 race: drain_blocking/backpressure used to be classified
    at pop time from ``toks.is_ready()`` — device timing. Classification
    now happens at enqueue from dispatch sequence numbers, so repeated
    runs of the same stream agree on every single count."""
    cfg = _tiny_cfg()
    counts = []
    for _ in range(3):
        eng, _ = _run_stream(cfg)
        counts.append(dict(eng.sync_counts))
    assert counts[0] == counts[1] == counts[2]
    # async engine: the only drain_blocking source is the sync fallback
    assert counts[0]["drain_blocking"] == 0


def test_sampler_bitwise_identity_and_zero_added_syncs():
    cfg = _tiny_cfg()
    bare, toks_off = _run_stream(cfg)
    reg = MetricsRegistry()
    sampled, toks_on = _run_stream(cfg, sampler_reg=reg)
    assert toks_on == toks_off
    assert sampled.sync_counts == bare.sync_counts
    snap = reg.snapshot()
    # the registry's totals mirror the engine's own counters
    for kind, v in sampled.sync_counts.items():
        assert snap.get("engine_host_syncs_total",
                        instance="0", kind=kind) == v
    assert snap.get("engine_decode_iters_total", instance="0") \
        == sampled.decode_iters
    assert snap.get("engine_tokens_drained_total", instance="0") \
        == sampled.n_tokens_drained > 0


def test_publish_engine_and_debug_state_agree():
    cfg = _tiny_cfg()
    eng, _ = _run_stream(cfg)
    reg = MetricsRegistry()
    publish_engine(eng, reg, instance="0")
    flat = reg.snapshot().flat()
    dbg = eng.debug_state()
    assert dbg == flat                 # one publication path, one answer
    assert 'scheduler_completed_total{instance="0"}' in dbg
    assert 'kvc_free_blocks{instance="0"}' in dbg


def test_sampler_handles_spawned_instances():
    """Fleet attach must also cover autoscaler-spawned engines (the
    registry reference is kept, not the sampler list)."""
    from repro.cluster import EngineFleet

    cfg = _tiny_cfg()
    fleet = EngineFleet(cfg, n_instances=2, router="least-kvc", seed=0,
                        max_batch=4, capacity=128, rl_accuracy=1.0)
    reg = MetricsRegistry()
    fleet.attach_metrics(reg)
    fleet._spawn(0.0)
    assert fleet.instances[-1].engine.metrics is not None
    samples = reg.snapshot().flat()
    assert 'sampler_samples_total{instance="2"}' in samples
