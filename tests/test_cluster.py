"""Cluster serving layer: routers, autoscaler hysteresis, KV-migration
token equality, and rid conservation across instances (sim + real fleet).
"""
import numpy as np
import pytest

from repro.cluster import (AutoscaleConfig, EngineFleet, GoodputAutoscaler,
                           ROUTERS, make_router)
from repro.cluster.sim import ClusterSim
from repro.configs import get_config
from repro.core import predictor, registry, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.serving import GenRequest, SamplingParams, ServingEngine


# --------------------------------------------------------------------- #
# routers
# --------------------------------------------------------------------- #
class _Stub:
    """Minimal InstanceStats stand-in."""

    def __init__(self, iid, alloc_frac=0.0, cap=4096, outstanding=0):
        self.id = iid
        self._alloc = alloc_frac
        self._cap = cap
        self._out = outstanding

    def kvc_allocated_frac(self):
        return self._alloc

    def kvc_capacity_tokens(self):
        return self._cap

    def outstanding_tokens(self):
        return self._out


def test_round_robin_cycles_by_id():
    r = make_router("round-robin")
    insts = [_Stub(2), _Stub(0), _Stub(1)]
    picks = [r.choose(insts, 10).id for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_least_tokens_picks_min():
    r = make_router("least-tokens")
    insts = [_Stub(0, outstanding=500), _Stub(1, outstanding=20),
             _Stub(2, outstanding=300)]
    assert r.choose(insts, 10).id == 1


def test_least_kvc_accounts_for_demand():
    r = make_router("least-kvc")
    # instance 0 is less allocated but tiny: the request's demand tips it
    insts = [_Stub(0, alloc_frac=0.10, cap=256),
             _Stub(1, alloc_frac=0.30, cap=8192)]
    assert r.choose(insts, 200).id == 1          # 0.10+0.78 vs 0.30+0.02
    assert r.choose(insts, 8).id == 0            # 0.13 vs 0.30


@pytest.mark.parametrize("name", ROUTERS)
def test_router_determinism_under_seeded_ties(name):
    """Identical state + identical seed => identical choice sequences,
    even when every candidate ties."""
    def run(seed):
        r = make_router(name, seed=seed)
        insts = [_Stub(i, alloc_frac=0.5, outstanding=100)
                 for i in range(4)]
        return [r.choose(insts, 64).id for _ in range(12)]

    assert run(3) == run(3)
    seqs = {tuple(run(s)) for s in range(8)}
    if name != "round-robin":                    # ties actually random
        assert len(seqs) > 1


# --------------------------------------------------------------------- #
# autoscaler
# --------------------------------------------------------------------- #
def _feed(scaler, t, met, n=1, n_live=2, load=0.5):
    acts = []
    for _ in range(n):
        scaler.record(met)
        acts.append(scaler.decide(t, n_live=n_live, load_frac=load))
    return acts


def test_autoscaler_scales_up_on_attainment_drop():
    cfg = AutoscaleConfig(window=8, min_window=4, patience=2, cooldown=10.0)
    sc = GoodputAutoscaler(cfg)
    for i in range(8):
        sc.record(True)
    assert sc.decide(0.0, n_live=1, load_frac=0.9) == 0   # healthy
    acts = []
    t = 100.0
    for i in range(10):
        sc.record(False)
        acts.append(sc.decide(t + i, n_live=1, load_frac=0.9))
    assert acts.count(+1) == 1                   # exactly one action
    assert sc.events and sc.events[0][1] == +1


def test_autoscaler_no_flap_on_step_load_change():
    """Load steps up -> one scale-up; the recovered (high) attainment must
    NOT immediately drain the new instance while it is still loaded."""
    cfg = AutoscaleConfig(window=8, min_window=4, patience=2,
                          cooldown=50.0, down_load_cap=0.7)
    sc = GoodputAutoscaler(cfg)
    t = 0.0
    # degraded attainment -> scale up once
    ups = _feed(sc, t, met=False, n=10, n_live=1, load=0.95)
    assert ups.count(+1) == 1
    # recovery: attainment back to 1.0 but survivors would be overloaded
    t = 10.0
    acts = []
    for i in range(30):
        sc.record(True)
        acts.append(sc.decide(t + i, n_live=2, load_frac=0.6))
    # projected load on 1 survivor = 1.2 > cap -> no drain, no flap
    assert all(a == 0 for a in acts)
    assert [d for _, d in sc.events] == [+1]


def test_autoscaler_drains_idle_capacity():
    cfg = AutoscaleConfig(window=8, min_window=4, patience=2,
                          cooldown=5.0, down_load_cap=0.7)
    sc = GoodputAutoscaler(cfg)
    acts = []
    for i in range(10):
        sc.record(True)
        acts.append(sc.decide(100.0 + i, n_live=3, load_frac=0.1))
    assert acts.count(-1) == 1


def test_autoscaler_cooldown_blocks_consecutive_actions():
    cfg = AutoscaleConfig(window=4, min_window=2, patience=1,
                          cooldown=100.0)
    sc = GoodputAutoscaler(cfg)
    a1 = _feed(sc, 0.0, met=False, n=5, n_live=1)
    assert a1.count(+1) == 1
    # still degraded, but inside the cooldown window
    a2 = _feed(sc, 50.0, met=False, n=5, n_live=2)
    assert a2.count(+1) == 0
    a3 = _feed(sc, 200.0, met=False, n=5, n_live=2)
    assert a3.count(+1) == 1


# --------------------------------------------------------------------- #
# cluster simulator
# --------------------------------------------------------------------- #
def _sim_trace(n, rate=6.0, seed=0, accuracy=0.75):
    reqs = traces.generate(traces.SHAREGPT, n, seed=seed, rate=rate)
    predictor.annotate(reqs, predictor.NoisyPredictor(accuracy=accuracy,
                                                      seed=seed), 0.15)
    return reqs


@pytest.mark.parametrize("router", ROUTERS)
def test_cluster_sim_conservation(router):
    cost = CostModel()
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=3, router=router, seed=0)
    res = cs.run(_sim_trace(200))
    cons = res.conservation()
    assert cons["ok"], cons
    assert res.n_migrations == 0                 # unified: no roles
    # load actually spread: no instance served everything
    share = [len(v) for v in res.completed_by.values()]
    assert max(share) < 200 and sum(share) == 200


def test_cluster_sim_disagg_roles_migrate_every_request():
    cost = CostModel()
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=2, router="least-kvc",
                    roles=("prefill", "decode"), seed=0)
    reqs = _sim_trace(150, rate=4.0)
    res = cs.run(reqs)
    cons = res.conservation()
    assert cons["ok"], cons
    # every request whose RL > 1 crossed the prefill->decode boundary
    assert res.n_migrations >= sum(1 for r in reqs if r.true_rl > 1)
    # decode-side completions only (RL==1 requests may finish at prefill)
    assert len(res.completed_by[1]) >= res.n_migrations


def test_cluster_sim_registry_front_door():
    res = registry.run_cluster("econoserve", _sim_trace(120),
                               n_instances=2, router="round-robin", seed=1)
    assert res.conservation()["ok"]
    assert res.goodput > 0


def test_cluster_sim_autoscaler_step_load_no_flap():
    """A rate step that overloads one instance must scale up (>=1) and
    never oscillate up->down->up."""
    cost = CostModel()
    scaler = GoodputAutoscaler(AutoscaleConfig(
        window=24, min_window=8, patience=2, cooldown=25.0,
        max_instances=4))
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=1, router="least-kvc", seed=0,
                    autoscaler=scaler)
    res = cs.run(_sim_trace(400, rate=12.0))
    assert res.conservation()["ok"]
    dirs = [d for _, d in res.scale_events]
    assert dirs.count(+1) >= 1
    for a, b in zip(dirs, dirs[1:]):             # no direction flip-flop
        assert not (a == -1 and b == +1), res.scale_events


# --------------------------------------------------------------------- #
# real-engine fleet
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")


def _gen_reqs(cfg, n=6, seed=5):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(8, 24)))),
        params=SamplingParams(max_new_tokens=int(rng.integers(4, 10)),
                              temperature=0.0))
        for _ in range(n)]


def test_fleet_conservation_unified(tiny_cfg):
    fleet = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0)
    reqs = fleet.run(_gen_reqs(tiny_cfg, n=8))
    cons = fleet.conservation()
    assert cons["ok"], cons
    assert all(g.t_done is not None for g in reqs)
    # both instances actually served something
    served = [len(i.engine.scheduler.completed) for i in fleet.instances]
    assert min(served) > 0


def test_fleet_kv_migration_token_equality(tiny_cfg):
    """A request migrated prefill→decode produces a greedy token stream
    identical to the same request served on a single engine — both for
    the KV-image path and the swap-recompute fallback."""
    fleet = EngineFleet(tiny_cfg, n_instances=2,
                        roles=("prefill", "decode"), router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0)
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg)
    ref.run(ref_reqs)
    ref_out = [g.output for g in ref_reqs]
    assert all(len(o) > 0 for o in ref_out)

    out = [g.output for g in fleet.run(_gen_reqs(tiny_cfg))]
    assert out == ref_out
    cons = fleet.conservation()
    assert cons["ok"] and cons["migrations"] == len(ref_reqs), cons
    assert fleet.n_kv_fallbacks == 0             # KV images actually moved

    fb = EngineFleet(tiny_cfg, n_instances=2, roles=("prefill", "decode"),
                     router="round-robin", seed=0, kv_migration=False,
                     max_batch=4, capacity=256, rl_accuracy=1.0)
    out_fb = [g.output for g in fb.run(_gen_reqs(tiny_cfg))]
    assert out_fb == ref_out
    assert fb.n_kv_fallbacks == fb.n_migrations > 0


def test_fleet_engine_export_inject_roundtrip(tiny_cfg):
    """Unit-level: export removes the request from the source engine
    (scheduler + slots + KVC) and inject registers it on the target."""
    src = ServingEngine(tiny_cfg, max_batch=4, capacity=256,
                        rl_accuracy=1.0, seed=0)
    dst = ServingEngine(tiny_cfg, params=src.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=1)
    g = _gen_reqs(tiny_cfg, n=1)[0]
    t = 0.0
    src.submit(g, t)
    while not src.scheduler.gt_queue:
        t += 1.0
        src.step(t)
    rid = next(iter(src.scheduler.gt_queue)).rid
    payload = src.export_kv(rid)
    assert not src.has_work()
    assert rid not in src.slot_of and rid not in src.scheduler.kvc.allocs
    assert payload["kv"] is not None and payload["ctx"] == len(g.prompt)
    new_rid = dst.inject_kv(payload, t)
    assert dst.has_work()
    assert new_rid in dst.slot_of                # KV path seeded a slot
    while dst.has_work() and t < 200:
        t += 1.0
        dst.step(t)
    assert g.t_done is not None
    assert len(g.output) == g.params.max_new_tokens
