"""Hedged execution + network-partition battery, example-by-example.

Covers the deterministic surface of the hedging tier on both backends:
``part@t:a|b/dur`` parsing (typed errors naming the bad clause), the
transport's asymmetric cut (beats lost forever, data held until heal),
zombie fencing in the sim (a partitioned instance keeps stepping; its
late completions are counted, never double-delivered), first-winner
racing with provable conservation, the bitwise-off contract, and the
registry-sourced autoscaler attainment window.
"""
import numpy as np
import pytest

from repro.cluster import (ChaosSpecError, DetectorConfig, EngineFleet,
                           FaultInjector, GoodputAutoscaler, HedgeConfig,
                           RecoveryConfig, Transport,
                           check_fleet_invariants, parse_chaos_spec)
from repro.cluster.autoscale import AutoscaleConfig
from repro.cluster.sim import ClusterSim
from repro.cluster.transport import BEAT, DETECTOR, SUBMIT
from repro.configs import get_config
from repro.core import predictor, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.obs import MetricsRegistry
from repro.serving import GenRequest, SamplingParams, ServingEngine


# --------------------------------------------------------------------- #
# part@ chaos-spec parsing: typed errors that name the bad clause
# --------------------------------------------------------------------- #
def test_part_spec_parses_fields():
    (ev,) = parse_chaos_spec("part@6:2|0/12")
    assert (ev.kind, ev.t, ev.target, ev.peer, ev.duration) \
        == ("part", 6.0, 2, 0, 12.0)


def test_part_spec_self_partition_rejected():
    with pytest.raises(ChaosSpecError, match="self-partition"):
        parse_chaos_spec("part@6:1|1/12")


def test_part_spec_nonpositive_duration_rejected():
    with pytest.raises(ChaosSpecError, match="duration"):
        parse_chaos_spec("part@6:2|0/0")
    with pytest.raises(ChaosSpecError, match="duration"):
        parse_chaos_spec("part@6:2|0/-3")


def test_part_spec_missing_target_rejected():
    # no ':a|b' at all, and a target without the bar — both name the
    # offending clause in the message
    with pytest.raises(ChaosSpecError, match=r"part@6/12"):
        parse_chaos_spec("part@6/12")
    with pytest.raises(ChaosSpecError, match=r"part@6:2/12"):
        parse_chaos_spec("part@6:2/12")


def test_part_spec_unknown_instance_rejected():
    with pytest.raises(ChaosSpecError, match="unknown instance 7"):
        parse_chaos_spec("part@6:7|0/12", n_instances=3)
    with pytest.raises(ChaosSpecError, match="unknown instance 5"):
        parse_chaos_spec("part@6:2|5/12", n_instances=3)
    # in range parses fine with the same validation armed
    assert len(parse_chaos_spec("part@6:2|0/12", n_instances=3)) == 1


# --------------------------------------------------------------------- #
# transport: the asymmetric cut
# --------------------------------------------------------------------- #
def test_partition_loses_beats_holds_data():
    tr = Transport(seed=0)
    (ev,) = parse_chaos_spec("part@5:1|0/10")
    tr.add_fault(ev)
    # before the window: clean
    tr.send(DETECTOR, BEAT, 1, 1.0, link=1)
    assert len(tr.recv(DETECTOR, 1.0)) == 1
    # inside the window: the beat is swallowed outright...
    tr.send(DETECTOR, BEAT, 1, 6.0, link=1)
    assert tr.recv(DETECTOR, 20.0) == []
    assert tr.n_partition_lost == 1
    # ...but a data-plane send is held and lands only after the heal
    tr.send(1, SUBMIT, {"rid": 7}, 6.0, dkey=(7, 0), link=1)
    assert tr.n_partition_held == 1
    assert tr.recv(1, 14.9) == []
    msgs = tr.recv(1, 15.0)
    assert [m.payload for m in msgs] == [{"rid": 7}]
    # the majority side's own link is never cut
    tr.send(DETECTOR, BEAT, 0, 6.0, link=0)
    assert len(tr.recv(DETECTOR, 6.0)) == 1


def test_partition_heal_times():
    tr = Transport(seed=0)
    (ev,) = parse_chaos_spec("part@5:1|0/10")
    tr.add_fault(ev)
    assert tr.partition_heal(1, 4.9) == 0.0       # not yet open
    assert tr.partition_heal(1, 5.0) == 15.0      # cut: heals at t1
    assert tr.partition_heal(0, 5.0) == 0.0       # majority side clean
    assert tr.partition_heal(1, 15.0) == 0.0      # healed
    assert tr.judge(1, 6.0).heal == 15.0


# --------------------------------------------------------------------- #
# sim: zombie fencing + hedged racing
# --------------------------------------------------------------------- #
def _sim_trace(n=120, rate=6.0, seed=0):
    reqs = traces.generate(traces.SHAREGPT, n, seed=seed, rate=rate)
    predictor.annotate(reqs, predictor.NoisyPredictor(accuracy=0.75,
                                                      seed=seed), 0.15)
    return reqs


def _mk_sim(spec=None, hedge=None, n_instances=3, seed=0):
    cost = CostModel()
    scfg = SchedulerConfig()
    kw = {}
    if spec is not None:
        kw["faults"] = FaultInjector(
            schedule=parse_chaos_spec(spec, n_instances), seed=seed,
            min_alive=1)
    return ClusterSim(lambda i: make_econoserve(scfg, cost), cost,
                      n_instances=n_instances, router="least-kvc",
                      seed=seed, detector=DetectorConfig(),
                      recovery=RecoveryConfig(max_retries=4,
                                              backoff_base=1.0),
                      hedge=hedge, **kw)


def test_sim_partition_zombie_is_fenced_and_conserved():
    """A partitioned instance outlives its lease, keeps stepping as a
    zombie, and finishes work the control plane already re-routed: that
    completion must be *fenced* — counted, never double-delivered — and
    every request still completes exactly once."""
    res = _mk_sim(spec="part@6:2|0/12").run(_sim_trace())
    cons = res.conservation()
    assert cons["ok"]
    assert cons["completed"] == cons["submitted"] == 120
    assert cons["duplicate_completions"] == 0
    assert res.n_fenced_completions >= 1
    assert res.transport_stats["partition_lost"] >= 1


def test_sim_hedge_off_is_bitwise_identical():
    """``HedgeConfig(enabled=False)`` must change nothing: same token
    counts and completion times as ``hedge=None`` under the same chaos."""
    spec = "slow@5:1/30x25,part@15:1|0/15"
    a = _mk_sim(spec=spec).run(_sim_trace())
    b = _mk_sim(spec=spec, hedge=HedgeConfig(enabled=False)) \
        .run(_sim_trace())
    assert [(r.rid, r.generated, r.t_complete) for r in a.requests] \
        == [(r.rid, r.generated, r.t_complete) for r in b.requests]
    assert b.n_hedges_fired == b.n_hedges_won == b.n_hedges_cancelled == 0


def test_sim_hedge_races_cut_the_straggler_tail():
    """Hedging on under straggler + partition chaos: >= 1 race fired AND
    won, the partitioned zombie's completions fenced, conservation
    exactly-once, and the p99 JCT tail strictly better than hedging
    off."""
    spec = "slow@5:1/30x25,part@15:1|0/15"
    off = _mk_sim(spec=spec).run(_sim_trace())
    on = _mk_sim(spec=spec, hedge=HedgeConfig(floor=0.5)) \
        .run(_sim_trace())

    def p99_jct(res):
        jct = sorted(r.t_complete - r.arrival for r in res.requests
                     if r.t_complete is not None)
        return jct[int(0.99 * (len(jct) - 1))]

    cons = on.conservation()
    assert cons["ok"] and cons["completed"] == 120
    assert cons["duplicate_completions"] == 0
    assert on.n_hedges_fired >= 1
    assert on.n_hedges_won >= 1
    assert on.n_hedges_cancelled == on.n_hedges_fired
    assert on.n_fenced_completions >= 1
    assert p99_jct(on) < p99_jct(off)


def test_sim_hedge_publishes_metrics():
    reg = MetricsRegistry()
    sim = _mk_sim(spec="slow@5:1/30x25,part@15:1|0/15",
                  hedge=HedgeConfig(floor=0.5))
    res = sim.run(_sim_trace())
    sim.publish_metrics(reg)
    snap = reg.snapshot()
    assert snap.get("hedge_fired_total") == res.n_hedges_fired
    assert snap.get("hedge_won_total") == res.n_hedges_won
    assert snap.get("cluster_fenced_completions_total") \
        == res.n_fenced_completions


# --------------------------------------------------------------------- #
# fleet: first-winner racing on real engines
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")


def _gen_reqs(cfg, n=10, seed=5, lo=8, hi=16):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(8, 24)))),
        params=SamplingParams(max_new_tokens=int(rng.integers(lo, hi)),
                              temperature=0.0))
        for _ in range(n)]


def test_fleet_hedge_race_under_partition_chaos(tiny_cfg):
    """Real engines: a 6x straggler plus a partitioned zombie. At least
    one hedge must fire and win, the zombie's completion must be fenced,
    and every winning stream must be bitwise-equal to a fault-free
    single-engine run with the invariant audit green."""
    scfg = SchedulerConfig(kvc_tokens=224, block_size=16, tfs=128,
                           max_model_len=128, max_batch_reqs=4)
    spec = "slow@2:1/40x6,part@6:2|0/12"
    fleet = EngineFleet(
        tiny_cfg, n_instances=3, router="least-kvc", seed=0,
        max_batch=4, capacity=128, rl_accuracy=1.0, scheduler_cfg=scfg,
        faults=FaultInjector(schedule=parse_chaos_spec(spec, 3), seed=0,
                             min_alive=1),
        recovery=RecoveryConfig(max_retries=4, backoff_base=1.0,
                                shed_retry=True),
        detector=DetectorConfig(), hedge=HedgeConfig())
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=0,
                        scheduler_cfg=scfg)
    ref_reqs = _gen_reqs(tiny_cfg)
    ref.run(ref_reqs)
    reqs = fleet.run(_gen_reqs(tiny_cfg))
    cons = fleet.conservation()
    assert cons["ok"]
    assert cons["dup_completions"] == 0
    hc = fleet.hedge.counters()
    assert hc["hedges_fired"] >= 1
    assert hc["hedges_won"] >= 1
    assert fleet.n_fenced_completions >= 1
    assert all(g.output == r.output for g, r in zip(reqs, ref_reqs)
               if g.status != "shed")
    assert check_fleet_invariants(fleet)["ok"]


def test_fleet_hedge_off_is_bitwise_identical(tiny_cfg):
    plain = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256,
                        rl_accuracy=1.0, detector=DetectorConfig())
    p_reqs = plain.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=12),
                       arrivals=[0.5 * i for i in range(8)])
    off = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                      seed=0, max_batch=4, capacity=256,
                      rl_accuracy=1.0, detector=DetectorConfig(),
                      hedge=HedgeConfig(enabled=False))
    o_reqs = off.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=12),
                     arrivals=[0.5 * i for i in range(8)])
    assert [g.output for g in o_reqs] == [g.output for g in p_reqs]
    assert sum(off.hedge.counters().values()) == 0


# --------------------------------------------------------------------- #
# autoscaler: registry-sourced attainment (satellite of this tier)
# --------------------------------------------------------------------- #
def test_autoscaler_registry_mode_is_decision_identical():
    """``bind_registry`` swaps the private rolling window for counter
    deltas over the obs registry series — every decision must match the
    legacy list mode step for step, including across invalidations."""
    cfg = AutoscaleConfig(window=16, min_window=4, patience=2,
                          cooldown=10.0)
    legacy = GoodputAutoscaler(cfg)
    bound = GoodputAutoscaler(cfg)
    bound.bind_registry(MetricsRegistry())
    rng = np.random.default_rng(3)
    t = 0.0
    for step in range(400):
        t += float(rng.uniform(0.2, 1.0))
        met = bool(rng.random() < (0.7 if step % 120 < 60 else 0.999))
        legacy.record(met)
        bound.record(met)
        if step % 97 == 50:
            legacy.invalidate()
            bound.invalidate()
        assert legacy.attainment == bound.attainment
        args = (t, 3, 0, 0.5, True)
        assert legacy.decide(*args) == bound.decide(*args)
    assert legacy.events == bound.events
    assert len(legacy.events) >= 1       # the load pattern forced actions


def test_autoscaler_registry_counters_survive_window_reset():
    """Invalidation moves the controller's baseline, not the counters:
    the exported series stays monotonic for the dashboards."""
    reg = MetricsRegistry()
    auto = GoodputAutoscaler(AutoscaleConfig(window=8, min_window=2))
    auto.bind_registry(reg)
    for met in [True, False, True, True]:
        auto.record(met)
    fam = reg.counter("autoscaler_completions_total",
                      "completions observed by the autoscaler", ("met",))
    assert fam.labels(met="true").value == 3.0
    assert fam.labels(met="false").value == 1.0
    assert auto.attainment == 0.75
    auto.invalidate()
    # counters untouched; the window restarts empty
    assert fam.labels(met="true").value == 3.0
    assert auto.attainment is None
    for met in [True, True]:
        auto.record(met)
    assert fam.labels(met="true").value == 5.0
    assert auto.attainment == 1.0
