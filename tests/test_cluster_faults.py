"""Fault-tolerance battery: chaos injection, crash recovery with KV
re-migration, deadline-aware abort/shedding, the serve_stream stall
watchdog, submit validation, and the post-run conservation/leak audit.

Everything greedy is checked bitwise against a fault-free run — crash
recovery re-seeds through the deterministic recompute path, so a fleet
that loses an instance mid-run must still produce the exact token
streams of an undisturbed engine.
"""
import numpy as np
import pytest

from repro.cluster import (ChaosSpecError, EngineFleet, FaultEvent,
                           FaultInjector, InvariantViolation,
                           RecoveryConfig, backoff_delay,
                           check_fleet_invariants, parse_chaos_spec)
from repro.cluster.base import DEAD, HEALTHY, SUSPECT
from repro.cluster.sim import ClusterSim
from repro.configs import get_config
from repro.core import predictor, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.serving import (EngineConfig, FleetStalled, GenRequest,
                           InvalidRequestError, RequestShed, SamplingParams,
                           ServingEngine)
from repro.serving.engine import kv_checksum, serve_stream


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")


def _gen_reqs(cfg, n=6, seed=5, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(8, 24)))),
        params=SamplingParams(max_new_tokens=int(rng.integers(lo, hi)),
                              temperature=0.0))
        for _ in range(n)]


def _sim_trace(n, rate=6.0, seed=0):
    reqs = traces.generate(traces.SHAREGPT, n, seed=seed, rate=rate)
    predictor.annotate(reqs, predictor.NoisyPredictor(accuracy=0.75,
                                                      seed=seed), 0.15)
    return reqs


# --------------------------------------------------------------------- #
# fault injector mechanics
# --------------------------------------------------------------------- #
def test_parse_chaos_spec():
    evs = parse_chaos_spec("kill@25:1,freeze@40:2/20,slow@10:0/30x3,"
                           "corrupt@15,squeeze@30:1/0.25")
    assert [(e.kind, e.t, e.target) for e in evs] == [
        ("kill", 25.0, 1), ("freeze", 40.0, 2), ("slow", 10.0, 0),
        ("corrupt_kv", 15.0, -1), ("squeeze", 30.0, 1)]
    assert evs[1].duration == 20.0 and evs[2].factor == 3
    assert evs[4].frac == 0.25


def test_parse_chaos_spec_typed_errors_name_the_clause():
    """Every malformed clause raises ChaosSpecError carrying the exact
    offending clause text — a typo must never half-parse into a silently
    weakened chaos schedule."""
    for bad, fragment in [
        ("explode@3", "explode"),              # unknown kind
        ("kill@abc", "kill@abc"),              # non-numeric fire time
        ("kill25", "kill25"),                  # missing @
        ("freeze@", "freeze@"),                # empty remainder
        ("kill@5:x", "kill@5:x"),              # non-numeric target
        ("freeze@5:1/abc", "freeze@5:1/abc"),  # non-numeric duration
        ("slow@5:1/10xq", "slow@5:1/10xq"),    # non-numeric factor
        ("squeeze@5:1/1.5", "squeeze@5:1/1.5"),  # frac out of (0, 1]
    ]:
        with pytest.raises(ChaosSpecError) as ei:
            parse_chaos_spec(bad)
        assert fragment in str(ei.value), (bad, str(ei.value))
    # ChaosSpecError is a ValueError: generic callers still catch it
    with pytest.raises(ValueError):
        parse_chaos_spec("explode@3")


class _HealthStub:
    def __init__(self, iid):
        self.id = iid
        self.health = HEALTHY
        self.frozen_until = 0.0
        self.slow_until = 0.0
        self.slow_factor = 1

    @property
    def alive(self):
        return self.health != DEAD


def test_injector_scheduled_and_seeded_faults_deterministic():
    def run(seed):
        inj = FaultInjector(schedule=[FaultEvent(t=5.0, kind="kill",
                                                 target=0)],
                            p_freeze=0.2, seed=seed, min_alive=1)
        insts = [_HealthStub(i) for i in range(4)]
        for t in range(20):
            inj.poll(float(t), insts)
        return inj.log

    assert run(3) == run(3)                      # seeded: reproducible
    log = run(3)
    assert (5.0, "kill", 0) in log               # schedule always fires


def test_injector_probabilistic_kill_spares_last_instance():
    inj = FaultInjector(p_kill=1.0, seed=0, min_alive=1)
    insts = [_HealthStub(i) for i in range(3)]
    for t in range(10):
        inj.poll(float(t), insts)
    assert sum(1 for i in insts if i.alive) == 1


# --------------------------------------------------------------------- #
# crash recovery (real fleet): token equality with a fault-free run
# --------------------------------------------------------------------- #
def test_fleet_kill_recovery_token_equality(tiny_cfg):
    """Instance 1 of 3 dies mid-run: every in-flight request must be
    recovered elsewhere and the greedy streams must equal a fault-free
    single-engine run, with exactly-once terminal states and no leaks."""
    fleet = EngineFleet(
        tiny_cfg, n_instances=3, router="least-kvc", seed=0,
        max_batch=4, capacity=256, rl_accuracy=1.0,
        faults=FaultInjector(
            schedule=[FaultEvent(t=6.0, kind="kill", target=1)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=1.0))
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=8, lo=6, hi=14)
    ref.run(ref_reqs)

    reqs = fleet.run(_gen_reqs(tiny_cfg, n=8, lo=6, hi=14))
    cons = fleet.conservation()
    assert cons["ok"] and cons["aborted"] == 0 and cons["shed"] == 0, cons
    assert fleet.n_recovered >= 1        # the kill actually stranded work
    assert not fleet.instances[1].alive
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]
    assert check_fleet_invariants(fleet)["ok"]


def test_fleet_retry_budget_exhausts_to_abort(tiny_cfg):
    """With every instance dead, redelivery burns its bounded retries and
    lands in a terminal abort — never an infinite redeliver loop."""
    fleet = EngineFleet(
        tiny_cfg, n_instances=2, router="least-kvc", seed=0,
        max_batch=4, capacity=256, rl_accuracy=1.0,
        faults=FaultInjector(schedule=[
            FaultEvent(t=4.0, kind="kill", target=0),
            FaultEvent(t=4.0, kind="kill", target=1)]),
        recovery=RecoveryConfig(max_retries=2, backoff_base=1.0))
    reqs = fleet.run(_gen_reqs(tiny_cfg, n=4, lo=8, hi=16))
    cons = fleet.conservation()
    assert cons["ok"], cons              # all terminal, just not completed
    assert cons["aborted"] >= 1
    dead = [g for g in reqs if g.status == "aborted"]
    assert dead and all("retries-exhausted" in g.fail_reason
                        or g.fail_reason == "no-live-instance"
                        for g in dead)


def test_fleet_freeze_evacuates_queued_gts_via_kv_migration(tiny_cfg):
    """A frozen (suspect) instance's device state is intact: its queued
    GTs must be evacuated by real KV re-migration and finish elsewhere,
    token-equal to an undisturbed run."""
    fleet = EngineFleet(tiny_cfg, n_instances=2, router="round-robin",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0,
                        faults=FaultInjector(),   # enables fault paths
                        recovery=RecoveryConfig())
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=0)
    g_ref = _gen_reqs(tiny_cfg, n=1, lo=8, hi=9)[0]
    ref.run([g_ref])

    g = _gen_reqs(tiny_cfg, n=1, lo=8, hi=9)[0]
    iid = fleet.submit(g, 0.0)
    src = next(i for i in fleet.instances if i.id == iid)
    t = 0.0
    while not src.engine.scheduler.gt_queue:     # stop right after prefill
        t += 1.0
        src.engine.step(t)
    src.health = SUSPECT
    src.frozen_until = t + 1_000.0               # long outage
    while fleet.has_work() and t < 300.0:
        t += 1.0
        fleet.step(t)
    assert fleet.n_evacuations >= 1
    assert g.t_done is not None and g.output == g_ref.output
    assert fleet.conservation()["ok"]


def test_fleet_corrupt_kv_rejected_by_checksum(tiny_cfg):
    """A KV payload corrupted in flight must be refused at inject (crc)
    and degrade to the recompute fallback — bitwise-identical tokens."""
    fleet = EngineFleet(
        tiny_cfg, n_instances=2, roles=("prefill", "decode"),
        router="least-kvc", seed=0, max_batch=4, capacity=256,
        rl_accuracy=1.0,
        faults=FaultInjector(
            schedule=[FaultEvent(t=1.0, kind="corrupt_kv", count=2)]),
        recovery=RecoveryConfig())
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=6)
    ref.run(ref_reqs)
    reqs = fleet.run(_gen_reqs(tiny_cfg, n=6))
    cons = fleet.conservation()
    assert cons["ok"] and cons["kv_rejects"] >= 1, cons
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]


# --------------------------------------------------------------------- #
# abort: deadline enforcement, megastep windows, ring draining
# --------------------------------------------------------------------- #
def test_engine_abort_defers_across_open_megastep_window(tiny_cfg):
    eng = ServingEngine(tiny_cfg, max_batch=4, capacity=256,
                        rl_accuracy=1.0, seed=0)
    victim, bystander = _gen_reqs(tiny_cfg, n=2, lo=64, hi=65)
    t = 0.0
    eng.submit(victim, t)
    eng.submit(bystander, t)
    while eng._mega_left == 0:
        t += 1.0
        eng.step(t)
    assert eng.abort(victim.rid, t) is True
    assert victim.status is None         # deferred: window still open
    assert eng.abort(victim.rid, t) is True      # idempotent queueing
    assert len(eng._pending_aborts) == 1
    while eng.has_work() and t < 500:
        t += 1.0
        eng.step(t)
    assert victim.status == "aborted"
    assert bystander.t_done is not None
    assert len(bystander.output) == bystander.params.max_new_tokens
    assert not eng.slot_of and len(eng.free_slots) == eng.max_batch
    assert not eng.scheduler.kvc.allocs
    eng.scheduler.kvc.check_invariants()
    assert eng.abort(victim.rid, t) is False     # already terminal


def test_engine_abort_force_drains_lagged_ring(tiny_cfg):
    """Satellite: with readback_lag > 1, tokens the device produced but
    the host hasn't drained must materialize on abort — never drop."""
    ecfg = EngineConfig(readback_lag=3)
    eng = ServingEngine(tiny_cfg, max_batch=2, capacity=256,
                        rl_accuracy=1.0, seed=0, engine_cfg=ecfg)
    ref = ServingEngine(tiny_cfg, params=eng.params, max_batch=2,
                        capacity=256, rl_accuracy=1.0, seed=0)
    g_ref = _gen_reqs(tiny_cfg, n=1, lo=32, hi=33)[0]
    ref.run([g_ref])

    g = _gen_reqs(tiny_cfg, n=1, lo=32, hi=33)[0]
    t = 0.0
    eng.submit(g, t)
    while not eng._pending_drain:        # decode until the ring lags
        t += 1.0
        eng.step(t)
    drained_before = len(g.output)
    while eng._mega_left > 0:            # abort applies at window close
        t += 1.0
        eng.step(t)
    eng.abort(g.rid, t)
    assert g.status == "aborted"
    assert not eng._pending_drain        # ring force-drained, not dropped
    assert len(g.output) > drained_before or drained_before > 0
    # everything materialized is a prefix of the reference greedy stream
    assert g.output == g_ref.output[:len(g.output)] and g.output


def test_fleet_deadline_watchdog_aborts_overdue(tiny_cfg):
    fleet = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0,
                        recovery=RecoveryConfig(deadline_factor=2.0))
    hopeless = GenRequest(
        prompt=list(range(10)),
        params=SamplingParams(max_new_tokens=400, temperature=0.0),
        deadline=3.0)                    # ~400 iters of work, 3-iter SLO
    easy = _gen_reqs(tiny_cfg, n=2)
    fleet.run([hopeless] + easy)
    assert hopeless.status == "aborted"
    assert hopeless.fail_reason == "deadline"
    assert fleet.n_deadline_aborts >= 1
    assert all(g.t_done is not None for g in easy)
    cons = fleet.conservation()
    assert cons["ok"] and cons["aborted"] == 1, cons
    assert check_fleet_invariants(fleet)["ok"]


def test_fleet_sheds_admissions_projected_to_miss_slo(tiny_cfg):
    fleet = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0,
                        recovery=RecoveryConfig(shed=True))
    doomed = GenRequest(
        prompt=list(range(10)),
        params=SamplingParams(max_new_tokens=200, temperature=0.0),
        deadline=5.0)
    with pytest.raises(RequestShed):
        fleet.submit(doomed, 0.0)
    assert doomed.status == "shed"
    assert doomed.fail_reason == "projected-slo-miss"
    # the stream driver absorbs the typed rejection and carries on
    ok = _gen_reqs(tiny_cfg, n=2)
    reqs = fleet.run([GenRequest(
        prompt=list(range(10)),
        params=SamplingParams(max_new_tokens=200, temperature=0.0),
        deadline=5.0)] + ok)
    assert reqs[0].status == "shed"
    assert all(g.t_done is not None for g in ok)
    cons = fleet.conservation()
    assert cons["ok"] and cons["shed"] >= 1, cons


# --------------------------------------------------------------------- #
# submit validation (typed, at the boundary)
# --------------------------------------------------------------------- #
def test_submit_validation_typed_errors(tiny_cfg):
    eng = ServingEngine(tiny_cfg, max_batch=2, capacity=64, rl_accuracy=1.0)
    with pytest.raises(InvalidRequestError, match="max_new_tokens"):
        eng.submit(GenRequest(prompt=[1, 2],
                              params=SamplingParams(max_new_tokens=0)), 0.0)
    with pytest.raises(InvalidRequestError, match="empty prompt"):
        eng.submit(GenRequest(prompt=[],
                              params=SamplingParams(max_new_tokens=4)), 0.0)
    with pytest.raises(InvalidRequestError, match="exceeds capacity"):
        eng.submit(GenRequest(prompt=list(range(200)),
                              params=SamplingParams(max_new_tokens=4)), 0.0)
    assert not eng.has_work()            # rejected before any state change
    assert not eng.requests


# --------------------------------------------------------------------- #
# serve_stream stall watchdog
# --------------------------------------------------------------------- #
class _WedgedServer:
    """has_work forever, never progresses — the failure mode the watchdog
    must convert from an infinite spin into a diagnostic exception."""

    def submit(self, req, now):
        pass

    def has_work(self):
        return True

    def step(self, now):
        return 0

    def flush(self):
        pass

    def progress_state(self):
        return (0,)

    def debug_state(self):
        return {"pt_queue": 1, "gt_queue": 0, "kvc_free_blocks": 0}


def test_serve_stream_raises_fleet_stalled_with_diagnostics():
    with pytest.raises(FleetStalled) as ei:
        serve_stream(_WedgedServer(), [], stall_limit=40)
    assert "no progress for 40" in str(ei.value)
    assert ei.value.debug.get("kvc_free_blocks") == 0


def test_serve_stream_tolerates_quiet_recovery_gaps(tiny_cfg):
    """Legitimate chaos-induced quiet periods (backoff waits) must stay
    under the watchdog: a chaotic run with default stall_limit finishes."""
    fleet = EngineFleet(
        tiny_cfg, n_instances=3, router="least-kvc", seed=0,
        max_batch=4, capacity=256, rl_accuracy=1.0,
        faults=FaultInjector(
            schedule=[FaultEvent(t=5.0, kind="kill", target=2)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=4.0))
    reqs = fleet.run(_gen_reqs(tiny_cfg, n=6))
    assert fleet.conservation()["ok"]
    assert all(g.finished for g in reqs)


# --------------------------------------------------------------------- #
# inject_kv degradation under a full target
# --------------------------------------------------------------------- #
def test_inject_kv_full_target_swaps_to_recompute(tiny_cfg):
    """Satellite: a migration landing on an engine with no free slot must
    take the slotless swap-recompute fallback and still finish with the
    exact greedy stream."""
    src = ServingEngine(tiny_cfg, max_batch=4, capacity=256,
                        rl_accuracy=1.0, seed=0)
    dst = ServingEngine(tiny_cfg, params=src.params, max_batch=1,
                        capacity=256, rl_accuracy=1.0, seed=1)
    ref = ServingEngine(tiny_cfg, params=src.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=2)
    g_ref = _gen_reqs(tiny_cfg, n=1, lo=6, hi=7)[0]
    ref.run([g_ref])

    # occupy dst's only slot with a long-running request
    hog = _gen_reqs(tiny_cfg, n=1, seed=9, lo=64, hi=65)[0]
    t = 0.0
    dst.submit(hog, t)
    while not dst.slot_of:
        t += 1.0
        dst.step(t)

    g = _gen_reqs(tiny_cfg, n=1, lo=6, hi=7)[0]
    src.submit(g, t)
    while not src.scheduler.gt_queue:
        t += 1.0
        src.step(t)
    payload = src.export_kv(g.rid)
    assert payload["kv"] is not None
    assert not dst.free_slots
    dst.inject_kv(payload, t)
    while dst.has_work() and t < 800:
        t += 1.0
        dst.step(t)
    assert g.t_done is not None and g.output == g_ref.output
    assert hog.t_done is not None


# --------------------------------------------------------------------- #
# pressure ladder under chaos: squeeze, salvage, jittered backoff
# --------------------------------------------------------------------- #
def test_backoff_delay_seeded_jitter():
    """jitter=0 reproduces the legacy pure-exponential schedule bit for
    bit; with jitter on, delays are deterministic per (seed, rid,
    attempt), bounded by base*2^a*(1+jitter), and decorrelated across
    rids and seeds."""
    rc0 = RecoveryConfig(backoff_base=1.0)
    assert [backoff_delay(rc0, 7, a) for a in range(3)] == [1.0, 2.0, 4.0]
    rc = RecoveryConfig(backoff_base=1.0, jitter=0.5, jitter_seed=11)
    d1 = [backoff_delay(rc, 7, a) for a in range(4)]
    assert d1 == [backoff_delay(rc, 7, a) for a in range(4)]
    for a, d in enumerate(d1):
        base = 2.0 ** a
        assert base <= d <= base * 1.5
    rc2 = RecoveryConfig(backoff_base=1.0, jitter=0.5, jitter_seed=12)
    assert [backoff_delay(rc2, 7, a) for a in range(4)] != d1
    assert [backoff_delay(rc, 8, a) for a in range(4)] != d1


def _squeeze_fleet(tiny_cfg, frac):
    scfg = SchedulerConfig(kvc_tokens=224, block_size=16, tfs=128,
                           max_model_len=128, max_batch_reqs=4)
    return EngineFleet(
        tiny_cfg, n_instances=2, router="least-kvc", seed=0,
        max_batch=4, capacity=128, rl_accuracy=1.0, scheduler_cfg=scfg,
        faults=FaultInjector(schedule=[
            FaultEvent(t=3.0, kind="squeeze", target=0, frac=frac),
            FaultEvent(t=3.0, kind="squeeze", target=1, frac=frac)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=1.0))


def test_fleet_squeeze_mid_run_degrades_not_crashes(tiny_cfg):
    """Acceptance: a mid-run ``squeeze`` on a KVC-saturated fleet must
    walk the pressure ladder — no AllocationError escapes ``run``, every
    request lands completed|aborted|shed (here: all completed), greedy
    streams stay bitwise-equal to a pressure-free run, and the post-run
    audit finds no leaked ledger entries or host images."""
    fleet = _squeeze_fleet(tiny_cfg, 0.5)
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=10, lo=8, hi=16)
    ref.run(ref_reqs)

    reqs = fleet.run(_gen_reqs(tiny_cfg, n=10, lo=8, hi=16))
    cons = fleet.conservation()
    assert cons["ok"] and cons["aborted"] == 0 and cons["shed"] == 0, cons
    assert [g.output for g in reqs] == [g.output for g in ref_reqs]
    assert check_fleet_invariants(fleet)["ok"]
    for inst in fleet.instances:        # the cut landed and fully drained
        kvc = inst.engine.scheduler.kvc
        assert kvc.total_blocks <= 7 and kvc.pending_shrink == 0
    assert sum(i.engine.scheduler.n_preempt_swap
               + i.engine.scheduler.kvc.n_swap_outs
               for i in fleet.instances) >= 1    # pressure actually bit


def test_fleet_squeeze_sheds_permanently_infeasible(tiny_cfg):
    """Rung 4: a harder squeeze leaves some queued requests with frozen
    demand beyond even an empty post-shrink cache — they must end as
    terminal ``shed`` (reason ``kvc-infeasible``), not livelock the
    fleet, while every still-feasible request completes bitwise-equal
    to the pressure-free run."""
    fleet = _squeeze_fleet(tiny_cfg, 0.6)
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=0)
    ref_reqs = _gen_reqs(tiny_cfg, n=10, lo=8, hi=16)
    ref.run(ref_reqs)

    reqs = fleet.run(_gen_reqs(tiny_cfg, n=10, lo=8, hi=16))
    cons = fleet.conservation()
    assert cons["ok"], cons              # exactly-once terminal states
    assert cons["shed"] >= 1
    assert cons["completed"] + cons["shed"] + cons["aborted"] == 10
    assert check_fleet_invariants(fleet)["ok"]
    for g, r in zip(reqs, ref_reqs):
        if g.status == "shed":
            assert g.fail_reason == "kvc-infeasible"
        else:
            assert g.output == r.output


def test_fleet_kill_salvages_host_image_for_restore(tiny_cfg):
    """A host-pool KV image on a crashed engine outlives the device:
    recovery must attach the salvaged pages to the redelivered request
    (``n_salvaged_restores``) so the survivor restores instead of
    recomputing — and the stream still matches a fault-free run."""
    fleet = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=128, rl_accuracy=1.0,
                        recovery=RecoveryConfig(max_retries=3,
                                                backoff_base=0.5))
    ref = ServingEngine(tiny_cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=0)
    g_ref = _gen_reqs(tiny_cfg, n=1, lo=12, hi=13)[0]
    ref.run([g_ref])

    g = _gen_reqs(tiny_cfg, n=1, lo=12, hi=13)[0]
    t = 0.0
    fleet.submit(g, t)
    inst = fleet.instances[fleet.route_of[id(g)]]
    eng = inst.engine
    while len(g.output) < 4:
        t += 1.0
        fleet.step(t)
    # materialize the ring, then capture the page image with the same
    # extent formula the swap tier uses at a preemption sweep
    eng._drain_tokens(force=True)
    slot = eng.slot_of[g.rid]
    ctx = len(g.prompt) + len(g.output) - 1
    kv = {kind: {n: np.asarray(sub[n][:, slot, :ctx]) for n in ("k", "v")}
          for kind, sub in eng.caches.items()}
    eng._host_swap[g.rid] = {"kv": kv, "ctx": ctx, "crc": kv_checksum(kv)}
    inst.health = DEAD                   # crash before any restore
    while g.t_done is None and t < 400.0:
        t += 1.0
        fleet.step(t)
    assert fleet.n_salvaged_restores == 1
    assert g.t_done is not None and g.status != "aborted"
    assert g.output == g_ref.output
    assert check_fleet_invariants(fleet)["ok"]


# --------------------------------------------------------------------- #
# ClusterSim chaos + routing fallbacks
# --------------------------------------------------------------------- #
def test_sim_kill_mid_run_conserves_and_recovers():
    cost = CostModel()
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=3, router="least-kvc", seed=0,
                    faults=FaultInjector(schedule=[
                        FaultEvent(t=5.0, kind="kill", target=1)]),
                    recovery=RecoveryConfig(max_retries=3,
                                            backoff_base=0.5))
    res = cs.run(_sim_trace(200))
    cons = res.conservation()
    assert cons["ok"], cons
    assert res.n_recovered >= 1
    assert res.fault_log and res.fault_log[0][1] == "kill"


def test_sim_freeze_and_slow_degrade_without_loss():
    cost = CostModel()
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=3, router="least-kvc", seed=0,
                    faults=FaultInjector(schedule=[
                        FaultEvent(t=3.0, kind="freeze", target=0,
                                   duration=10.0),
                        FaultEvent(t=8.0, kind="slow", target=2,
                                   duration=15.0, factor=3)]),
                    recovery=RecoveryConfig())
    res = cs.run(_sim_trace(200))
    assert res.conservation()["ok"], res.conservation()
    assert len(res.fault_log) == 2


def test_sim_all_draining_router_fallback():
    """Satellite: when every instance is draining, arrivals must still be
    routed (to a role-eligible instance) rather than dropped."""
    cost = CostModel()
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=2, router="least-kvc", seed=0)
    for inst in cs.instances:
        inst.draining = True
    res = cs.run(_sim_trace(60))
    cons = res.conservation()
    assert cons["ok"] and cons["completed"] == 60, cons


def test_sim_whole_fleet_dead_aborts_terminally():
    cost = CostModel()
    cs = ClusterSim(lambda i: make_econoserve(SchedulerConfig(), cost),
                    cost, n_instances=2, router="least-kvc", seed=0,
                    faults=FaultInjector(schedule=[
                        FaultEvent(t=2.0, kind="kill", target=0),
                        FaultEvent(t=2.0, kind="kill", target=1)]),
                    recovery=RecoveryConfig(max_retries=1,
                                            backoff_base=0.5))
    res = cs.run(_sim_trace(80, rate=8.0))
    cons = res.conservation()
    assert cons["ok"], cons              # exactly-once: completed OR aborted
    assert cons["aborted"] >= 1
    assert cons["completed"] + cons["aborted"] == 80


# --------------------------------------------------------------------- #
# invariant checker actually detects corruption
# --------------------------------------------------------------------- #
def test_invariant_checker_flags_leaks(tiny_cfg):
    fleet = EngineFleet(tiny_cfg, n_instances=2, router="least-kvc",
                        seed=0, max_batch=4, capacity=256, rl_accuracy=1.0)
    fleet.run(_gen_reqs(tiny_cfg, n=4))
    assert check_fleet_invariants(fleet)["ok"]
    # a leaked slot must fail the audit
    leaked = fleet.instances[0].engine.free_slots.pop()
    with pytest.raises(InvariantViolation, match="slot leak"):
        check_fleet_invariants(fleet)
    rep = check_fleet_invariants(fleet, strict=False)
    assert not rep["ok"] and rep["problems"]
    # a non-terminal submitted request must fail it too
    fleet.instances[0].engine.free_slots.append(leaked)
    assert check_fleet_invariants(fleet)["ok"]
    fleet.submitted[0].status = None
    fleet.submitted[0].t_done = None
    with pytest.raises(InvariantViolation, match="non-terminal"):
        check_fleet_invariants(fleet)
