"""Tiered KVC degradation: host-offload KV swap + watermark guard.

The pressure ladder (lend → host swap → recompute → shed) must be
invisible in the token stream: at every rung a greedy run under KVC
pressure produces bitwise the streams of a pressure-free run. These
tests drive each rung explicitly — reactive preempt-swap capture and
restore, proactive watermark-guard swaps, budget-refused captures,
corrupt host images degrading to recompute — and check the swap ledger
conserves (``BlockKVC.check_invariants``) with nothing left behind.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.kvc import BlockKVC
from repro.core.pressure import EWMA, WatermarkGuard
from repro.core.scheduler import SchedulerConfig
from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                           ServingEngine)


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")


def _workload(cfg, n=10, seed=3):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(12, 28)))),
        params=SamplingParams(max_new_tokens=int(rng.integers(8, 20)),
                              temperature=0.0))
        for _ in range(n)]


def _engine(cfg, kvc_tokens, *, ecfg=None, acc=0.5, seed=0):
    scfg = SchedulerConfig(kvc_tokens=kvc_tokens, block_size=16, tfs=128,
                          max_model_len=128, max_batch_reqs=4)
    return ServingEngine(cfg, max_batch=4, capacity=128,
                         scheduler_cfg=scfg, rl_accuracy=acc, seed=seed,
                         engine_cfg=ecfg or EngineConfig())


def _run(cfg, kvc_tokens, **kw):
    eng = _engine(cfg, kvc_tokens, **kw)
    reqs = _workload(cfg)
    eng.run(reqs)
    return eng, [tuple(g.output) for g in reqs]


@pytest.fixture(scope="module")
def free_streams(cfg):
    """Pressure-free reference streams (KVC never binds)."""
    return _run(cfg, 6 * 128)[1]


# --------------------------------------------------------------------- #
# rung 2: reactive capture + restore
# --------------------------------------------------------------------- #
def test_preempt_swap_restores_without_recompute(cfg, free_streams):
    """Preempt-swapped GTs must come back via a host-pool page restore
    (n_swap_restores, zero extra prefill recompute), with streams equal
    to the pressure-free run and the ledger fully drained."""
    eng, out = _run(cfg, 160)
    s = eng.scheduler
    assert s.n_preempt_swap >= 1          # pressure actually bit
    assert eng.n_swap_captures >= 1
    assert eng.n_swap_restores == eng.n_swap_captures
    assert eng.n_swap_drops == 0 and eng.n_swap_rejects == 0
    assert out == free_streams
    s.kvc.check_invariants()
    assert not s.kvc.swapped and not eng._host_swap and not s.swap_hold
    assert s.kvc.n_swap_ins == eng.n_swap_restores


def test_host_swap_off_recomputes_same_streams(cfg, free_streams):
    """``host_swap=False`` keeps the pre-swap recompute behavior — same
    tokens, no captures."""
    eng, out = _run(cfg, 160, ecfg=EngineConfig(host_swap=False))
    assert eng.scheduler.n_preempt_swap >= 1
    assert eng.n_swap_captures == 0 and eng.n_swap_restores == 0
    assert out == free_streams


def test_swap_restore_skips_prefill_recompute(cfg):
    """The restore path must not ride the prefill wave: with host_swap on,
    preemptions add no whole-prompt prefill waves beyond the swap-off
    run minus its recompute re-prefills."""
    eng_on, out_on = _run(cfg, 160)
    eng_off, out_off = _run(cfg, 160, ecfg=EngineConfig(host_swap=False))
    assert out_on == out_off
    assert eng_on.n_swap_restores > eng_off.n_swap_restores == 0
    # restores ride the decode path: re-prefill waves can only shrink
    assert eng_on.n_prefill_waves <= eng_off.n_prefill_waves


# --------------------------------------------------------------------- #
# rung degradation: budget refusal and corruption -> recompute
# --------------------------------------------------------------------- #
def test_tiny_host_pool_degrades_to_recompute(cfg, free_streams):
    """A host pool too small for any image refuses every capture
    (n_swap_drops) and the ladder falls back to rung-3 recompute —
    streams still exact."""
    eng, out = _run(cfg, 160, ecfg=EngineConfig(host_pool_frac=0.01))
    assert eng.scheduler.n_preempt_swap >= 1
    assert eng.n_swap_drops >= 1 and eng.n_swap_restores == 0
    assert out == free_streams
    eng.scheduler.kvc.check_invariants()
    assert not eng.scheduler.kvc.swapped and not eng._host_swap


def test_corrupt_host_image_degrades_to_recompute(cfg, free_streams):
    """Flip a bit in every captured host image: the CRC check must refuse
    it (n_swap_rejects), recompute must take over, and the output stays
    bitwise-correct — a corrupt image never poisons a cache."""
    eng = _engine(cfg, 160)
    reqs = _workload(cfg)
    orig = eng._swap_out

    def corrupting(rid, slot):
        orig(rid, slot)
        img = eng._host_swap.get(rid)
        if img is not None:
            kind = sorted(img["kv"])[0]
            name = sorted(img["kv"][kind])[0]
            bad = np.array(img["kv"][kind][name])
            bad.flat[0] += 1.0
            img["kv"][kind][name] = bad
    eng._swap_out = corrupting
    eng.run(reqs)
    assert eng.n_swap_captures >= 1
    assert eng.n_swap_rejects == eng.n_swap_captures
    assert eng.n_swap_restores == 0
    assert [tuple(g.output) for g in reqs] == free_streams
    eng.scheduler.kvc.check_invariants()
    assert not eng.scheduler.kvc.swapped and not eng._host_swap


# --------------------------------------------------------------------- #
# proactive watermark guard
# --------------------------------------------------------------------- #
def test_watermark_guard_swaps_and_restores_bitwise(cfg, free_streams):
    """Aggressive watermarks force proactive guard swaps; trips/releases
    fire, victims are captured and restored, and the greedy streams stay
    equal to the pressure-free run."""
    ecfg = EngineConfig(swap_watermarks=True, guard_high=0.6,
                        guard_low=0.3, guard_patience=1)
    eng, out = _run(cfg, 240, ecfg=ecfg)
    s = eng.scheduler
    assert eng.guard.n_trips >= 1 and eng.guard.n_releases >= 1
    assert s.n_guard_swaps >= 1
    assert eng.n_swap_restores >= 1
    assert out == free_streams
    s.kvc.check_invariants()
    assert not s.kvc.swapped and not eng._host_swap and not s.swap_hold


def test_guard_hysteresis_state_machine():
    g = WatermarkGuard(high=0.9, low=0.5, alpha=1.0, patience=2)
    assert g.observe(0.95) is False       # patience: first sighting
    assert g.observe(0.95) is True        # second consecutive -> trip
    assert g.n_trips == 1
    assert g.observe(0.7) is True         # between watermarks: hold
    assert g.observe(0.4) is False        # below low -> release
    assert g.n_releases == 1
    g2 = WatermarkGuard(high=0.9, low=0.5, alpha=1.0, patience=2)
    assert g2.observe(0.95) is False
    assert g2.observe(0.7) is False       # dip resets patience
    assert g2.observe(0.95) is False and g2.n_trips == 0


def test_ewma_seeded_by_first_sample():
    e = EWMA(alpha=0.5)
    assert e.update(10.0) == 10.0         # primed, not pulled toward 0
    assert e.update(0.0) == 5.0


def test_megastep_windows_guard_keeps_streams_bitwise(cfg):
    """The guard only observes at megastep window boundaries, so K=8
    fused decode sees fewer samples and may swap less often than K=1 —
    but both must swap at least once here and the greedy streams must
    stay bitwise-identical."""
    def run(k):
        ecfg = EngineConfig(swap_watermarks=True, guard_high=0.6,
                            guard_low=0.3, guard_patience=1,
                            decode_megastep=k)
        return _run(cfg, 240, ecfg=ecfg)
    eng1, out1 = run(1)
    eng8, out8 = run(8)
    assert out1 == out8
    for eng in (eng1, eng8):
        assert eng.scheduler.n_guard_swaps >= 1
        assert eng.n_swap_restores >= 1
        assert not eng._host_swap and not eng.scheduler.kvc.swapped


# --------------------------------------------------------------------- #
# swap ledger budget mechanics (unit level)
# --------------------------------------------------------------------- #
def test_ledger_budget_evicts_oldest_unpinned():
    k = BlockKVC(1024, 32, host_pool_tokens=100)
    assert k.swap_register(1, 40) == []
    assert k.swap_register(2, 40) == []
    k.swap_pin(1)
    # 3rd image: pool full, oldest unpinned (rid 2) evicted; pinned rid 1
    # survives
    assert k.swap_register(3, 40) == [2]
    assert sorted(k.swapped) == [1, 3] and k.host_used == 80
    k.check_invariants()
    # an image that cannot fit even after evicting everything unpinned
    assert k.swap_register(4, 80) is None
    k.swap_unpin(1)
    assert k.swap_register(5, 100) == [1, 3]
    k.check_invariants()
    assert k.swap_release(5, restored=True) == 100
    assert k.n_swap_ins == 1 and k.host_used == 0
    k.check_invariants()


def test_shrink_harvests_from_frees():
    k = BlockKVC(320, 32)                 # 10 blocks
    assert k.allocate(1, 200)             # 7 blocks held
    got = k.shrink(160)                   # want 5, only 3 free
    assert got == 3 and k.pending_shrink == 2
    k.check_invariants()
    k.free(1)                             # harvest the 2 owed blocks
    assert k.pending_shrink == 0 and k.total_blocks == 5
    k.check_invariants()
