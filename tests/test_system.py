"""End-to-end behaviour tests for the EconoServe system: trace in, SLO-
accounted responses out, on both the simulator and the real CPU engine."""
import numpy as np

from repro.core import registry, traces
from repro.core.costmodel import CostModel, ModelProfile
from repro.core.scheduler import SchedulerConfig
from repro.configs import get_config


def test_paper_pipeline_simulator():
    """The full paper pipeline: calibrated trace -> RL prediction with
    sweet-spot padding -> EconoServe scheduling -> SLO accounting."""
    reqs = traces.generate(traces.SHAREGPT, 200, seed=0, rate=2.0)
    cost = CostModel(model=ModelProfile.from_config(get_config("opt-13b")))
    res = registry.run_one("econoserve", reqs, SchedulerConfig(), cost,
                           pad_ratio=0.15, accuracy=0.732)
    s = res.summary()
    assert s["completed"] == 200
    assert s["ssr"] > 0.5
    assert s["alloc_fail_rate"] < 0.01
    assert 0 < s["kvc_util"] <= 1
    assert res.jct_breakdown()["exec"] > 0


def test_engine_end_to_end_under_econoserve():
    from repro.serving import GenRequest, SamplingParams, ServingEngine
    cfg = get_config("stablelm-12b").reduced().with_(dtype="float32",
                                                     param_dtype="float32")
    eng = ServingEngine(cfg, max_batch=4, capacity=128)
    rng = np.random.default_rng(0)
    reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                       params=SamplingParams(max_new_tokens=6))
            for _ in range(5)]
    eng.run(reqs)
    assert all(g.t_done is not None for g in reqs)
    assert all(len(g.output) == 6 for g in reqs)


def test_every_paper_scheduler_available():
    assert set(registry.SCHEDULERS) >= {
        "orca", "srtf", "fastserve", "vllm", "sarathi", "multires",
        "synccoupled", "econoserve", "econoserve-d", "econoserve-sd",
        "econoserve-sdo", "oracle", "distserve"}
