"""Sharding rules + a small-mesh dry-run in a subprocess (device count must
be set before jax init, so it cannot run in the main test process)."""
import json
import subprocess
import sys
import textwrap

import pytest

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import model as model_lib
from repro.models.common import EMBED, EXPERT, HEADS, MLP, VOCAB


def test_spec_priority_model_axis():
    assert shd.spec_for_axes((EMBED, HEADS)) == \
        shd.spec_for_axes((EMBED, HEADS))
    p = shd.spec_for_axes((EMBED, HEADS))
    assert tuple(p) == ("data", "model")
    p = shd.spec_for_axes((EXPERT, EMBED, MLP))
    assert tuple(p) == ("model", "data", None)
    p = shd.spec_for_axes((VOCAB, EMBED))
    assert tuple(p) == ("model", "data")


def test_param_specs_cover_every_param():
    for arch in ("qwen3_8b", "arctic_480b", "zamba2_7b", "xlstm_125m"):
        cfg = get_config(arch)
        tree = model_lib.param_tree(cfg)
        specs = shd.param_specs(cfg)
        assert set(specs) == set(tree)
        for k, meta in tree.items():
            assert len(tuple(specs[k])) <= len(meta.shape)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    os.environ["JAX_PLATFORMS"] = "cpu"   # no accelerator probing
    import json, jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.shapes import ShapeSpec, build_step
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    out = {}
    for arch, kind in [("qwen3-8b", "train"), ("zamba2-7b", "decode"),
                       ("phi3.5-moe-42b-a6.6b", "prefill")]:
        cfg = get_config(arch).reduced(d_model=256).with_(vocab_size=512)
        shape = {"train": ShapeSpec("t", "train", 256, 8),
                 "prefill": ShapeSpec("p", "prefill", 256, 8),
                 "decode": ShapeSpec("d", "decode", 512, 16)}[kind]
        step, args, kw = build_step(cfg, shape, mesh)
        with mesh:
            compiled = jax.jit(step, **kw).lower(*args).compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):   # older jax: list of dicts
            cost = cost[0] if cost else {}
        out[arch] = {"flops": float(cost.get("flops", 0))}
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    # JAX_PLATFORMS=cpu must reach the subprocess from the outside too:
    # the in-script assignment runs before `import jax`, but some jax
    # versions probe TPU metadata from the plugin discovery path, which
    # stalls ~8 min on CPU boxes — the env var is the supported switch
    r = subprocess.run([sys.executable, "-c", _SUBPROC], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert len(out) == 3
    for arch, rec in out.items():
        assert rec["flops"] > 0
