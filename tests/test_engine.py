"""Serving engine integration: continuous batching == isolated decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.serving import GenRequest, SamplingParams, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    return ServingEngine(cfg, max_batch=4, capacity=128, rl_accuracy=1.0)


def _requests(cfg, n, seed=0, max_tokens=(3, 12)):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, rng.integers(4, 20))),
        params=SamplingParams(
            max_new_tokens=int(rng.integers(*max_tokens))))
        for _ in range(n)]


def _ref_greedy(cfg, params, prompt, n):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(cfg, params, toks)
    cache = model.init_cache(cfg, 1, capacity=128, dtype=jnp.float32)
    cache = model.seed_cache(cfg, cache, caches, len(prompt))
    cur = int(jnp.argmax(logits[0, -1]))
    out = [cur]
    for i in range(n - 1):
        lg, cache = model.decode_step(
            cfg, params, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray([len(prompt) + i], jnp.int32), cache)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
    return out


def test_continuous_batching_matches_isolated_greedy(engine):
    cfg = engine.cfg
    reqs = _requests(cfg, 6)
    engine_out = engine.run(reqs)
    for g in engine_out:
        assert g.t_done is not None
        assert len(g.output) == g.params.max_new_tokens
        ref = _ref_greedy(cfg, engine.params, g.prompt,
                          g.params.max_new_tokens)
        assert ref == g.output


def test_eos_early_stop():
    cfg = get_config("musicgen_large").reduced().with_(
        dtype="float32", param_dtype="float32")
    eng = ServingEngine(cfg, max_batch=2, capacity=96, rl_accuracy=1.0)
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab_size, 8))
    ref = _ref_greedy(cfg, eng.params, prompt, 16)
    # pick the second emitted token as "EOS" so it must stop at 2 tokens
    eos = ref[1]
    g = GenRequest(prompt=prompt,
                   params=SamplingParams(max_new_tokens=16, eos_token=eos))
    eng.run([g])
    assert g.output[-1] == eos
    assert len(g.output) < 16


def test_scheduler_stats_exposed(engine):
    # after the module-scoped runs the scheduler accounted everything
    s = engine.scheduler
    s.kvc.check_invariants()
    assert s.completed


def test_prefill_compile_count_bounded(engine):
    """Bucketed prefill: distinct traced shapes <= ceil(log2(max_prompt))
    (power-of-two sequence buckets at a fixed batch dimension)."""
    import math
    assert engine._pad_prefill
    max_ctx = max(len(g.prompt) + len(g.output)
                  for g in engine.requests.values())
    bound = max(1, math.ceil(math.log2(max(2, max_ctx))))
    assert engine.n_prefill_compiles <= bound
    assert len({b for b, _ in engine._prefill_shapes}) == 1  # one batch dim


def test_per_request_temperatures_not_collapsed():
    """Mixed greedy + hot-temperature batches: the greedy request must
    decode exactly its isolated greedy sequence (the old engine collapsed
    all temperatures to max(), breaking greedy requests)."""
    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    eng = ServingEngine(cfg, max_batch=4, capacity=128, rl_accuracy=1.0,
                        seed=3)
    rng = np.random.default_rng(5)
    greedy = GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, 9)),
        params=SamplingParams(max_new_tokens=8, temperature=0.0))
    hot = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, 7)),
        params=SamplingParams(max_new_tokens=8, temperature=1.5, top_k=3))
        for _ in range(2)]
    eng.run([greedy] + hot)
    want = _ref_greedy(cfg, eng.params, greedy.prompt, 8)
    assert greedy.output == want
    for g in hot:
        assert len(g.output) == 8


def test_recurrent_model_exact_prefill_fallback():
    """Models with recurrent blocks cannot take padded prefill (pad tokens
    would corrupt the state) — the engine must fall back and still serve."""
    cfg = get_config("xlstm_125m").reduced().with_(dtype="float32",
                                                   param_dtype="float32")
    eng = ServingEngine(cfg, max_batch=2, capacity=64, rl_accuracy=1.0)
    assert not eng._pad_prefill
    rng = np.random.default_rng(2)
    reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 5 + i)),
                       params=SamplingParams(max_new_tokens=4))
            for i in range(2)]
    eng.run(reqs)
    for g, n in zip(reqs, (5, 6)):
        assert g.t_done is not None
        assert len(g.output) == 4
        assert g.output == _ref_greedy(cfg, eng.params, g.prompt, 4)
