"""Serving engine integration: continuous batching == isolated decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model
from repro.serving import GenRequest, SamplingParams, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    return ServingEngine(cfg, max_batch=4, capacity=128, rl_accuracy=1.0)


def _requests(cfg, n, seed=0, max_tokens=(3, 12)):
    rng = np.random.default_rng(seed)
    return [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, rng.integers(4, 20))),
        params=SamplingParams(
            max_new_tokens=int(rng.integers(*max_tokens))))
        for _ in range(n)]


def _ref_greedy(cfg, params, prompt, n):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(cfg, params, toks)
    cache = model.init_cache(cfg, 1, capacity=128, dtype=jnp.float32)
    cache = model.seed_cache(cfg, cache, caches, len(prompt))
    cur = int(jnp.argmax(logits[0, -1]))
    out = [cur]
    for i in range(n - 1):
        lg, cache = model.decode_step(
            cfg, params, jnp.asarray([[cur]], jnp.int32),
            jnp.asarray([len(prompt) + i], jnp.int32), cache)
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
    return out


def test_continuous_batching_matches_isolated_greedy(engine):
    cfg = engine.cfg
    reqs = _requests(cfg, 6)
    engine_out = engine.run(reqs)
    for g in engine_out:
        assert g.t_done is not None
        assert len(g.output) == g.params.max_new_tokens
        ref = _ref_greedy(cfg, engine.params, g.prompt,
                          g.params.max_new_tokens)
        assert ref == g.output


def test_eos_early_stop():
    cfg = get_config("musicgen_large").reduced().with_(
        dtype="float32", param_dtype="float32")
    eng = ServingEngine(cfg, max_batch=2, capacity=96, rl_accuracy=1.0)
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab_size, 8))
    ref = _ref_greedy(cfg, eng.params, prompt, 16)
    # pick the second emitted token as "EOS" so it must stop at 2 tokens
    eos = ref[1]
    g = GenRequest(prompt=prompt,
                   params=SamplingParams(max_new_tokens=16, eos_token=eos))
    eng.run([g])
    assert g.output[-1] == eos
    assert len(g.output) < 16


def test_scheduler_stats_exposed(engine):
    # after the module-scoped runs the scheduler accounted everything
    s = engine.scheduler
    s.kvc.check_invariants()
    assert s.completed
