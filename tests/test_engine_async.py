"""Async device-resident decode + token-packed prefill must be drop-in
equivalent to the legacy sync / padded-batch engine paths: identical token
streams, completion times, and scheduler decisions for every toggle
combination (the `EngineConfig` convention mirrors PR 1's
`incremental_queues`: new path default-on, legacy kept for these tests)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                           ServingEngine)

LEGACY = EngineConfig(async_decode=False, packed_prefill=False)
ASYNC_PACKED = EngineConfig(async_decode=True, packed_prefill=True)


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3_8b").reduced(d_model=128).with_(
        dtype="float32", param_dtype="float32")


def _engine(cfg, ecfg, *, seed=0, rl_accuracy=1.0, max_batch=4,
            capacity=96):
    return ServingEngine(cfg, max_batch=max_batch, capacity=capacity,
                         rl_accuracy=rl_accuracy, seed=seed,
                         engine_cfg=ecfg)


def _workload(cfg, n=6, seed=0, eos_token=None, temp_every=3):
    """Mixed greedy / hot-temperature / (optionally) EOS-bearing requests."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 18))
        temp = 0.0 if i % temp_every else 1.3
        top_k = 0 if temp == 0.0 else 4
        reqs.append(GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, plen)),
            params=SamplingParams(max_new_tokens=int(rng.integers(3, 9)),
                                  temperature=temp, top_k=top_k,
                                  eos_token=eos_token)))
    return reqs


def _fingerprint(eng, reqs):
    """Token streams + completion times + scheduler decisions."""
    per_req = [(g.rid, tuple(g.output), g.t_done) for g in reqs]
    s = eng.scheduler
    sched = (tuple(s.iter_completion_counts),
             tuple((r.rid, r.t_complete, r.generated, r.n_preemptions)
                   for r in s.completed),
             s.n_preempt_free, s.n_preempt_swap, s.n_underprov,
             s.n_hosted, s.n_reserve_rescues)
    return per_req, sched


@pytest.mark.parametrize("ecfg", [
    ASYNC_PACKED,
    EngineConfig(async_decode=True, packed_prefill=False),
    EngineConfig(async_decode=False, packed_prefill=True),
], ids=["async+packed", "async-only", "packed-only"])
def test_async_and_packed_match_legacy(cfg, ecfg):
    ref_eng = _engine(cfg, LEGACY)
    ref_reqs = _workload(cfg)
    ref_eng.run(ref_reqs)

    eng = _engine(cfg, ecfg)
    reqs = _workload(cfg)
    eng.run(reqs)
    assert _fingerprint(eng, reqs) == _fingerprint(ref_eng, ref_reqs)


def test_async_eos_same_iteration_as_sync(cfg):
    """EOS completions (token stream truncation AND completion timestamps)
    must land at the same iteration with async_decode on and off."""
    probe = _engine(cfg, LEGACY)
    preqs = _workload(cfg)
    probe.run(preqs)
    # an EOS that actually fires mid-stream for request 0 (the probe runs
    # the *same* workload shape, so the token streams match until EOS)
    eos = preqs[0].output[1]

    outs = []
    for ecfg in (LEGACY, ASYNC_PACKED):
        eng = _engine(cfg, ecfg)
        reqs = _workload(cfg, eos_token=eos)
        eng.run(reqs)
        outs.append(_fingerprint(eng, reqs))
        for g in reqs:      # EOS must terminate the stream when it fires
            if eos in g.output:
                assert g.output[-1] == eos
    assert outs[0] == outs[1]
    assert any(len(g.output) < g.params.max_new_tokens for g in reqs)


def test_async_equivalence_under_preemption(cfg):
    """An always-wrong RL predictor with no padding and no reserve forces
    under-provision preemptions and offload-free re-prefills; the drain
    ring must materialize outputs before the recompute context is rebuilt,
    keeping both paths bitwise identical."""
    from repro.core.scheduler import SchedulerConfig

    def run(ecfg):
        mb, cap = 4, 96
        scfg = SchedulerConfig(kvc_tokens=mb * cap, block_size=16, tfs=cap,
                               max_model_len=cap, max_batch_reqs=mb,
                               pad_ratio=0.0, reserve_frac=0.0, bucket=8)
        eng = ServingEngine(cfg, max_batch=mb, capacity=cap,
                            rl_accuracy=0.0, seed=0, scheduler_cfg=scfg,
                            engine_cfg=ecfg)
        rng = np.random.default_rng(5)
        reqs = [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(4, 18)))),
            params=SamplingParams(
                max_new_tokens=int(rng.integers(12, 28))))
            for _ in range(6)]
        eng.run(reqs)
        return eng, reqs

    ref_eng, ref_reqs = run(LEGACY)
    eng, reqs = run(ASYNC_PACKED)
    assert _fingerprint(eng, reqs) == _fingerprint(ref_eng, ref_reqs)
    # the scenario actually exercised a preemption + re-prefill
    assert ref_eng.scheduler.n_preempt_free > 0


def test_swap_preempted_gt_is_recomputed(cfg):
    """offload_free=False routes every under-provision through the swap
    path: the GT re-queues holding its KV 'in host memory', loses its
    engine slot, and is later rescheduled as a running GT without a
    prefill item. The engine must rebuild its context (recompute-prefill)
    instead of crashing on the missing slot — identically on both paths."""
    from repro.core.scheduler import SchedulerConfig

    def run(ecfg):
        mb, cap = 4, 96
        scfg = SchedulerConfig(kvc_tokens=mb * cap, block_size=16, tfs=cap,
                               max_model_len=cap, max_batch_reqs=mb,
                               pad_ratio=0.0, reserve_frac=0.0, bucket=8,
                               offload_free=False)
        eng = ServingEngine(cfg, max_batch=mb, capacity=cap,
                            rl_accuracy=0.0, seed=0, scheduler_cfg=scfg,
                            engine_cfg=ecfg)
        rng = np.random.default_rng(5)
        reqs = [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(4, 18)))),
            params=SamplingParams(
                max_new_tokens=int(rng.integers(12, 28))))
            for _ in range(6)]
        eng.run(reqs)
        return eng, reqs

    ref_eng, ref_reqs = run(LEGACY)
    assert ref_eng.scheduler.n_preempt_swap > 0     # scenario really swaps
    for g in ref_reqs:
        assert g.t_done is not None
        assert len(g.output) == g.params.max_new_tokens
    eng, reqs = run(ASYNC_PACKED)
    assert _fingerprint(eng, reqs) == _fingerprint(ref_eng, ref_reqs)


def test_packed_prefill_matches_exact_per_item(cfg):
    """Block-diagonal packed prefill vs one exact-shape call per item
    (greedy-only: the exact path runs each item as its own sampling batch,
    so stochastic draws would not be comparable row-for-row)."""
    packed = _engine(cfg, EngineConfig(async_decode=False,
                                       packed_prefill=True))
    exact = _engine(cfg, LEGACY)
    exact._pad_prefill = False      # force the per-item exact-shape path
    exact._packed = False

    rng = np.random.default_rng(4)
    mk = lambda: [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 16)))),
        params=SamplingParams(max_new_tokens=5)) for _ in range(5)]
    rng = np.random.default_rng(4)
    r1 = mk()
    rng = np.random.default_rng(4)
    r2 = mk()
    packed.run(r1)
    exact.run(r2)
    assert [g.output for g in r1] == [g.output for g in r2]


def test_packed_prefill_no_batch_padding(cfg):
    """The packed path must trace flattened (1, T) shapes only — no
    max_batch-row padding — and stay within the pow2 compile bound."""
    eng = _engine(cfg, ASYNC_PACKED)
    eng.run(_workload(cfg))
    assert eng._packed
    assert {b for b, _ in eng._prefill_shapes} == {1}
    assert all(s % 16 == 0 for _, s in eng._prefill_shapes)


def test_steady_state_decode_has_no_eos_readbacks(cfg):
    """With no EOS-capable request active, the async decode loop never
    reads flags back; tokens reach the host only through the lag ring and
    completion flushes."""
    eng = _engine(cfg, ASYNC_PACKED)
    reqs = _workload(cfg, eos_token=None)
    eng.run(reqs)
    assert eng.decode_iters > 0
    assert eng.sync_counts["eos_flags"] == 0
    total_drained = (eng.sync_counts["drain_ready"]
                     + eng.sync_counts["drain_blocking"]
                     + eng.sync_counts["flush"])
    assert total_drained > 0                     # ring actually used
    for g in reqs:                               # and fully flushed
        assert len(g.output) == g.params.max_new_tokens


def test_device_resident_state_not_read_per_iteration(cfg):
    """The async engine's host mirrors of last_tok never advance during
    decode — proof the loop is device-resident (the sync path advances
    them every iteration)."""
    eng = _engine(cfg, ASYNC_PACKED)
    reqs = _workload(cfg, n=2)
    eng.run(reqs)
    # mirrors only hold prefill-time seeds on the async path
    assert eng.decode_iters > 0
    assert int(eng.last_tok.sum()) == 0
