"""The incremental queue index (OrderedQueue) must reproduce the legacy
full-re-sort path's batch decisions exactly — same iterations, same batch
compositions, same completion order, bitwise-equal timings."""
import copy
import dataclasses

import pytest

from repro.core import predictor, simulator, traces
from repro.core.costmodel import CostModel
from repro.core.ordering import OrderedQueue, sort_queue
from repro.core.request import Request
from repro.core.scheduler import SchedulerConfig, make_econoserve


def _run(variant, incremental, reqs, rate_cfg=None, queue_index="skiplist"):
    cfg = rate_cfg or SchedulerConfig()
    cfg = dataclasses.replace(cfg, incremental_queues=incremental,
                              queue_index=queue_index)
    cost = CostModel()
    rr = copy.deepcopy(reqs)
    predictor.annotate(rr, predictor.NoisyPredictor(seed=0), 0.15)
    sched = make_econoserve(cfg, cost, variant)
    res = simulator.simulate(rr, sched, cost)
    return res


def _fingerprint(res):
    per_iter = [(s.t, s.forward_size, s.prompt_tokens, s.n_decode,
                 s.kvc_used_frac, s.kvc_alloc_frac, s.sched_time,
                 s.extra_time, s.n_completed) for s in res.samples]
    per_req = sorted((r.rid, r.t_complete, r.generated, r.n_preemptions)
                     for r in res.completed)
    return per_iter, per_req


@pytest.mark.parametrize("variant", ["full", "sdo"])
@pytest.mark.parametrize("rate", [2.0, 5.0])
@pytest.mark.parametrize("queue_index", ["skiplist", "list"])
def test_incremental_queues_bitwise_identical(variant, rate, queue_index):
    reqs = traces.generate(traces.SHAREGPT, 250, seed=3, rate=rate)
    legacy = _run(variant, False, reqs)
    fast = _run(variant, True, reqs, queue_index=queue_index)
    assert len(legacy.samples) == len(fast.samples)
    assert _fingerprint(legacy) == _fingerprint(fast)


@pytest.mark.parametrize("queue_index", ["skiplist", "list"])
def test_incremental_identical_with_tight_slos(queue_index):
    """Deadline buckets actually roll over here, exercising lazy re-keying."""
    reqs = traces.generate(traces.SHAREGPT, 150, seed=7, rate=4.0)
    for r in reqs:
        r.slo_deadline = r.arrival + 0.3 + (r.rid % 5) * 0.6
    legacy = _run("full", False, reqs)
    fast = _run("full", True, reqs, queue_index=queue_index)
    assert _fingerprint(legacy) == _fingerprint(fast)


@pytest.mark.parametrize("queue_index", ["skiplist", "list"])
def test_ordered_queue_matches_sort_queue_under_churn(queue_index):
    import random
    rng = random.Random(0)
    oq = OrderedQueue(is_gt=True, index=queue_index)
    plain = []
    now = 0.0
    rid = 0
    for _ in range(500):
        op = rng.random()
        if op < 0.5 or not plain:
            r = Request(rid=rid, prompt_len=rng.randrange(1, 400),
                        true_rl=rng.randrange(1, 400), arrival=now,
                        slo_deadline=now + rng.choice(
                            [0.1, 0.4, 1.5, 10.0, float("inf")]))
            r.padded_rl = r.true_rl
            r.occupied_kvc = rng.randrange(0, 2000)
            rid += 1
            oq.append(r)
            plain.append(r)
        elif op < 0.8:
            victim = rng.choice(plain)
            oq.remove(victim)
            plain.remove(victim)
        else:
            now += rng.random()
        assert oq.sorted_view(now) == sort_queue(plain, now, is_gt=True)
        assert list(oq) == plain
