"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_attention
from repro.kernels.paged_attention import paged_decode_attention

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,K,hd,win,cap", [
    (2, 256, 4, 2, 64, None, None),
    (1, 200, 8, 8, 128, None, None),       # MHA + ragged S (padding path)
    (2, 384, 4, 1, 64, 128, None),          # MQA + sliding window
    (1, 256, 2, 2, 64, None, 30.0),         # logit softcap
    (1, 130, 6, 3, 32, 64, None),           # odd everything
])
def test_flash_prefill_matches_ref(B, S, H, K, hd, win, cap, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=True, window=win, softcap=cap,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=win, softcap=cap)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seg_lens,win,cap", [
    ((48, 80),       None, None),
    ((17, 60, 51),   None, 30.0),            # ragged segments + softcap
    ((100, 28),      32,   None),            # sliding window within segments
    ((5, 3, 90, 30), None, None),            # tiny segments
])
def test_flash_prefill_segment_mask(seg_lens, win, cap, dtype):
    """Token-packed (block-diagonal) masking: a flattened batch of segments
    must match per-segment exact-shape attention."""
    B, H, K, hd = 1, 4, 2, 32
    S = sum(seg_lens)
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    seg = jnp.asarray(np.repeat(np.arange(len(seg_lens)), seg_lens)[None])
    out = flash_attention(q, k, v, causal=True, window=win, softcap=cap,
                          segment_ids=seg, block_q=32, block_k=32,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=win,
                               softcap=cap, segment_ids=seg)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])
    # the oracle itself equals isolated per-segment attention
    st = 0
    for L in seg_lens:
        alone = ref.flash_attention(q[:, st:st + L], k[:, st:st + L],
                                    v[:, st:st + L], causal=True,
                                    window=win, softcap=cap)
        np.testing.assert_allclose(
            want[:, st:st + L].astype(jnp.float32),
            alone.astype(jnp.float32), atol=TOLS[dtype], rtol=TOLS[dtype])
        st += L


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,S,plen,win,cap", [
    (64, 48, 40, None, None),               # prefix + chunk, padded C view
    (96, 17, 60, None, 30.0),               # ragged chunk + softcap
    (128, 33, 100, 48, None),               # sliding window across prefix
    (64, 48, 0, None, None),                # empty prefix (first chunk)
])
def test_flash_prefill_prefix_positions(C, S, plen, win, cap, dtype):
    """Chunked-prefill masking: explicit q/kv positions with a rectangular
    key axis (cache-prefix view of C slots, plen valid, then the chunk)
    must match (a) the positions-aware oracle and (b) the tail rows of a
    plain contiguous causal run over [prefix ++ chunk]."""
    from repro.kernels.flash_prefill import POS_INVALID
    B, H, K, hd = 2, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    kp = jax.random.normal(ks[3], (B, C, K, hd), dtype)
    vp = jax.random.normal(ks[4], (B, C, K, hd), dtype)
    qpos = jnp.broadcast_to(plen + jnp.arange(S), (B, S))
    slot = jnp.arange(C)
    kpos = jnp.broadcast_to(jnp.concatenate(
        [jnp.where(slot < plen, slot, POS_INVALID),
         plen + jnp.arange(S)]), (B, C + S))
    k_all = jnp.concatenate([kp, kc], axis=1)
    v_all = jnp.concatenate([vp, vc], axis=1)
    out = flash_attention(q, k_all, v_all, causal=True, window=win,
                          softcap=cap, q_positions=qpos, kv_positions=kpos,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k_all, v_all, causal=True, window=win,
                               softcap=cap, q_positions=qpos,
                               kv_positions=kpos)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])
    # oracle cross-check: chunk-over-prefix == tail of the contiguous run
    kf = jnp.concatenate([kp[:, :plen], kc], axis=1)
    vf = jnp.concatenate([vp[:, :plen], vc], axis=1)
    qf = jnp.concatenate(
        [jax.random.normal(ks[1], (B, plen, H, hd), dtype), q], axis=1)
    full = ref.flash_attention(qf, kf, vf, causal=True, window=win,
                               softcap=cap)
    np.testing.assert_allclose(
        want.astype(jnp.float32), full[:, plen:].astype(jnp.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Cp,spans,win,cap", [
    (64, ((40, 24), (0, 30)),          None, None),   # mid + first chunk
    (64, ((60, 17), (32, 33), (5, 8)), None, 30.0),   # ragged 3-wave
    (96, ((90, 20), (48, 40)),         64,   None),   # window across prefix
])
def test_flash_prefill_packed_chunk_mask(Cp, spans, win, cap, dtype):
    """Packed multi-request chunked prefill: the key axis carries every
    segment's own prefix view (per-slot positions, POS_INVALID beyond
    each seeded prefix) plus the packed chunk wave, with separate q/kv
    segment arrays. The kernel must match the oracle, and the oracle must
    equal each request's isolated prefix-attending call."""
    from repro.kernels.flash_prefill import POS_INVALID
    B, H, K, hd = 1, 4, 2, 32
    n = len(spans)
    T = sum(L for _, L in spans)
    ks = jax.random.split(jax.random.PRNGKey(11), 3 + n)
    q = jax.random.normal(ks[0], (B, T, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, T, K, hd), dtype)
    vc = jax.random.normal(ks[2], (B, T, K, hd), dtype)
    prefixes = [jax.random.normal(ks[3 + i], (2, B, Cp, K, hd), dtype)
                for i in range(n)]
    qpos = np.zeros((B, T), np.int32)
    qseg = np.zeros((B, T), np.int32)
    ppos = np.zeros((B, n * Cp), np.int32)
    pseg = np.zeros((B, n * Cp), np.int32)
    off = 0
    for i, (start, L) in enumerate(spans):
        qpos[:, off:off + L] = start + np.arange(L)
        qseg[:, off:off + L] = i
        slot = np.arange(Cp)
        ppos[:, i * Cp:(i + 1) * Cp] = np.where(slot < start, slot,
                                                POS_INVALID)
        pseg[:, i * Cp:(i + 1) * Cp] = i
        off += L
    k_all = jnp.concatenate([p[0] for p in prefixes] + [kc], axis=1)
    v_all = jnp.concatenate([p[1] for p in prefixes] + [vc], axis=1)
    kpos = jnp.asarray(np.concatenate([ppos, qpos], axis=1))
    kseg = jnp.asarray(np.concatenate([pseg, qseg], axis=1))
    out = flash_attention(q, k_all, v_all, causal=True, window=win,
                          softcap=cap, segment_ids=jnp.asarray(qseg),
                          kv_segment_ids=kseg,
                          q_positions=jnp.asarray(qpos), kv_positions=kpos,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k_all, v_all, causal=True, window=win,
                               softcap=cap, segment_ids=jnp.asarray(qseg),
                               kv_segment_ids=kseg,
                               q_positions=jnp.asarray(qpos),
                               kv_positions=kpos)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])
    # oracle cross-check: each packed segment equals its isolated
    # single-request prefix-attending call
    off = 0
    for i, (start, L) in enumerate(spans):
        qi = q[:, off:off + L]
        ki = jnp.concatenate([prefixes[i][0], kc[:, off:off + L]], axis=1)
        vi = jnp.concatenate([prefixes[i][1], vc[:, off:off + L]], axis=1)
        slot = np.arange(Cp)
        kpos_i = jnp.asarray(np.concatenate(
            [np.where(slot < start, slot, POS_INVALID)[None].repeat(B, 0),
             qpos[:, off:off + L]], axis=1))
        alone = ref.flash_attention(
            qi, ki, vi, causal=True, window=win, softcap=cap,
            q_positions=jnp.asarray(qpos[:, off:off + L]),
            kv_positions=kpos_i)
        np.testing.assert_allclose(
            want[:, off:off + L].astype(jnp.float32),
            alone.astype(jnp.float32), atol=TOLS[dtype], rtol=TOLS[dtype])
        off += L


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,page,MP", [
    (3, 8, 2, 64, 16, 5),
    (2, 4, 4, 128, 32, 4),
    (1, 8, 1, 64, 8, 7),                    # MQA
    (4, 2, 2, 32, 16, 3),                   # MHA tiny heads
])
def test_paged_decode_matches_ref(B, H, K, hd, page, MP, dtype):
    P = B * MP + 3
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (P, page, K, hd), dtype)
    vp = jax.random.normal(ks[2], (P, page, K, hd), dtype)
    rng = np.random.default_rng(0)
    bt = jnp.array(rng.permutation(P)[:B * MP].reshape(B, MP).astype(np.int32))
    cl = jnp.array(rng.integers(1, MP * page, B).astype(np.int32))
    out = paged_decode_attention(q, kp, vp, bt, cl, interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOLS[dtype], rtol=TOLS[dtype])


def _paged_case(B, H, K, hd, page, MP, dtype=jnp.float32, seed=1):
    P = B * MP + 3
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (P, page, K, hd), dtype)
    vp = jax.random.normal(ks[2], (P, page, K, hd), dtype)
    rng = np.random.default_rng(seed)
    bt = jnp.array(rng.permutation(P)[:B * MP].reshape(B, MP).astype(np.int32))
    return q, kp, vp, bt


@pytest.mark.parametrize("pps", [2, 3, 8])
def test_paged_decode_multipage_bit_identical_to_single_page(pps):
    """The pages_per_step tiling only batches DMA — the flash update order
    is unchanged, so outputs must be *bitwise* equal to one page per step."""
    B, H, K, hd, page, MP = 3, 8, 2, 64, 16, 5
    q, kp, vp, bt = _paged_case(B, H, K, hd, page, MP)
    cl = jnp.array([7, 40, MP * page], jnp.int32)
    one = paged_decode_attention(q, kp, vp, bt, cl, pages_per_step=1,
                                 interpret=True)
    many = paged_decode_attention(q, kp, vp, bt, cl, pages_per_step=pps,
                                  interpret=True)
    assert np.array_equal(np.asarray(one), np.asarray(many))


@pytest.mark.parametrize("softcap", [None, 25.0])
def test_paged_decode_gqa_softcap(softcap):
    """GQA (H > K) with softcap on/off, multi-page tile."""
    B, H, K, hd, page, MP = 2, 8, 2, 64, 16, 6
    q, kp, vp, bt = _paged_case(B, H, K, hd, page, MP, seed=4)
    cl = jnp.array([50, 90], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, cl, softcap=softcap,
                                 pages_per_step=4, interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, bt, cl, softcap=softcap)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_decode_context_shorter_than_one_page():
    """Contexts inside the first page: every later grid step must early-exit
    without touching its pages."""
    B, H, K, hd, page, MP = 3, 4, 4, 32, 32, 8
    q, kp, vp, bt = _paged_case(B, H, K, hd, page, MP, seed=5)
    cl = jnp.array([1, 5, 31], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, cl, pages_per_step=4,
                                 interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_paged_decode_context_equals_capacity():
    """context == max_pages * page: the final (partial) tile is exercised."""
    B, H, K, hd, page, MP = 2, 4, 2, 64, 16, 5   # 5 pages, pps 2 -> tail 1
    q, kp, vp, bt = _paged_case(B, H, K, hd, page, MP, seed=6)
    cl = jnp.array([MP * page, MP * page], jnp.int32)
    out = paged_decode_attention(q, kp, vp, bt, cl, pages_per_step=2,
                                 interpret=True)
    want = ref.paged_decode_attention(q, kp, vp, bt, cl)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_contiguous_wrapper():
    from repro.kernels import ops
    B, C, K, hd, H = 2, 96, 2, 64, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    ck = jax.random.normal(ks[1], (B, C, K, hd))
    cv = jax.random.normal(ks[2], (B, C, K, hd))
    ctx = jnp.array([40, 96], jnp.int32)
    out = ops.decode_attention(q, ck, cv, ctx)
    mp = C // 32
    bt = (jnp.arange(B)[:, None] * mp + jnp.arange(mp)[None, :]).astype(jnp.int32)
    want = ref.paged_decode_attention(q, ck.reshape(B * mp, 32, K, hd),
                                      cv.reshape(B * mp, 32, K, hd), bt, ctx)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_kv_page_append_roundtrip():
    from repro.kernels.ref import kv_page_append
    B, page, K, hd, MP = 2, 8, 2, 16, 3
    P = B * MP
    kp = jnp.zeros((P, page, K, hd))
    vp = jnp.zeros((P, page, K, hd))
    bt = jnp.arange(P, dtype=jnp.int32).reshape(B, MP)
    k_new = jnp.ones((B, K, hd))
    pos = jnp.array([0, 13], jnp.int32)
    kp2, vp2 = kv_page_append(kp, vp, k_new, k_new * 2, bt, pos)
    assert float(kp2[bt[0, 0], 0].sum()) == K * hd
    assert float(kp2[bt[1, 1], 5].sum()) == K * hd
    assert float(vp2[bt[1, 1], 5].sum()) == 2 * K * hd
