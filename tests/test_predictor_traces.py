"""RL predictor + synthetic trace calibration tests."""
import numpy as np
import pytest

from repro.core import predictor, traces


def test_bucketize():
    assert predictor.bucketize(1) == 32
    assert predictor.bucketize(32) == 32
    assert predictor.bucketize(33) == 64


def test_oracle_exact_bucket():
    reqs = traces.generate(traces.ALPACA, 50, seed=0)
    p = predictor.OraclePredictor()
    for r in reqs:
        assert p.predict(r) == predictor.bucketize(r.true_rl)


def test_noisy_calibrated_accuracy():
    reqs = traces.generate(traces.SHAREGPT, 3000, seed=0)
    p = predictor.NoisyPredictor(accuracy=0.732, seed=1)
    hits = sum(p.predict(r) == predictor.bucketize(r.true_rl) for r in reqs)
    assert abs(hits / len(reqs) - 0.732) < 0.05


def test_learned_predictor_beats_constant():
    reqs = traces.generate(traces.SHAREGPT, 2000, seed=0)
    p = predictor.LearnedPredictor(seed=0)
    mse = p.fit(reqs[:1500])
    y = np.log([r.true_rl for r in reqs[1500:]])
    const_mse = float(np.mean((y - y.mean()) ** 2))
    preds = np.log([max(1, p.predict(r)) for r in reqs[1500:]])
    test_mse = float(np.mean((preds - y) ** 2))
    assert test_mse < const_mse * 1.35      # bucketing adds noise


def test_padding():
    assert predictor.apply_padding(100, 0.15) == 128
    assert predictor.apply_padding(100, 0.0) == 128  # bucket roundup only? no:
    # 100 * 1.0 -> bucketize(100) = 128


@pytest.mark.parametrize("spec", [traces.ALPACA, traces.SHAREGPT,
                                  traces.BOOKCORPUS])
def test_trace_statistics_match_table2(spec):
    reqs = traces.generate(spec, 4000, seed=3)
    plen = np.array([r.prompt_len for r in reqs])
    rl = np.array([r.true_rl for r in reqs])
    assert plen.min() >= spec.in_min and plen.max() <= spec.in_max
    assert rl.min() >= spec.out_min and rl.max() <= spec.out_max
    assert abs(plen.mean() / spec.in_mean - 1) < 0.35
    assert abs(rl.mean() / spec.out_mean - 1) < 0.35
    # Poisson arrivals at the configured rate
    T = reqs[-1].arrival
    assert abs(len(reqs) / T / spec.rate - 1) < 0.15


def test_rl_correlates_with_prompt():
    reqs = traces.generate(traces.SHAREGPT, 4000, seed=0)
    x = np.log([r.prompt_len for r in reqs])
    y = np.log([r.true_rl for r in reqs])
    rho = np.corrcoef(x, y)[0, 1]
    assert rho > 0.2
