"""Property-based state machine over the hedged-execution lifecycle.

Drives random interleavings of request tracking, progress, hedge
launches, race resolutions, clone deaths, and zombie completions
against one ``HedgeCoordinator``, and audits after every rule that the
racing invariants the backends rely on never break:

  * at most one winner per request, ever — and once recorded it never
    changes;
  * a cancelled loser is fenced: it can never deliver downstream, and
    every post-fence completion is counted (``record_fenced``) rather
    than delivered;
  * delivery epochs per request strictly increase — a reused epoch is
    rejected at launch time;
  * no hedge ever launches for a terminal (or already-resolved)
    request.

Skips cleanly when ``hypothesis`` is not installed — the deterministic
races in ``test_cluster_hedge.py`` cover the same surface
example-by-example.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import settings, strategies as st      # noqa: E402
from hypothesis.stateful import (RuleBasedStateMachine,  # noqa: E402
                                 invariant, rule)

from repro.cluster.hedge import (HedgeConfig,  # noqa: E402
                                 HedgeCoordinator, HedgeViolation)

N_HOSTS = 4
KEYS = st.integers(min_value=0, max_value=7)
HOSTS = st.integers(min_value=0, max_value=N_HOSTS - 1)


class HedgeLifecycleMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        # generous budget so abandon -> re-hedge interleavings occur
        self.coord = HedgeCoordinator(HedgeConfig(max_hedges=3))
        self.now = 0.0
        self.epoch = 0                   # global monotonic epoch source
        self.primary = {}                # key -> primary host
        self.last_epoch = {}             # key -> last epoch issued
        self.winners = {}                # key -> winner, once decided
        self.terminal = set()
        self.fenced = []                 # (key, host) pairs ever fenced
        self.n_fenced_seen = 0

    # -- rules ---------------------------------------------------------- #
    @rule(dt=st.floats(min_value=0.1, max_value=5.0,
                       allow_nan=False, allow_infinity=False))
    def advance(self, dt):
        self.now += dt

    @rule(key=KEYS, host=HOSTS)
    def submit(self, key, host):
        if key in self.primary or key in self.terminal:
            return
        self.primary[key] = host
        self.coord.track(key, self.now)

    @rule(key=KEYS, tokens=st.integers(min_value=0, max_value=64))
    def progress(self, key, tokens):
        if key in self.primary and key not in self.terminal:
            self.coord.observe_progress(key, tokens, self.now)

    @rule(key=KEYS, clone_host=HOSTS)
    def hedge(self, key, clone_host):
        """The suspect path: the host looks degraded, the coordinator
        decides, the backend launches under a fresh epoch."""
        if key not in self.primary or key in self.terminal:
            return
        if clone_host == self.primary[key]:
            return                        # backends never pick the primary
        if not self.coord.deliverable(key, clone_host):
            return                        # ...nor a host already fenced
        reason = self.coord.want_hedge(key, self.now, host_suspect=True)
        if reason is None:
            return
        self.epoch += 1
        self.coord.launch(key, (self.epoch,), clone_host, reason)
        self.last_epoch[key] = self.epoch

    @rule(key=KEYS)
    def primary_wins(self, key):
        if key not in self.primary or key in self.terminal:
            return
        if self.coord.active(key):
            loser = self.coord.clone_host(key)
            self.coord.resolve(key, "primary", self.primary[key])
            self.winners[key] = "primary"
            self.fenced.append((key, loser))
        else:
            self.coord.mark_terminal(key)
        self.terminal.add(key)

    @rule(key=KEYS)
    def clone_wins(self, key):
        if not self.coord.active(key) or key in self.terminal:
            return
        self.coord.resolve(key, "clone", self.primary[key])
        self.winners[key] = "clone"
        self.fenced.append((key, self.primary[key]))
        self.terminal.add(key)

    @rule(key=KEYS)
    def clone_dies(self, key):
        """The clone's host crashed mid-race: no winner, the clone's
        host is fenced, the primary may hedge again later."""
        if not self.coord.active(key) or key in self.terminal:
            return
        loser = self.coord.clone_host(key)
        self.coord.abandon(key)
        self.fenced.append((key, loser))

    @rule(i=st.integers(min_value=0, max_value=31))
    def zombie_completion(self, i):
        """A fenced loser finishes into the void: it must be counted,
        never deliverable."""
        if not self.fenced:
            return
        key, host = self.fenced[i % len(self.fenced)]
        assert not self.coord.deliverable(key, host)
        self.coord.record_fenced(key, host)
        self.n_fenced_seen += 1

    @rule(key=KEYS, clone_host=HOSTS)
    def hedge_after_terminal_rejected(self, key, clone_host):
        if key not in self.terminal:
            return
        self.epoch += 1
        with pytest.raises(HedgeViolation):
            self.coord.launch(key, (self.epoch,), clone_host, "suspect")

    @rule(key=KEYS, clone_host=HOSTS)
    def reused_epoch_rejected(self, key, clone_host):
        if key not in self.primary or key in self.terminal \
                or self.coord.active(key) \
                or self.last_epoch.get(key) is None:
            return
        if self.coord.want_hedge(key, self.now, host_suspect=True) is None:
            return
        with pytest.raises(HedgeViolation):
            self.coord.launch(key, (self.last_epoch[key],), clone_host,
                              "suspect")

    # -- invariants audited after every rule ----------------------------- #
    @invariant()
    def at_most_one_winner_and_it_never_changes(self):
        for key, winner in self.winners.items():
            assert self.coord.winner(key) == winner

    @invariant()
    def fenced_losers_never_deliver(self):
        for key, host in self.fenced:
            assert not self.coord.deliverable(key, host)

    @invariant()
    def terminal_requests_never_race(self):
        for key in self.terminal:
            assert not self.coord.active(key)

    @invariant()
    def counters_consistent(self):
        c = self.coord.counters()
        assert c["hedges_won"] <= c["hedges_fired"]
        # every cancel (win or abandon) required a launch first
        assert c["hedges_cancelled"] <= c["hedges_fired"]
        assert c["hedges_cancelled"] == len(self.fenced)
        assert c["fenced_completions"] == self.n_fenced_seen


HedgeLifecycleMachine.TestCase.settings = settings(
    max_examples=60, stateful_step_count=50, deadline=None)
TestHedgeLifecycle = HedgeLifecycleMachine.TestCase
