"""Property-based state machine over the ``BlockKVC`` swap ledger.

Drives random interleavings of allocate/extend/free/swap/shrink and
checks ``check_invariants`` (block conservation, host-pool budget,
pinned accounting) after every rule. Skips cleanly when ``hypothesis``
is not installed — the deterministic unit suites still cover the same
surfaces example-by-example.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import settings, strategies as st      # noqa: E402
from hypothesis.stateful import (RuleBasedStateMachine,  # noqa: E402
                                 invariant, precondition, rule)

from repro.core.kvc import BlockKVC  # noqa: E402

RIDS = st.integers(min_value=0, max_value=15)
TOKENS = st.integers(min_value=1, max_value=160)


class KVCLedgerMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.kvc = BlockKVC(512, 32, reserve_frac=0.25,
                            host_pool_tokens=256)
        self.shadow_pinned = set()       # rids pinned per the test model

    # -- device-side allocations -------------------------------------- #
    @rule(rid=RIDS, tokens=TOKENS)
    def allocate(self, rid, tokens):
        if rid in self.kvc.allocs:
            return
        self.kvc.allocate(rid, tokens)

    @rule(rid=RIDS, blocks=st.integers(min_value=1, max_value=4))
    def allocate_reserve(self, rid, blocks):
        self.kvc.allocate_reserve(rid, blocks)

    @rule(rid=RIDS, blocks=st.integers(min_value=1, max_value=4))
    def extend(self, rid, blocks):
        if rid in self.kvc.allocs:
            self.kvc.extend(rid, blocks)

    @rule(rid=RIDS)
    def free(self, rid):
        self.kvc.free(rid)

    # -- host swap ledger ---------------------------------------------- #
    @rule(rid=RIDS, tokens=TOKENS)
    def swap_register(self, rid, tokens):
        if rid in self.kvc.swapped:
            return
        evicted = self.kvc.swap_register(rid, tokens)
        if evicted is None:
            # refused: ledger must be untouched by the failed attempt
            assert rid not in self.kvc.swapped
        else:
            assert rid in self.kvc.swapped
            for old in evicted:
                assert old not in self.kvc.swapped
                # budget eviction must never sacrifice a pinned image
                assert old not in self.shadow_pinned

    @rule(rid=RIDS, restored=st.booleans())
    def swap_release(self, rid, restored):
        before = self.kvc.swapped_tokens(rid)
        got = self.kvc.swap_release(rid, restored=restored)
        assert got == before              # missing rid -> 0, tolerated
        self.shadow_pinned.discard(rid)

    @rule(rid=RIDS)
    def swap_pin(self, rid):
        self.kvc.swap_pin(rid)
        if rid in self.kvc.swapped:
            self.shadow_pinned.add(rid)

    @rule(rid=RIDS)
    def swap_unpin(self, rid):
        self.kvc.swap_unpin(rid)
        self.shadow_pinned.discard(rid)

    # -- live capacity squeeze ----------------------------------------- #
    @precondition(lambda self: self.kvc.total_blocks
                  - self.kvc.pending_shrink > 1)
    @rule(tokens=st.integers(min_value=1, max_value=96))
    def shrink(self, tokens):
        cap = (self.kvc.total_blocks - self.kvc.pending_shrink - 1) \
            * self.kvc.block_size
        self.kvc.shrink(min(tokens, cap))

    # -- invariants checked after every rule ---------------------------- #
    @invariant()
    def ledger_conserves(self):
        self.kvc.check_invariants()

    @invariant()
    def pinned_model_agrees(self):
        # the shadow pin-set and the ledger agree: every modeled pin is
        # still resident and marked pinned (evictions spare pinned rids)
        for rid in self.shadow_pinned:
            assert rid in self.kvc.swapped
            assert self.kvc.swapped[rid].pinned


KVCLedgerMachine.TestCase.settings = settings(
    max_examples=60, stateful_step_count=40, deadline=None)
TestKVCLedger = KVCLedgerMachine.TestCase
