"""Decode megastep (K fused iterations per dispatch) must be bitwise
drop-in for the per-iteration async path: identical token streams,
completion times and scheduler decisions — including EOS firing *inside*
a fused window — while amortizing dispatches ~K×."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                           ServingEngine)

PER_ITER = EngineConfig(decode_megastep=1)
MEGA = EngineConfig(decode_megastep=8)
LEGACY = EngineConfig(async_decode=False, packed_prefill=False)


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3_8b").reduced(d_model=128).with_(
        dtype="float32", param_dtype="float32")


def _engine(cfg, ecfg, *, seed=0, max_batch=4, capacity=96):
    return ServingEngine(cfg, max_batch=max_batch, capacity=capacity,
                         rl_accuracy=1.0, seed=seed, engine_cfg=ecfg)


def _workload(cfg, n=4, seed=0, eos_token=None, long=True):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(4, 18))
        temp = 0.0 if i % 2 else 1.3
        reqs.append(GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, plen)),
            params=SamplingParams(
                max_new_tokens=int(rng.integers(24, 40)) if long else
                int(rng.integers(3, 9)),
                temperature=temp, top_k=4 if temp else 0,
                eos_token=eos_token)))
    return reqs


def _fingerprint(eng, reqs):
    per_req = [(g.rid, tuple(g.output), g.t_done) for g in reqs]
    s = eng.scheduler
    sched = (tuple(s.iter_completion_counts),
             tuple((r.rid, r.t_complete, r.generated, r.n_preemptions)
                   for r in s.completed),
             s.n_preempt_free, s.n_preempt_swap, s.n_underprov)
    return per_req, sched


def test_megastep_matches_per_iteration(cfg):
    outs = []
    for ecfg in (PER_ITER, MEGA):
        eng = _engine(cfg, ecfg)
        reqs = _workload(cfg)
        eng.run(reqs)
        outs.append((_fingerprint(eng, reqs), eng))
    (fp1, e1), (fp8, e8) = outs
    assert fp1 == fp8
    # windows fused (staggered completions bound many of them, so the
    # strong ~K× claim lives in the uniform steady-state test below)
    assert e8.decode_iters == e1.decode_iters
    assert e1.n_decode_dispatches == e1.decode_iters
    assert e8.n_decode_dispatches < e8.decode_iters


def test_megastep_matches_legacy_sync(cfg):
    ref = _engine(cfg, LEGACY)
    ref_reqs = _workload(cfg)
    ref.run(ref_reqs)
    eng = _engine(cfg, MEGA)
    reqs = _workload(cfg)
    eng.run(reqs)
    assert _fingerprint(eng, reqs) == _fingerprint(ref, ref_reqs)


def test_eos_inside_megastep_window(cfg):
    """EOS firing mid-window: the replay must deliver it to the scheduler
    at the iteration it fired, complete the request there, and keep the
    surviving rows' streams bitwise-identical."""
    probe = _engine(cfg, PER_ITER)
    preqs = _workload(cfg)
    probe.run(preqs)
    # pick a token some way into the longest greedy stream so windows have
    # formed (queues drained) before it fires
    greedy = [g for g in preqs if g.params.temperature == 0.0][0]
    eos = greedy.output[len(greedy.output) // 2]

    outs = []
    for ecfg in (PER_ITER, MEGA):
        eng = _engine(cfg, ecfg)
        reqs = _workload(cfg, eos_token=eos)
        eng.run(reqs)
        outs.append((_fingerprint(eng, reqs), eng, reqs))
    assert outs[0][0] == outs[1][0]
    reqs = outs[1][2]
    assert any(len(g.output) < g.params.max_new_tokens for g in reqs)
    for g in reqs:
        if len(g.output) < g.params.max_new_tokens:
            assert g.output[-1] == eos
    # the megastep engine really fused windows in this run
    assert outs[1][1].n_decode_dispatches < outs[1][1].decode_iters


def test_megastep_steady_state_stays_async(cfg):
    """Uniform batch, no EOS-capable requests: zero EOS readbacks, the
    decode loop stays device-resident (host last_tok mirrors untouched),
    and dispatches amortize ~K× (all requests complete together, so every
    full window fuses to the K=8 max)."""
    eng = _engine(cfg, MEGA, capacity=256)   # KVC fits the whole batch
    rng = np.random.default_rng(0)
    reqs = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 18)))),
        params=SamplingParams(max_new_tokens=33,
                              temperature=1.3 if i % 2 else 0.0,
                              top_k=4 if i % 2 else 0))
        for i in range(4)]
    eng.run(reqs)
    assert eng.decode_iters > 0
    assert eng.sync_counts["eos_flags"] == 0
    # ~decode_iters/8 full windows plus admission/tail edges
    assert eng.n_decode_dispatches <= eng.decode_iters // 4
    assert int(eng.last_tok.sum()) == 0
    for g in reqs:
        assert len(g.output) == g.params.max_new_tokens


def test_megastep_respects_admission_horizon(cfg):
    """Requests arriving while others decode: windows must not fuse past
    admission points (the step() assert enforces it), and results stay
    identical to per-iteration execution."""
    def run(ecfg):
        eng = _engine(cfg, ecfg, max_batch=2, capacity=96)
        rng = np.random.default_rng(9)
        reqs = [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(4, 16)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(10, 30))))
            for _ in range(6)]        # 6 requests through 2 slots: staged
        eng.run(reqs)
        return eng, reqs

    e1, r1 = run(PER_ITER)
    e8, r8 = run(MEGA)
    assert _fingerprint(e8, r8) == _fingerprint(e1, r1)
    assert e8.n_decode_dispatches < e8.decode_iters


def test_megastep_chunked_prefill_interplay(cfg):
    """Chunked long-prompt admission + megastep decode in one run: both
    hot paths active, still bitwise-equal to the fully-legacy engine."""
    from repro.core.scheduler import SchedulerConfig
    mb, cap = 4, 192

    def run(ecfg):
        scfg = SchedulerConfig(kvc_tokens=mb * cap, block_size=16, tfs=48,
                               max_model_len=cap, max_batch_reqs=mb)
        eng = ServingEngine(cfg, max_batch=mb, capacity=cap,
                            rl_accuracy=1.0, seed=0, scheduler_cfg=scfg,
                            engine_cfg=ecfg)
        rng = np.random.default_rng(21)
        reqs = [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, 120)),
            params=SamplingParams(max_new_tokens=10))] + [
            GenRequest(
                prompt=list(rng.integers(0, cfg.vocab_size,
                                         int(rng.integers(4, 20)))),
                params=SamplingParams(max_new_tokens=int(
                    rng.integers(16, 30))))
            for _ in range(3)]
        eng.run(reqs)
        return eng, reqs

    ref, ref_reqs = run(LEGACY)
    eng, reqs = run(MEGA)
    assert eng.n_prefill_chunks >= 2
    assert eng.n_decode_dispatches < eng.decode_iters
    assert _fingerprint(eng, reqs) == _fingerprint(ref, ref_reqs)
