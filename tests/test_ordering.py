"""Task ordering (§3.4) tests."""
from repro.core.ordering import order_key, pick_fit, sort_queue
from repro.core.request import Request


def _req(rid, deadline=100.0, occupied=0, rl=64, plen=64):
    r = Request(rid=rid, prompt_len=plen, true_rl=rl, arrival=0.0,
                slo_deadline=deadline)
    r.padded_rl = rl
    r.occupied_kvc = occupied
    return r


def test_deadline_dominates():
    urgent = _req(1, deadline=0.1, occupied=0, rl=32)
    lazy = _req(2, deadline=50.0, occupied=10_000, rl=512)
    q = sort_queue([lazy, urgent], now=0.0, is_gt=True)
    assert q[0] is urgent


def test_occupied_kvc_breaks_ties():
    small = _req(1, occupied=10)
    big = _req(2, occupied=400)
    q = sort_queue([small, big], now=0.0, is_gt=True)
    assert q[0] is big


def test_length_breaks_remaining_ties():
    short = _req(1, rl=32)
    long = _req(2, rl=512)
    q = sort_queue([short, long], now=0.0, is_gt=True)
    assert q[0] is long


def test_pick_fit_finds_near_exact():
    reqs = [_req(i, rl=rl) for i, rl in enumerate((512, 384, 256, 128, 64))]
    q = sort_queue(reqs, now=0.0, is_gt=True)
    i = pick_fit(q, budget=300, now=0.0, is_gt=True)
    assert q[i].padded_rl == 256


def test_pick_fit_none_when_nothing_fits():
    q = sort_queue([_req(1, rl=512)], now=0.0, is_gt=True)
    assert pick_fit(q, budget=100, now=0.0, is_gt=True) is None
