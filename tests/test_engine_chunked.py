"""Engine-executed chunked prefill: the engine must honor the scheduler's
per-chunk PT grants (``_fill_pts`` with TFS < prompt length) instead of
requiring whole prompts, with token streams bitwise-equal to whole-prompt
prefill and all engine-path toggles (async/sync, incremental/recompute)
drop-in equivalent."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.scheduler import SchedulerConfig
from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                           ServingEngine)
from repro.serving.engine import MIN_SEQ_BUCKET

LEGACY = EngineConfig(async_decode=False, packed_prefill=False)


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen3_8b").reduced(d_model=128).with_(
        dtype="float32", param_dtype="float32")


def _scfg(tfs, mb=4, cap=192, **kw):
    base = dict(kvc_tokens=mb * cap, block_size=16, tfs=tfs,
                max_model_len=cap, max_batch_reqs=mb)
    base.update(kw)
    return SchedulerConfig(**base)


def _workload(cfg, seed=7, long_len=80, temps=False, eos_token=None,
              max_long=6):
    """One long prompt (chunk-forcing under small TFS) + short fillers."""
    rng = np.random.default_rng(seed)
    reqs = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size, long_len)),
        params=SamplingParams(max_new_tokens=max_long, eos_token=eos_token))]
    for i in range(3):
        t = 1.3 if (temps and i == 1) else 0.0
        reqs.append(GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, 8 + i)),
            params=SamplingParams(max_new_tokens=8, temperature=t,
                                  top_k=4 if t else 0,
                                  eos_token=eos_token)))
    return reqs


def _run(cfg, tfs, ecfg=None, scfg=None, seed=0, rl_accuracy=1.0,
         mb=4, cap=192, wl=None):
    eng = ServingEngine(cfg, max_batch=mb, capacity=cap,
                        rl_accuracy=rl_accuracy, seed=seed,
                        scheduler_cfg=scfg or _scfg(tfs, mb=mb, cap=cap),
                        engine_cfg=ecfg)
    reqs = wl() if wl else _workload(cfg)
    eng.run(reqs)
    return eng, reqs


def _fingerprint(eng, reqs):
    per_req = [(g.rid, tuple(g.output), g.t_done) for g in reqs]
    s = eng.scheduler
    sched = (tuple(s.iter_completion_counts),
             tuple((r.rid, r.t_complete, r.generated, r.n_preemptions)
                   for r in s.completed),
             s.n_preempt_free, s.n_preempt_swap, s.n_underprov,
             s.n_hosted, s.n_reserve_rescues)
    return per_req, sched


def test_chunked_matches_whole_prompt_tokens(cfg):
    """A prompt longer than the per-iteration budget completes via >= 2
    engine-executed chunks with greedy token streams bitwise-equal to the
    whole-prompt run (the chunked run makes *different* scheduler
    decisions — more PT iterations — so only tokens are comparable)."""
    chunked, reqs_c = _run(cfg, tfs=32)
    whole, reqs_w = _run(cfg, tfs=192)
    assert chunked.n_prefill_chunks >= 2
    assert whole.n_prefill_chunks == 0
    for a, b in zip(reqs_c, reqs_w):
        assert a.output == b.output
        assert a.t_done is not None


def test_chunked_async_matches_sync(cfg):
    """Full fingerprints (tokens + completion times + scheduler decisions)
    must be identical across async/sync engines under chunking, with
    mixed-temperature sampling in flight."""
    wl = lambda: _workload(cfg, temps=True)
    ref_eng, ref_reqs = _run(cfg, tfs=32, ecfg=LEGACY, wl=wl)
    eng, reqs = _run(cfg, tfs=32, wl=wl)
    assert ref_eng.n_prefill_chunks >= 2
    assert _fingerprint(eng, reqs) == _fingerprint(ref_eng, ref_reqs)


def test_incremental_matches_recompute_reference(cfg):
    """The prefix-attending incremental chunk path must be equivalent to
    the recompute-from-start reference path."""
    wl = lambda: _workload(cfg, temps=True)
    inc, reqs_i = _run(cfg, tfs=32, wl=wl)
    rec, reqs_r = _run(cfg, tfs=32, wl=wl,
                       ecfg=EngineConfig(incremental_chunk_prefill=False))
    assert inc._chunk_incremental and not rec._chunk_incremental
    assert inc.n_prefill_chunks == rec.n_prefill_chunks >= 2
    assert _fingerprint(inc, reqs_i) == _fingerprint(rec, reqs_r)


def test_chunked_with_eos_matches_whole(cfg):
    """EOS-bearing requests behave identically whether their prompts ran
    chunked or whole."""
    probe, preqs = _run(cfg, tfs=192)
    eos = preqs[0].output[1]
    wl = lambda: _workload(cfg, eos_token=eos, max_long=16)
    chunked, reqs_c = _run(cfg, tfs=32, wl=wl)
    whole, reqs_w = _run(cfg, tfs=192, wl=wl)
    assert chunked.n_prefill_chunks >= 2
    for a, b in zip(reqs_c, reqs_w):
        assert a.output == b.output
    assert any(len(g.output) < g.params.max_new_tokens for g in reqs_c)


def test_preempted_request_reprefills_chunked(cfg):
    """An always-wrong predictor with no padding/reserve forces offload-
    free preemptions; the preempted request's recompute re-prefill
    (prompt + generated tail) must itself run chunked under a small TFS,
    identically on async and sync paths."""
    def run(ecfg):
        mb, cap = 4, 192
        scfg = _scfg(32, mb=mb, cap=cap, pad_ratio=0.0, reserve_frac=0.0,
                     bucket=8)

        def wl():
            rng = np.random.default_rng(5)
            return [GenRequest(
                prompt=list(rng.integers(0, cfg.vocab_size, 60)),
                params=SamplingParams(max_new_tokens=14))] + [
                GenRequest(
                    prompt=list(rng.integers(0, cfg.vocab_size,
                                             int(rng.integers(4, 18)))),
                    params=SamplingParams(
                        max_new_tokens=int(rng.integers(12, 28))))
                for _ in range(4)]

        return _run(cfg, tfs=32, ecfg=ecfg, scfg=scfg, rl_accuracy=0.0,
                    wl=wl)

    ref_eng, ref_reqs = run(LEGACY)
    assert ref_eng.scheduler.n_preempt_free > 0
    assert ref_eng.n_prefill_chunks >= 2
    for g in ref_reqs:
        assert g.t_done is not None
        assert len(g.output) == g.params.max_new_tokens
    eng, reqs = run(None)
    assert _fingerprint(eng, reqs) == _fingerprint(ref_eng, ref_reqs)


def _chunk_wave_workload(cfg, seed=13, lens=(96, 80, 72), shorts=0,
                         max_new=6):
    """Several long prompts arriving together: with TFS below the prompt
    lengths, _fill_pts spreads the budget across requests once the head's
    remaining chunk undershoots it, so iterations carry >= 2 chunk grants
    — the packed-chunk wave."""
    def wl():
        rng = np.random.default_rng(seed)
        reqs = [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, L)),
            params=SamplingParams(max_new_tokens=max_new))
            for L in lens]
        for i in range(shorts):
            t = 1.1 if i % 2 else 0.0
            reqs.append(GenRequest(
                prompt=list(rng.integers(0, cfg.vocab_size, 6 + i)),
                params=SamplingParams(max_new_tokens=8, temperature=t,
                                      top_k=4 if t else 0)))
        return reqs
    return wl


def test_packed_chunk_wave_one_dispatch_tokens_equal(cfg):
    """A wave of >= 2 chunk grants in one iteration must run as ONE
    packed prefill dispatch, with the full fingerprint (tokens +
    scheduler decisions) identical to the one-call-per-chunk reference
    path."""
    wl = _chunk_wave_workload(cfg)
    scfg = _scfg(64, cap=256)
    packed, reqs_p = _run(cfg, tfs=64, scfg=scfg, cap=256, wl=wl)
    ref, reqs_r = _run(cfg, tfs=64, scfg=scfg, cap=256, wl=wl,
                       ecfg=EngineConfig(packed_chunk_prefill=False))
    assert packed._chunk_packed and not ref._chunk_packed
    assert packed.n_prefill_chunks == ref.n_prefill_chunks >= 4
    # the reference pays one dispatch per chunk; the packed engine fuses
    # every multi-chunk iteration into a single call
    assert packed.max_chunk_items_per_call >= 2
    assert ref.max_chunk_items_per_call == 1
    assert packed.n_chunk_calls < ref.n_chunk_calls
    assert _fingerprint(packed, reqs_p) == _fingerprint(ref, reqs_r)


def test_packed_chunk_mixed_whole_prompt_wave(cfg):
    """Mixed waves — whole short prompts admitted alongside mid-prompt
    chunks — must stay fingerprint-identical between the packed and
    per-chunk paths (whole prompts keep riding the packed whole-prefill
    call; chunks pack separately)."""
    wl = _chunk_wave_workload(cfg, lens=(96, 88), shorts=3)
    scfg = _scfg(64, mb=6, cap=256)
    packed, reqs_p = _run(cfg, tfs=64, scfg=scfg, mb=6, cap=256, wl=wl)
    ref, reqs_r = _run(cfg, tfs=64, scfg=scfg, mb=6, cap=256, wl=wl,
                       ecfg=EngineConfig(packed_chunk_prefill=False))
    assert packed.max_chunk_items_per_call >= 2
    assert _fingerprint(packed, reqs_p) == _fingerprint(ref, reqs_r)


def test_packed_chunk_preempted_reprefill(cfg):
    """Offload-free preemptions (always-wrong predictor, no reserve)
    interleave recompute re-prefills with the chunk waves; the packed
    path must stay fingerprint-identical to the per-chunk reference
    through the churn."""
    def run(ecfg):
        mb, cap = 4, 192
        scfg = _scfg(40, mb=mb, cap=cap, pad_ratio=0.0, reserve_frac=0.0,
                     bucket=8)

        def wl():
            rng = np.random.default_rng(5)
            return [GenRequest(
                prompt=list(rng.integers(0, cfg.vocab_size, 60 - 4 * i)),
                params=SamplingParams(max_new_tokens=12))
                for i in range(3)]

        return _run(cfg, tfs=40, ecfg=ecfg, scfg=scfg, rl_accuracy=0.0,
                    seed=1, wl=wl)

    packed, reqs_p = run(None)
    ref, reqs_r = run(EngineConfig(packed_chunk_prefill=False))
    assert packed.scheduler.n_preempt_free > 0
    assert packed.max_chunk_items_per_call >= 2
    # the preempted requests' recompute re-prefills themselves ran
    # through the chunk path (prompt + tail exceed the 40-token TFS)
    assert packed.n_prefill_chunks > 4
    assert _fingerprint(packed, reqs_p) == _fingerprint(ref, reqs_r)


def test_recurrent_state_carry_matches_recompute():
    """Pure-recurrent stacks (xLSTM) carry the per-request state snapshot
    across chunks (O(n) total) — fingerprints must match the recompute-
    from-start reference path exactly."""
    cfg = get_config("xlstm_125m").reduced().with_(dtype="float32",
                                                   param_dtype="float32")
    mb, cap = 2, 96

    def wl():
        rng = np.random.default_rng(3)
        return [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                           params=SamplingParams(max_new_tokens=5)),
                GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 7)),
                           params=SamplingParams(max_new_tokens=5))]

    carry, reqs_c = _run(cfg, tfs=16, mb=mb, cap=cap, wl=wl)
    rec, reqs_r = _run(cfg, tfs=16, mb=mb, cap=cap, wl=wl,
                       ecfg=EngineConfig(incremental_chunk_prefill=False))
    assert carry._chunk_rec and not rec._chunk_rec
    assert carry.n_prefill_chunks == rec.n_prefill_chunks >= 2
    assert _fingerprint(carry, reqs_c) == _fingerprint(rec, reqs_r)


def test_mamba_state_carry_matches_recompute():
    """Pure-Mamba2 stack: the SSD recurrence resumes from {h, conv} —
    conv history must cross chunk boundaries exactly."""
    from repro.models.config import MAMBA, ModelConfig
    cfg = ModelConfig(name="mamba-test", arch_type="ssm", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
                      d_ff=0, vocab_size=128, ssm_state=16, ssm_expand=2,
                      ssm_head_dim=16, ssm_chunk=16,
                      layer_pattern=MAMBA * 2, dtype="float32",
                      param_dtype="float32")
    mb, cap = 2, 96

    def wl():
        rng = np.random.default_rng(9)
        return [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 50)),
                           params=SamplingParams(max_new_tokens=4)),
                GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 9)),
                           params=SamplingParams(max_new_tokens=4))]

    carry, reqs_c = _run(cfg, tfs=16, mb=mb, cap=cap, wl=wl)
    rec, reqs_r = _run(cfg, tfs=16, mb=mb, cap=cap, wl=wl,
                       ecfg=EngineConfig(incremental_chunk_prefill=False))
    assert carry._chunk_rec and not rec._chunk_rec
    assert carry.n_prefill_chunks == rec.n_prefill_chunks >= 2
    assert _fingerprint(carry, reqs_c) == _fingerprint(rec, reqs_r)


def test_recurrent_stack_chunk_fallback():
    """Recurrent stacks (xLSTM) have no KV-prefix view: with the
    state-carry path disabled (``incremental_chunk_prefill=False``),
    chunk grants must fall back to recompute-from-start and still
    produce the whole-prompt token stream."""
    cfg = get_config("xlstm_125m").reduced().with_(dtype="float32",
                                                   param_dtype="float32")
    mb, cap = 2, 96

    def wl():
        rng = np.random.default_rng(3)
        return [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 40)),
                           params=SamplingParams(max_new_tokens=5)),
                GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 7)),
                           params=SamplingParams(max_new_tokens=5))]

    chunked, reqs_c = _run(cfg, tfs=16, mb=mb, cap=cap, wl=wl,
                           ecfg=EngineConfig(incremental_chunk_prefill=False))
    whole, reqs_w = _run(cfg, tfs=cap, mb=mb, cap=cap, wl=wl)
    assert not chunked._chunk_incremental and not chunked._chunk_rec
    assert chunked.n_prefill_chunks >= 2
    for a, b in zip(reqs_c, reqs_w):
        assert a.output == b.output
        assert a.t_done is not None


def test_tail_chunk_bucket_capped_at_capacity(cfg):
    """The pow2 round-up of a tail chunk must be clamped so the padded
    call never implies cache slots (KVC pages) past the grant/capacity —
    a 70-token prompt in a 72-slot cache forces start + seq_bucket(tail)
    past capacity without the cap."""
    mb, cap = 2, 72

    def wl():
        rng = np.random.default_rng(11)
        return [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 70)),
                           params=SamplingParams(max_new_tokens=2))]

    chunked, reqs_c = _run(cfg, tfs=64, mb=mb, cap=cap, wl=wl)
    whole, reqs_w = _run(cfg, tfs=72, mb=mb, cap=cap, wl=wl)
    assert chunked.n_prefill_chunks >= 2
    # every prefill here is a chunk call, and no padded chunk shape may
    # reach past the cache: 64 + seq_bucket(tail) would (64+16 > 72), so
    # the clamp must have produced a sub-bucket (non-pow2-padded) tail
    assert all(b == 1 and s <= cap for b, s in chunked._prefill_shapes)
    assert any(s < MIN_SEQ_BUCKET or (s & (s - 1))
               for _, s in chunked._prefill_shapes)
    assert reqs_c[0].output == reqs_w[0].output
