"""Scheduler integration tests: every policy drains its trace, invariants
hold, and the paper's qualitative orderings emerge at load."""
import copy

import pytest

from repro.core import registry, traces
from repro.core.costmodel import CostModel
from repro.core.request import State
from repro.core.scheduler import SchedulerConfig


def _trace(n=120, rate=2.0, seed=1, spec=traces.SHAREGPT):
    return traces.generate(spec, n, seed=seed, rate=rate)


ALL = ["orca", "srtf", "fastserve", "vllm", "sarathi", "multires",
       "synccoupled", "econoserve-d", "econoserve-sd", "econoserve-sdo",
       "econoserve", "oracle"]


@pytest.mark.parametrize("name", ALL)
def test_scheduler_drains_and_conserves(name):
    reqs = _trace()
    res = registry.run_one(name, reqs)
    assert len(res.completed) == len(reqs), name
    for r in res.completed:
        assert r.state == State.COMPLETED
        assert r.generated >= r.true_rl
        assert r.t_complete >= r.arrival


def test_distserve_drains():
    reqs = _trace(80)
    res = registry.run_one("distserve", reqs)
    assert len(res.completed) == len(reqs)


def test_econoserve_kvc_invariants_at_end():
    reqs = _trace(150, rate=4.0)
    cfg = SchedulerConfig()
    cost = CostModel()
    from repro.core import predictor, simulator
    from repro.core.registry import make_scheduler
    rr = copy.deepcopy(reqs)
    predictor.annotate(rr, predictor.NoisyPredictor(seed=0), 0.15)
    sched = make_scheduler("econoserve", cfg, cost)
    simulator.simulate(rr, sched, cost)
    sched.kvc.check_invariants()
    assert sched.kvc.free_blocks == sched.kvc.total_blocks   # all freed
    assert sched.kvc.reserve_in_use == 0


def test_max_allocation_limits_batch_size():
    """ORCA's max-allocation must yield lower KVC utilization than
    EconoServe's exact-allocation (fig 1 motivation)."""
    reqs = _trace(150, rate=3.0)
    orca = registry.run_one("orca", reqs)
    econo = registry.run_one("econoserve", reqs)
    assert econo.kvc_utilization > orca.kvc_utilization
    assert econo.throughput_reqs >= orca.throughput_reqs


def test_econoserve_no_runtime_alloc_failures():
    """Exact-allocation avoids the KVC allocation failures that
    block-allocation schedulers hit (Table 1)."""
    reqs = _trace(200, rate=5.0)
    econo = registry.run_one("econoserve", reqs)
    vllm = registry.run_one("vllm", reqs)
    assert econo.alloc_failure_rate < 0.01
    assert vllm.n_preempt_swap > 0         # vLLM preempts under pressure


def test_ablation_ordering_at_load():
    """Full EconoServe should not lose to its own ablations on JCT under
    pressure (paper fig 13, directional)."""
    reqs = _trace(250, rate=3.5)
    full = registry.run_one("econoserve", reqs)
    sd = registry.run_one("econoserve-sd", reqs)
    assert full.mean_jct <= sd.mean_jct * 1.10


def test_oracle_upper_bound():
    reqs = _trace(200, rate=3.0)
    oracle = registry.run_one("oracle", reqs)
    full = registry.run_one("econoserve", reqs)
    assert oracle.mean_jct <= full.mean_jct * 1.05
    assert oracle.ssr >= full.ssr - 0.02


def test_steady_state_throughput_beats_vllm_at_pressure():
    """The paper's headline (fig 9): under KVC pressure EconoServe sustains
    higher steady-state throughput than swap-thrashing vLLM."""
    import numpy as np
    reqs = _trace(400, rate=6.0)
    t_end = max(r.arrival for r in reqs)
    econo = registry.run_one("econoserve", reqs)
    vllm = registry.run_one("vllm", reqs)

    def steady_tput(res):
        return sum(r.t_complete <= t_end for r in res.completed) / t_end

    assert steady_tput(econo) > steady_tput(vllm)
