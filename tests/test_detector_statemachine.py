"""Property-based state machine over the failure-detector health
lifecycle.

Drives random interleavings of clock advances, heartbeats, silent
crashes, and detection passes, and checks after every rule that the
observed-health automaton never misbehaves: transitions never skip a
state (HEALTHY -> DEAD requires passing through SUSPECT), DEAD is final
(a fenced zombie's late beat never resurrects it), and the router can
never be handed a SUSPECT or DEAD instance. Skips cleanly when
``hypothesis`` is not installed — the deterministic lifecycle tests in
``test_cluster_detector.py`` cover the same surface example-by-example.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import settings, strategies as st      # noqa: E402
from hypothesis.stateful import (RuleBasedStateMachine,  # noqa: E402
                                 invariant, rule)

from repro.cluster.base import (DEAD, DetectorConfig,  # noqa: E402
                                FailureDetector, HEALTHY, InstanceBase,
                                SUSPECT)
from repro.cluster.transport import BEAT, DETECTOR, Transport  # noqa: E402

N_INST = 3
IDS = st.integers(min_value=0, max_value=N_INST - 1)

# legal edges of the observed-health automaton; everything else —
# notably HEALTHY -> DEAD (skipping suspicion) and DEAD -> anything
# (resurrection) — is a bug
LEGAL = {(HEALTHY, SUSPECT), (SUSPECT, HEALTHY), (SUSPECT, DEAD)}


class DetectorLifecycleMachine(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.cfg = DetectorConfig(beat_every=1.0, patience=3.0,
                                  lease=10.0)
        self.transport = Transport(seed=0)
        self.det = FailureDetector(self.cfg, self.transport)
        self.instances = [InstanceBase(i) for i in range(N_INST)]
        self.now = 0.0
        self.ever_dead = set()           # ids once declared dead
        self.n_seen = 0                  # transitions already audited
        for inst in self.instances:      # all beat once at t=0
            self.transport.send(DETECTOR, BEAT, inst.id, 0.0,
                                link=inst.id)
        self.det.observe(0.0, self.instances)

    # -- rules ---------------------------------------------------------- #
    @rule(dt=st.floats(min_value=0.1, max_value=6.0,
                       allow_nan=False, allow_infinity=False))
    def advance(self, dt):
        self.now += dt

    @rule(iid=IDS)
    def beat(self, iid):
        inst = self.instances[iid]
        inst.maybe_beat(self.transport, self.now, self.cfg.beat_every)

    @rule(iid=IDS)
    def crash(self, iid):
        # ground truth only: the instance falls silent, health is still
        # whatever the detector last observed
        self.instances[iid].crashed = True

    @rule(iid=IDS)
    def zombie_beat(self, iid):
        # a fenced zombie (or a partition healing after the lease) may
        # still emit late beats; they must never resurrect a DEAD peer
        self.transport.send(DETECTOR, BEAT, iid, self.now, link=iid)

    @rule()
    def observe(self):
        newly = self.det.observe(self.now, self.instances)
        for iid in newly:
            self.ever_dead.add(iid)

    # -- invariants audited after every rule ----------------------------- #
    @invariant()
    def transitions_never_skip_states(self):
        fresh = self.det.transitions[self.n_seen:]
        self.n_seen = len(self.det.transitions)
        for _, _, frm, to in fresh:
            assert (frm, to) in LEGAL, (frm, to)

    @invariant()
    def dead_is_final(self):
        for iid in self.ever_dead:
            assert self.instances[iid].health == DEAD

    @invariant()
    def never_route_to_degraded(self):
        for inst in self.instances:
            if inst.health != HEALTHY:
                assert not inst.accepts_prompts()
                assert not inst.accepts_decodes()

    @invariant()
    def transition_log_times_monotone(self):
        ts = [t for t, _, _, _ in self.det.transitions]
        assert ts == sorted(ts)


DetectorLifecycleMachine.TestCase.settings = settings(
    max_examples=60, stateful_step_count=50, deadline=None)
TestDetectorLifecycle = DetectorLifecycleMachine.TestCase
