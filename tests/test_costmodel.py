"""Cost model sanity: regimes and monotonicity the simulator relies on."""
from repro.core.costmodel import (A100, TPU_V5E, CostModel, ModelProfile,
                                  OPT_13B, tfs_for)
from repro.configs import get_config


def test_iteration_time_monotonic_in_tokens():
    cm = CostModel()
    t1 = cm.iteration_time(100, [])
    t2 = cm.iteration_time(1000, [])
    assert t2 > t1 > 0


def test_decode_is_memory_bound_small_batch():
    cm = CostModel()
    # one decode token: weights stream dominates -> adding a second token
    # barely changes the iteration time
    t1 = cm.token_time()
    t2 = cm.iteration_time(0, [512, 512])
    assert t2 < 1.5 * t1


def test_prefill_compute_bound():
    cm = CostModel()
    # 4096 prompt tokens: doubling tokens ~doubles time (compute-bound)
    t1 = cm.iteration_time(4096, [])
    t2 = cm.iteration_time(8192, [])
    assert 1.7 < t2 / t1 < 2.3


def test_tfs_reasonable():
    tfs = tfs_for(A100, OPT_13B)
    # A100: peak/bw * dtype/2 = 312e12*2/(2e12*2) = 312 -> rounded to 320
    assert 128 <= tfs <= 512
    tfs_tpu = tfs_for(TPU_V5E, OPT_13B)
    assert 128 <= tfs_tpu <= 512


def test_swap_slower_than_recompute_for_short_contexts():
    """O4: offload-free preemption beats swap for typical contexts."""
    cm = CostModel()
    tokens = 500
    assert cm.recompute_time(tokens) < 2 * cm.swap_time(tokens)


def test_model_profile_from_config():
    prof = ModelProfile.from_config(get_config("qwen3_8b"))
    assert 6e9 < prof.n_params < 11e9
    assert prof.n_active == prof.n_params
    moe = ModelProfile.from_config(get_config("phi3.5-moe-42b-a6.6b"))
    assert moe.n_active < 0.3 * moe.n_params


def test_sched_time_orderings():
    cm = CostModel()
    n = 500
    assert cm.sched_time_fcfs(n, 10) < cm.sched_time_grouped(n, 10) \
        < cm.sched_time_quadratic(n, 10)
