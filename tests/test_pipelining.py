"""KVCPipe slot-tree tests (§3.2 semantics)."""
from repro.core.pipelining import PipeBook, dyadic_slots
from repro.core.request import Request


def _req(rid, rl=100):
    return Request(rid=rid, prompt_len=10, true_rl=rl, arrival=0.0)


def test_dyadic_slots():
    r = _req(1)
    slots = dyadic_slots(r, 256, min_size=32)
    assert [(s.offset, s.size) for s in slots] == \
        [(128, 128), (64, 64), (32, 32)]


def test_place_best_fit_and_recursion():
    book = PipeBook(buffer_tokens=8, min_size=32)
    host = _req(1)
    book.offer(host, 256)
    child = _req(2)
    slot = book.place(child, 100)           # fits 128-slot (eff 120)
    assert slot is not None and slot.size == 128
    assert child.hosted
    # the child's own span offered sub-slots (100 -> 50 ... below min 32 -> 50)
    sizes = sorted(s.size for s in book.open_slots)
    assert 50 in sizes and 64 in sizes and 32 in sizes


def test_aging_shrinks_effective_capacity():
    book = PipeBook(buffer_tokens=0, min_size=32)
    host = _req(1)
    book.offer(host, 256)
    age = {1: 100}
    cap = book.max_hostable(lambda r: age[r.rid])
    assert cap == 128 - 100                 # owner grew 100 toward the slot
    assert book.place(_req(2), 100, lambda r: age[r.rid]) is None
    assert book.place(_req(3), 28, lambda r: age[r.rid]) is not None


def test_expiry_and_release():
    book = PipeBook(buffer_tokens=0, min_size=32)
    host = _req(1)
    book.offer(host, 128)
    child = _req(2)
    slot = book.place(child, 60)
    assert slot.deadline_age == 64
    assert not book.expired(lambda r: 63)
    exp = book.expired(lambda r: 64 if r is host else 0)
    assert exp and exp[0].child is child
    book.release_child(child)
    assert not book.active and not child.hosted


def test_drop_owner_orphans_children():
    book = PipeBook(buffer_tokens=0, min_size=32)
    host = _req(1)
    book.offer(host, 128)
    child = _req(2)
    book.place(child, 60)
    orphans = book.drop_owner(host)
    assert orphans == [child]
    assert not book.open_slots or all(s.owner is not host
                                      for s in book.open_slots)
