"""Training substrate: loss decreases, checkpoint roundtrip, data pipeline."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.training import checkpoint
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def test_loss_decreases_dense():
    cfg = get_config("qwen3_8b").reduced(layers=2, d_model=128).with_(
        dtype="float32", param_dtype="float32", vocab_size=256)
    _, _, hist = train(cfg, steps=30, opt=AdamWConfig(lr=3e-3,
                                                      warmup_steps=5),
                       batch_size=8, seq_len=64, log_every=1)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.9, (first, last)


def test_loss_decreases_moe():
    cfg = get_config("phi3_5_moe_42b").reduced(layers=2, d_model=128).with_(
        dtype="float32", param_dtype="float32", vocab_size=256)
    _, _, hist = train(cfg, steps=25, opt=AdamWConfig(lr=3e-3,
                                                      warmup_steps=5),
                       batch_size=8, seq_len=64, log_every=1)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_data_pipeline_deterministic_and_structured():
    cfg = DataConfig(vocab_size=128, seq_len=32, batch_size=4, seed=7)
    ds = SyntheticDataset(cfg)
    b1 = next(ds.batches())
    b2 = next(ds.batches())
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # markov structure: successor matches the permutation most of the time
    t = b1["tokens"]
    hits = np.mean(ds.perm[t[:, :-1]] == t[:, 1:])
    assert hits > 0.5


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("stablelm_12b").reduced(layers=2, d_model=128).with_(
        param_dtype="float32", vocab_size=128)
    from repro.models import model
    import jax
    params = model.init(cfg, jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.msgpack")
    checkpoint.save(path, params, meta={"step": np.asarray(7)})
    loaded = checkpoint.load(path)
    assert int(loaded["__meta__"]["step"]) == 7
    for k, v in params.items():
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(loaded["params"][k]))


def test_bf16_optimizer_states():
    cfg = get_config("xlstm_125m").reduced(layers=2, d_model=128).with_(
        dtype="float32", param_dtype="float32", vocab_size=128)
    _, opt_state, hist = train(
        cfg, steps=6, opt=AdamWConfig(lr=1e-3, state_dtype="bfloat16"),
        batch_size=4, seq_len=32, log_every=1)
    leaf = next(iter(opt_state["m"].values()))
    assert leaf.dtype == jnp.bfloat16
    assert np.isfinite(hist[-1]["loss"])
