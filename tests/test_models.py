"""Per-architecture smoke + prefill/decode equivalence on reduced configs.

Smoke (deliverable f): every assigned architecture instantiates a REDUCED
family variant (<=2 layers, d_model<=512, <=4 experts), runs one forward +
train step on CPU, asserts output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs, get_config, list_archs
from repro.models import model
from repro.training.optimizer import AdamWConfig, apply_updates, init_state
from repro.training.train_loop import make_train_step

ARCHS = list_archs(include_paper_model=True)


def _reduced(name, **kw):
    cfg = get_config(name).reduced().with_(dtype="float32",
                                           param_dtype="float32", **kw)
    if cfg.is_moe:
        cfg = cfg.with_(capacity_factor=float(cfg.num_experts))
    return cfg


@pytest.mark.parametrize("name", ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = _reduced(name)
    assert cfg.num_layers <= 3 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = model.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend:
        batch["embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model))
    logits, aux = model.forward_train(cfg, params, batch["tokens"],
                                      batch.get("embeds"))
    F = cfg.frontend_tokens if cfg.frontend else 0
    assert logits.shape == (B, S + F, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    # one full train step
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3)))
    opt_state = init_state(params, AdamWConfig())
    params2, _, metrics = step(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert not jnp.isnan(params2["final_norm"]).any()


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_matches_full_forward(name):
    cfg = _reduced(name)
    params = model.init(cfg, jax.random.PRNGKey(0))
    B, S, T = 2, 35, 3
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + T), 0,
                                cfg.vocab_size)
    embeds = None
    F = 0
    if cfg.frontend:
        F = cfg.frontend_tokens
        embeds = 0.02 * jax.random.normal(jax.random.PRNGKey(2),
                                          (B, F, cfg.d_model))
    full, _ = model.forward_train(cfg, params, tokens, embeds)
    pf, caches = model.prefill(cfg, params, tokens[:, :S], embeds)
    assert jnp.max(jnp.abs(pf - full[:, :F + S])) < 2e-3
    cache = model.init_cache(cfg, B, capacity=F + S + T, dtype=jnp.float32)
    cache = model.seed_cache(cfg, cache, caches, F + S)
    for t in range(T):
        pos = jnp.full((B,), F + S + t, jnp.int32)
        lg, cache = model.decode_step(cfg, params,
                                      tokens[:, S + t:S + t + 1], pos, cache)
        assert jnp.max(jnp.abs(lg - full[:, F + S + t])) < 2e-3


def test_sliding_window_ring_buffer_decode():
    cfg = _reduced("mistral_nemo_12b", sliding_window=16)
    params = model.init(cfg, jax.random.PRNGKey(1))
    B, S, T = 2, 37, 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + T), 0,
                                cfg.vocab_size)
    full, _ = model.forward_train(cfg, params, tokens)
    _, caches = model.prefill(cfg, params, tokens[:, :S])
    cache = model.init_cache(cfg, B, capacity=S + T, dtype=jnp.float32)
    assert cache["A"]["k"].shape[2] == 16      # window-clamped
    cache = model.seed_cache(cfg, cache, caches, S)
    for t in range(T):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, cache = model.decode_step(cfg, params,
                                      tokens[:, S + t:S + t + 1], pos, cache)
        assert jnp.max(jnp.abs(lg - full[:, S + t])) < 2e-3


def test_adamw_reduces_loss_direction():
    cfg = _reduced("xlstm_125m")
    params = model.init(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(lr=1e-2, warmup_steps=1)
    state = init_state(params, opt)
    g = jax.tree.map(jnp.ones_like, params)
    p2, state2, gnorm = apply_updates(params, g, state, opt)
    assert float(gnorm) > 0
    assert int(state2["step"]) == 1
    # params moved against the gradient
    assert float(p2["final_norm"][0]) < float(params["final_norm"][0])
