"""Hypothesis property tests over the simulator: for random small traces
and any scheduler, every request completes exactly once and no KVC leaks."""
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import predictor, simulator
from repro.core.costmodel import CostModel
from repro.core.registry import make_scheduler
from repro.core.request import Request, State
from repro.core.scheduler import SchedulerConfig

SCHEDS = ["orca", "vllm", "sarathi", "multires", "econoserve",
          "econoserve-d"]


@st.composite
def small_trace(draw):
    n = draw(st.integers(3, 25))
    reqs = []
    t = 0.0
    for i in range(n):
        t += draw(st.floats(0.0, 1.0))
        reqs.append(Request(
            rid=i,
            prompt_len=draw(st.integers(1, 900)),
            true_rl=draw(st.integers(1, 700)),
            arrival=t,
            slo_deadline=t + draw(st.floats(0.1, 100.0))))
    return reqs


@settings(max_examples=25, deadline=None)
@given(trace=small_trace(), sched_name=st.sampled_from(SCHEDS),
       acc=st.floats(0.3, 1.0))
def test_complete_exactly_once_no_leak(trace, sched_name, acc):
    cfg = SchedulerConfig(kvc_tokens=4096, max_model_len=1024)
    cost = CostModel()
    predictor.annotate(trace, predictor.NoisyPredictor(accuracy=acc, seed=0),
                       pad_ratio=0.15)
    sched = make_scheduler(sched_name, cfg, cost)
    res = simulator.simulate(trace, sched, cost, max_iters=200_000)
    done = [r for r in trace if r.t_complete is not None]
    assert len(done) == len(trace), (sched_name, len(done), len(trace))
    assert len(sched.completed) == len(trace)
    assert all(r.state == State.COMPLETED for r in done)
    sched.kvc.check_invariants()
    assert sched.kvc.free_blocks == sched.kvc.total_blocks
    # time accounting: component times are non-negative
    for r in done:
        assert r.waiting_time >= 0 and r.exec_time >= 0
        assert r.preempt_time >= 0 and r.gt_queue_time >= 0
