"""Diurnal trace replayer: the metrics plane's judgement harness.

Replays a large synthetic request log (default 100k requests) through
``ClusterSim`` with a *diurnal* arrival process — a sinusoidal day/night
rate ramp with Poisson flash-crowd bursts on top (``traces.DiurnalSpec``)
and the usual heavy-tailed lognormal prompt/response lengths — while
recording fleet state into the ``repro.obs`` registry:

  * per-sample gauges → ``TimeSeriesLog``: instantaneous goodput,
    windowed TTFT/TPOT means, arrival rate, queue depths, running
    requests, KVC allocated fraction per instance;
  * per-completion observations → registry histograms
    (``replay_ttft_seconds``, ``replay_tpot_seconds``,
    ``replay_jct_seconds``);
  * end-of-run → the full ``ClusterSim.publish_metrics`` family set,
    exported as Prometheus text + JSON snapshot.

Exit is non-zero unless the conservation audit is green (every routed
request reaches exactly one terminal state, zero double routes) and the
requested request count was actually replayed — this is the CI judge for
the observability PR, wired into the hotpath job as ``--tiny``.

Usage:
    python -m benchmarks.trace_replay                # full 100k replay
    python -m benchmarks.trace_replay --tiny         # CI smoke (~2k)
    python -m benchmarks.trace_replay --out DIR      # write exports
"""
from __future__ import annotations

import argparse
import copy
import json
import math
import os
import sys
import time
from typing import Dict, List, Optional

from repro.core import predictor, traces
from repro.core.registry import make_scheduler, needs_oracle_rl
from repro.core.scheduler import SchedulerConfig
from repro.cluster.sim import ClusterSim
from repro.obs import (MetricsRegistry, TimeSeriesLog, to_prometheus_text,
                       parse_prometheus_text, write_json_snapshot,
                       write_prometheus)

from .common import ACCURACY, PAD_RATIOS, cost_model, sched_config

DEFAULT_BUCKETS_S = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                     100.0)


class ReplayRecorder:
    """The ``on_sample`` hook: harvests completions since the last tick
    into registry histograms and appends one point per gauge series to
    the ``TimeSeriesLog``."""

    def __init__(self, registry: MetricsRegistry, log: TimeSeriesLog):
        self.registry = registry
        self.log = log
        self.ttft = registry.histogram(
            "replay_ttft_seconds", "time to first token",
            buckets=DEFAULT_BUCKETS_S)
        self.tpot = registry.histogram(
            "replay_tpot_seconds", "mean time per output token",
            buckets=tuple(b / 50 for b in DEFAULT_BUCKETS_S))
        self.jct = registry.histogram(
            "replay_jct_seconds", "job completion time",
            buckets=DEFAULT_BUCKETS_S)
        self.goodput_g = registry.gauge(
            "replay_goodput_rps", "SLO-met completions per second over "
            "the last sample window")
        self.arrival_g = registry.gauge(
            "replay_arrival_rate_rps", "arrivals routed per second over "
            "the last sample window")
        self._n_done: Dict[int, int] = {}
        self._last_t = 0.0
        self._last_routed = 0
        self.n_samples = 0

    def __call__(self, t: float, cs: ClusterSim) -> None:
        self.n_samples += 1
        window = max(1e-9, t - self._last_t)
        met = done = 0
        sum_ttft = n_ttft = 0.0
        for inst in cs.instances:
            comp = inst.sim.scheduler.completed
            start = self._n_done.get(inst.id, 0)
            for r in comp[start:]:
                done += 1
                met += r.met_slo
                self.jct.unlabeled.observe(r.jct)
                if r.t_first_token is not None:
                    ttft = r.t_first_token - r.arrival
                    self.ttft.unlabeled.observe(ttft)
                    sum_ttft += ttft
                    n_ttft += 1
                    if r.generated > 1 and r.t_complete is not None:
                        self.tpot.unlabeled.observe(
                            (r.t_complete - r.t_first_token)
                            / (r.generated - 1))
            self._n_done[inst.id] = len(comp)
        self.goodput_g.unlabeled.set(met / window)
        self.arrival_g.unlabeled.set(
            (len(cs.route_of) - self._last_routed) / window)
        self._last_routed = len(cs.route_of)
        self._last_t = t

        point = {"replay_goodput_rps": met / window,
                 "replay_completions_window": done,
                 "replay_ttft_mean_s":
                     (sum_ttft / n_ttft) if n_ttft else 0.0,
                 "replay_arrival_rate_rps": self.arrival_g.unlabeled.value}
        for inst in cs.instances:
            sched = inst.sim.scheduler
            i = inst.id
            point[f'scheduler_queue_depth{{instance="{i}",queue="pt"}}'] \
                = len(sched.pt_queue)
            point[f'scheduler_queue_depth{{instance="{i}",queue="gt"}}'] \
                = len(sched.gt_queue)
            point[f'scheduler_running_requests{{instance="{i}"}}'] = sum(
                len(g.members) for g in sched.running_groups)
            point[f'kvc_allocated_frac{{instance="{i}"}}'] = \
                sched.kvc.allocated_frac
        self.log.record(t, point)


def replay(n: int = 100_000, sched: str = "econoserve",
           trace: str = "alpaca", n_instances: int = 2,
           router: str = "least-kvc", rate: Optional[float] = None,
           seed: int = 0, n_samples: int = 400,
           max_iters: int = 20_000_000,
           diurnal: Optional[traces.DiurnalSpec] = None):
    """Generate, annotate and replay; returns (result, registry, log,
    recorder, wall_seconds)."""
    spec = traces.TRACES[trace]
    rate = rate if rate is not None else spec.rate
    dspec = diurnal or traces.DiurnalSpec()
    reqs = traces.generate_diurnal(spec, n, seed=seed, rate=rate,
                                   diurnal=dspec)
    span = reqs[-1].arrival if reqs else 1.0

    cfg = sched_config(trace)
    cost = cost_model()
    reqs = copy.deepcopy(reqs)
    if needs_oracle_rl(sched):
        pred = predictor.OraclePredictor(cfg.bucket)
        predictor.annotate(reqs, pred, 0.0, cfg.bucket)
    else:
        pred = predictor.NoisyPredictor(accuracy=ACCURACY[trace],
                                        bucket=cfg.bucket, seed=seed)
        predictor.annotate(reqs, pred, PAD_RATIOS[trace], cfg.bucket)

    registry = MetricsRegistry()
    log = TimeSeriesLog()
    rec = ReplayRecorder(registry, log)
    cs = ClusterSim(lambda i: make_scheduler(sched, cfg, cost), cost,
                    n_instances=n_instances, router=router, seed=seed,
                    name=f"replay-{sched}-x{n_instances}")
    t0 = time.perf_counter()
    res = cs.run(reqs, max_iters=max_iters,
                 sample_every=span / max(1, n_samples), on_sample=rec)
    wall = time.perf_counter() - t0
    cs.publish_metrics(registry)
    registry.counter("replay_requests_total", "requests in the replayed "
                     "log").unlabeled.inc_to(len(reqs))
    registry.gauge("replay_trace_span_seconds",
                   "arrival span of the log").unlabeled.set(span)
    return res, registry, log, rec, wall


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=100_000,
                    help="requests to replay (default 100000)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 2000 requests, 60 samples")
    ap.add_argument("--sched", default="econoserve")
    ap.add_argument("--trace", default="alpaca",
                    choices=sorted(traces.TRACES))
    ap.add_argument("--instances", type=int, default=2)
    ap.add_argument("--router", default="least-kvc")
    ap.add_argument("--rate", type=float, default=None,
                    help="base arrival rate (default: the trace's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--samples", type=int, default=400,
                    help="time-series sample points over the replay")
    ap.add_argument("--out", default=None,
                    help="directory for metrics.prom / metrics.json / "
                         "timeseries.json")
    args = ap.parse_args(argv)
    n = 2_000 if args.tiny else args.n
    n_samples = 60 if args.tiny else args.samples

    print(f"replaying {n} {args.trace} requests (diurnal + bursts) "
          f"through {args.sched} x{args.instances} ...")
    res, registry, log, rec, wall = replay(
        n=n, sched=args.sched, trace=args.trace,
        n_instances=args.instances, router=args.router, rate=args.rate,
        seed=args.seed, n_samples=n_samples)

    cons = res.conservation()
    snap = registry.snapshot()
    ttft = snap.get("replay_ttft_seconds")
    print(f"  wall {wall:.1f}s  trace-span {res.wall_time:.0f}s  "
          f"goodput {res.goodput:.2f}/s  ssr {res.ssr:.3f}")
    print(f"  completed {len(res.completed)}  aborted "
          f"{len(res.aborted)}  migrations {res.n_migrations}  "
          f"samples {rec.n_samples}")
    if ttft is not None and ttft.count:
        print(f"  ttft mean {ttft.sum / ttft.count:.3f}s over "
              f"{ttft.count} first tokens")
    print(f"  conservation: {cons}")

    if args.out:
        os.makedirs(args.out, exist_ok=True)
        prom = os.path.join(args.out, "metrics.prom")
        write_prometheus(snap, prom)
        parse_prometheus_text(open(prom).read())   # self-check
        write_json_snapshot(snap, os.path.join(args.out, "metrics.json"),
                            extra={"conservation": cons,
                                   "wall_seconds": wall})
        log.write(os.path.join(args.out, "timeseries.json"))
        print(f"  wrote {args.out}/metrics.prom, metrics.json, "
              f"timeseries.json")

    ok = True
    if not cons["ok"]:
        print("FAIL: conservation audit violated")
        ok = False
    if cons["routed"] < n:
        print(f"FAIL: only routed {cons['routed']}/{n} requests")
        ok = False
    if rec.n_samples < min(10, n_samples):
        print(f"FAIL: only {rec.n_samples} time-series samples recorded")
        ok = False
    series = log.to_json()["series"]
    if "replay_goodput_rps" not in series:
        print("FAIL: goodput series missing")
        ok = False
    print("trace_replay OK" if ok else "trace_replay FAILED")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
