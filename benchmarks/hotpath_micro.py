"""Hot-path microbenchmarks: scheduler form_batch throughput (legacy full
re-sort vs incremental OrderedQueue with O(1) removal and a skip-list
priority index), steady-state decode-loop throughput (legacy host-synced
vs fused async device-resident) with host-blocking-sync counts per
iteration, decode-megastep dispatch amortization (K fused iterations per
dispatch vs one) both at empty queues and under a KVC-saturated workload
whose queues stay non-empty (the pressure-aware horizon), packed
multi-request chunk waves (>= 2 chunk grants in ONE prefill dispatch),
chunked-prefill per-iteration stall bounds under a long-prompt + decode
mixed wave, engine prefill retrace count under token packing,
cluster-layer conservation (2-instance real fleet + disaggregated
KV-migration pair + ClusterSim, every routed request completing exactly
once), and paged-attention kernel step time single- vs multi-page.

Emits before/after numbers to ``BENCH_hotpath.json`` at the repo root —
the baseline the acceptance criteria check against:

  * >= 5x form_batch ops/sec on a 10k-request synthetic trace,
  * >= 2x steady-state decode iterations/s at full batch, with zero
    blocking host syncs per steady-state async iteration,
  * ~K× fewer decode dispatches per generated token with megastep K=8
    (the structural invariant CI gates on),
  * a long prompt completing via >= 2 engine-executed chunks with tokens
    equal to the whole-prompt run and a bounded max single-iteration
    stall,
  * <= ceil(log2(max_total_prompt_tokens)) distinct prefill compilations.

Run:  PYTHONPATH=src python -m benchmarks.hotpath_micro [--quick]
      (--quick is a smoke run and does NOT rewrite BENCH_hotpath.json;
      only full runs refresh the committed baseline)
CI:   PYTHONPATH=src python -m benchmarks.hotpath_micro --check
      (quick mode, no JSON rewrite; exits 1 when the scheduler microbench
      regresses >2x, the decode loop regresses >3x — generous because
      runner scheduling is noisy, but a reintroduced per-iteration sync
      shows up far larger — or a structural invariant breaks: megastep
      dispatch amortization, chunked execution/equality)
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict

from repro.core import predictor, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_hotpath.json")


# --------------------------------------------------------------------- #
# 1. scheduler form_batch throughput
# --------------------------------------------------------------------- #
def bench_form_batch(n_reqs: int = 10_000, iters: int = 40,
                     seed: int = 0) -> Dict:
    """All requests arrive at t=0 (a worst-case standing queue): time
    form_batch+finish_iteration cycles with both queue implementations."""
    out = {}
    for label, incremental in (("legacy_sort", False),
                               ("incremental", True)):
        reqs = traces.generate(traces.SHAREGPT, n_reqs, seed=seed, rate=1e9)
        predictor.annotate(reqs, predictor.NoisyPredictor(seed=seed), 0.15)
        cfg = dataclasses.replace(SchedulerConfig(),
                                  incremental_queues=incremental)
        cost = CostModel()
        sched = make_econoserve(cfg, cost, "full")
        for r in reqs:
            sched.on_arrival(r, 0.0)
        t = 0.0
        t0 = time.perf_counter()
        done = 0
        for _ in range(iters):
            plan = sched.form_batch(t)
            if plan.empty:
                break
            t += plan.sched_time + plan.extra_time + 0.05
            sched.finish_iteration(t)
            done += 1
        dt = time.perf_counter() - t0
        out[label] = {"iters": done, "seconds": round(dt, 4),
                      "form_batch_per_s": round(done / dt, 2)}
    out["speedup"] = round(out["incremental"]["form_batch_per_s"]
                           / out["legacy_sort"]["form_batch_per_s"], 2)
    return out


# --------------------------------------------------------------------- #
# 2. steady-state decode loop: legacy host-synced vs fused async
# --------------------------------------------------------------------- #
def bench_decode_loop(decode_iters: int = 300, seed: int = 0) -> Dict:
    """Full-batch steady-state decode (no admissions, no completions inside
    the timed window): iterations/s plus blocking host syncs per iteration.
    The legacy path materializes every iteration's sampled batch and then
    reads tokens per request; the async path carries state on device and
    drains tokens with a readback lag, so its steady-state blocking-sync
    count is zero."""
    import numpy as np
    from repro.configs import get_config
    from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                               ServingEngine)

    # deliberately tiny model: the quantity under test is the *per-
    # iteration host overhead* (dispatches, transfers, readbacks), which
    # this PR removes — a large model would bury it under compute that is
    # identical on both paths
    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")
    # batch 16 is "full batch" here: big enough that the sync path's O(B)
    # per-iteration host work (the per-request int() reads this PR removes)
    # is visible, small enough that the tiny model still fits the 2-core
    # CI-class containers without saturating them
    mb, warmup, n_windows = 16, 8, 5
    # each path gets its own engine measured alone (as it runs in
    # production — back-to-back alternation lets the async path's constant
    # device activity keep the XLA threadpool spinning through the sync
    # path's blocking waits, flattering the sync number). The median over
    # N windows discards thread-handoff spike and stall windows alike;
    # regimes persist for seconds on small shared boxes, so individual
    # runs still swing — compare medians across fresh processes.
    per_window = max(1, decode_iters // n_windows)
    out = {}
    for label, ecfg in (
            ("sync_legacy", EngineConfig(async_decode=False,
                                         packed_prefill=False)),
            ("async_device", EngineConfig(async_decode=True,
                                          packed_prefill=True))):
        eng = ServingEngine(cfg, max_batch=mb, capacity=512,
                            rl_accuracy=1.0, seed=seed, engine_cfg=ecfg)
        rng = np.random.default_rng(seed)
        reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 16)),
                           params=SamplingParams(
                               max_new_tokens=decode_iters + warmup + 64))
                for _ in range(mb)]
        t = 0.0
        for g in reqs:
            eng.submit(g, t)
        for _ in range(warmup):                 # prefill + compile
            t += 1.0
            eng.step(t)
        base_iters = eng.decode_iters
        base_counts = dict(eng.sync_counts)
        rates, total_s = [], 0.0
        for _ in range(n_windows):
            t0 = time.perf_counter()
            for _ in range(per_window):
                t += 1.0
                eng.step(t)
            dt = time.perf_counter() - t0
            total_s += dt
            rates.append(per_window / dt)
        n = eng.decode_iters - base_iters
        window = {k: eng.sync_counts[k] - base_counts[k]
                  for k in eng.sync_counts}
        blocking = window["eos_flags"] + window["drain_blocking"]
        rates.sort()
        out[label] = {
            "iters": n, "seconds": round(total_s, 4),
            "iters_per_s": round(rates[len(rates) // 2], 1),
            "blocking_syncs_per_iter": round(blocking / n, 4),
            "host_sync_counts": window,
        }
    out["speedup"] = round(out["async_device"]["iters_per_s"]
                           / out["sync_legacy"]["iters_per_s"], 2)
    return out


# --------------------------------------------------------------------- #
# 3. decode megastep: dispatches per iteration amortized ~K×
# --------------------------------------------------------------------- #
def bench_decode_megastep(decode_iters: int = 240, seed: int = 0) -> Dict:
    """Steady-state full-batch decode with the fused K-iteration window vs
    the per-iteration async path. iters/s is wall-clock (noisy on shared
    runners); *dispatches per iteration* is the structural invariant
    (~1/K in steady state) CI gates on."""
    import numpy as np
    from repro.configs import get_config
    from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                               ServingEngine)

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")
    mb, warmup, n_windows = 16, 12, 5
    per_window = max(1, decode_iters // n_windows)
    out = {}
    for label, k in (("per_iteration", 1), ("megastep_8", 8)):
        eng = ServingEngine(cfg, max_batch=mb, capacity=512,
                            rl_accuracy=1.0, seed=seed,
                            engine_cfg=EngineConfig(decode_megastep=k))
        rng = np.random.default_rng(seed)
        reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 16)),
                           params=SamplingParams(
                               max_new_tokens=decode_iters + warmup + 64))
                for _ in range(mb)]
        t = 0.0
        for g in reqs:
            eng.submit(g, t)
        for _ in range(warmup):                 # admit + compile + settle
            t += 1.0
            eng.step(t)
        base_iters = eng.decode_iters
        base_disp = eng.n_decode_dispatches
        base_counts = dict(eng.sync_counts)
        rates, total_s = [], 0.0
        for _ in range(n_windows):
            t0 = time.perf_counter()
            for _ in range(per_window):
                t += 1.0
                eng.step(t)
            dt = time.perf_counter() - t0
            total_s += dt
            rates.append(per_window / dt)
        n = eng.decode_iters - base_iters
        disp = eng.n_decode_dispatches - base_disp
        window = {kk: eng.sync_counts[kk] - base_counts[kk]
                  for kk in eng.sync_counts}
        blocking = window["eos_flags"] + window["drain_blocking"]
        rates.sort()
        out[label] = {
            "iters": n, "seconds": round(total_s, 4),
            "iters_per_s": round(rates[len(rates) // 2], 1),
            "dispatches": disp,
            "dispatches_per_iter": round(disp / n, 4),
            "blocking_syncs_per_iter": round(blocking / n, 4),
            "host_sync_counts": window,
        }
    out["speedup"] = round(out["megastep_8"]["iters_per_s"]
                           / out["per_iteration"]["iters_per_s"], 2)
    out["dispatch_amortization"] = round(
        out["per_iteration"]["dispatches_per_iter"]
        / max(out["megastep_8"]["dispatches_per_iter"], 1e-9), 1)
    return out


# --------------------------------------------------------------------- #
# 3b. pressure megastep: windows stay fused while the queues are
#     KVC-blocked (the saturated regime every figure benchmark runs in)
# --------------------------------------------------------------------- #
def bench_pressure_megastep(measure_iters: int = 60, seed: int = 0) -> Dict:
    """KVC-saturated steady state: 4 running requests exact-allocate the
    whole KVC while 8 more wait, so queues stay non-empty through the
    measured window. Before the pressure-aware horizon the megastep
    collapsed to K=1 here (~1x amortization, 1 dispatch/iteration); the
    no-admission certificate keeps windows fused, and both engines must
    produce identical token streams. Counter-based, gated by --check."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.scheduler import SchedulerConfig
    from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                               ServingEngine)

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")
    mb = 8
    scfg = SchedulerConfig(kvc_tokens=512, block_size=16, tfs=256,
                           max_model_len=256, max_batch_reqs=mb,
                           reserve_frac=0.0, pad_ratio=0.0, bucket=16)
    out: Dict = {}
    streams = {}
    for label, k in (("per_iteration", 1), ("megastep_8", 8)):
        eng = ServingEngine(cfg, max_batch=mb, capacity=256,
                            rl_accuracy=1.0, seed=seed, scheduler_cfg=scfg,
                            engine_cfg=EngineConfig(decode_megastep=k))
        rng = np.random.default_rng(seed)
        # 16-token prompt + 112 predicted RL = 8 blocks; 4 fill the
        # 32-block KVC exactly, 8 wait KVC-blocked
        reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 16)),
                           params=SamplingParams(max_new_tokens=112))
                for _ in range(12)]
        t = 0.0
        for g in reqs:
            eng.submit(g, t)
        for _ in range(40):                 # admit + compile + settle
            t += 1.0
            eng.step(t)
        base_iters = eng.decode_iters
        base_disp = eng.n_decode_dispatches
        qmin = 10 ** 9
        t0 = time.perf_counter()
        for _ in range(measure_iters):
            t += 1.0
            eng.step(t)
            s = eng.scheduler
            qmin = min(qmin, len(s.pt_queue) + len(s.gt_queue))
        dt = time.perf_counter() - t0
        n = eng.decode_iters - base_iters
        disp = eng.n_decode_dispatches - base_disp
        while eng.has_work() and t < 5000:   # drain for token equality
            t += 1.0
            eng.step(t)
        eng.flush()
        streams[label] = [g.output for g in reqs]
        out[label] = {
            "iters": n, "seconds": round(dt, 4),
            "iters_per_s": round(n / dt, 1),
            "dispatches": disp,
            "dispatches_per_iter": round(disp / max(n, 1), 4),
            "min_queued_during_window": qmin,
        }
    out["queues_nonempty_throughout"] = (
        out["per_iteration"]["min_queued_during_window"] >= 1
        and out["megastep_8"]["min_queued_during_window"] >= 1)
    out["tokens_equal"] = streams["per_iteration"] == streams["megastep_8"]
    out["dispatch_amortization"] = round(
        out["per_iteration"]["dispatches_per_iter"]
        / max(out["megastep_8"]["dispatches_per_iter"], 1e-9), 1)
    out["note"] = ("pre-PR5 the horizon returned 1 whenever a queue was "
                   "non-empty, so this workload ran at 1 dispatch/iter; "
                   "the KVC-bound certificate keeps windows fused")
    return out


# --------------------------------------------------------------------- #
# 3c. packed chunk prefill: a >= 2-chunked-request wave in ONE dispatch
# --------------------------------------------------------------------- #
def bench_packed_chunk(seed: int = 0) -> Dict:
    """Several long prompts admitted together under a small TFS produce
    iterations granting chunks to >= 2 requests. The packed path must run
    each such wave as ONE prefill dispatch (per-segment prefix views +
    block-diagonal masking) with token streams identical to the
    one-call-per-chunk reference. Counter-based, gated by --check."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.scheduler import SchedulerConfig
    from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                               ServingEngine)

    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    mb, cap, tfs = 4, 256, 64
    out: Dict = {}
    streams = {}
    for label, packed in (("per_chunk_call", False), ("packed", True)):
        scfg = SchedulerConfig(kvc_tokens=mb * cap, block_size=32, tfs=tfs,
                               max_model_len=cap, max_batch_reqs=mb)
        eng = ServingEngine(cfg, max_batch=mb, capacity=cap,
                            rl_accuracy=1.0, seed=seed, scheduler_cfg=scfg,
                            engine_cfg=EngineConfig(
                                packed_chunk_prefill=packed))
        rng = np.random.default_rng(seed)
        reqs = [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size, L)),
            params=SamplingParams(max_new_tokens=6))
            for L in (96, 80, 72)]
        t0 = time.perf_counter()
        eng.run(reqs)
        dt = time.perf_counter() - t0
        streams[label] = [g.output for g in reqs]
        out[label] = {
            "n_prefill_chunks": eng.n_prefill_chunks,
            "n_chunk_dispatches": eng.n_chunk_calls,
            "max_chunk_items_per_dispatch": eng.max_chunk_items_per_call,
            "seconds": round(dt, 2),
        }
    out["tokens_equal"] = streams["per_chunk_call"] == streams["packed"]
    out["wave_packed"] = out["packed"]["max_chunk_items_per_dispatch"] >= 2
    out["dispatches_saved"] = (out["per_chunk_call"]["n_chunk_dispatches"]
                               - out["packed"]["n_chunk_dispatches"])
    out["note"] = ("the reference path pays one model call per chunked "
                   "request per iteration; packing flattens the wave into "
                   "one (1, T) call with per-segment cache-prefix views")
    return out


# --------------------------------------------------------------------- #
# 4. chunked prefill: bounded per-iteration stall under a long-prompt +
#    decode mixed wave
# --------------------------------------------------------------------- #
def bench_chunked_prefill(plen: int = 256, chunk_tfs: int = 64,
                          seed: int = 0) -> Dict:
    """A long prompt arrives while a decode batch runs. Whole-prompt
    prefill stalls every in-flight decode for the full prompt's forward
    pass; chunked execution (TFS < prompt) bounds the max single-iteration
    stall near the per-chunk cost, at the price of spreading the long
    request's TTFT over ceil(plen/TFS) iterations. Token streams must be
    identical either way."""
    import numpy as np
    from repro.configs import get_config
    from repro.core.scheduler import SchedulerConfig
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    mb, cap = 4, 512
    out: Dict = {}
    streams = {}
    for label, tfs in (("whole_prompt", cap), (f"chunked_{chunk_tfs}",
                                               chunk_tfs)):
        scfg = SchedulerConfig(kvc_tokens=mb * cap, block_size=32, tfs=tfs,
                               max_model_len=cap, max_batch_reqs=mb)
        eng = ServingEngine(cfg, max_batch=mb, capacity=cap,
                            rl_accuracy=1.0, seed=seed, scheduler_cfg=scfg)
        rng = np.random.default_rng(seed)

        def wave():
            shorts = [GenRequest(
                prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                params=SamplingParams(max_new_tokens=48))
                for _ in range(mb - 1)]
            long_req = GenRequest(
                prompt=list(rng.integers(0, cfg.vocab_size, plen)),
                params=SamplingParams(max_new_tokens=8))
            return shorts, long_req

        t = 0.0
        all_reqs = []
        step_ms, prefill_ms = [], []
        rid = None
        # pass 1 warms every shape (prefill buckets, chunk buckets, decode
        # windows) so pass-2 timings measure execution, not compilation
        for passno in ("warm", "measured"):
            shorts, long_req = wave()
            all_reqs += shorts + [long_req]
            for g in shorts:
                eng.submit(g, t)
            for _ in range(6):      # reach steady decode before the long
                t += 1.0            # prompt lands
                eng.step(t)
            rid = eng.submit(long_req, t)
            while eng.has_work() and t < 600:
                t += 1.0
                t0 = time.perf_counter()
                eng.step(t)
                if passno == "measured":
                    dt = (time.perf_counter() - t0) * 1e3
                    step_ms.append(dt)
                    p = eng.scheduler.current_plan
                    if p is not None and p.prompt_items:
                        # attribute to prefill: these iterations are where
                        # a prompt stalls the in-flight decode batch
                        prefill_ms.append(dt)
        if eng._pending_drain:
            eng._drain_tokens(force=True)
        streams[label] = [g.output for g in all_reqs]
        sreq = next(r for r in eng.scheduler.completed if r.rid == rid)
        step_ms.sort()
        out[label] = {
            "tfs": tfs,
            "n_chunks": eng.n_prefill_chunks,
            "ttft_iterations": int(sreq.t_first_token - sreq.arrival),
            "p50_step_ms": round(step_ms[len(step_ms) // 2], 2),
            "max_step_ms": round(step_ms[-1], 2),
            "max_prefill_step_ms": round(max(prefill_ms), 2),
        }
    chunk_label = f"chunked_{chunk_tfs}"
    out["tokens_equal"] = streams["whole_prompt"] == streams[chunk_label]
    out["prefill_stall_ratio"] = round(
        out["whole_prompt"]["max_prefill_step_ms"]
        / max(out[chunk_label]["max_prefill_step_ms"], 1e-9), 2)
    out["note"] = ("max_prefill_step_ms bounds the decode-token stall a "
                   "prompt admission inflicts on in-flight requests "
                   "(max_step_ms also includes megastep window-boundary "
                   "drains, identical in both configs); chunking trades "
                   "the long request's own TTFT (spread over its chunks) "
                   "for that bound")
    return out


# --------------------------------------------------------------------- #
# 5. engine prefill retraces under token packing
# --------------------------------------------------------------------- #
def bench_prefill_retraces(n: int = 24, seed: int = 0) -> Dict:
    import numpy as np
    from repro.configs import get_config
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    max_batch = 4
    eng = ServingEngine(cfg, max_batch=max_batch, capacity=256,
                        rl_accuracy=1.0, seed=seed)
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 120, n)          # many distinct prompt lengths
    reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, L)),
                       params=SamplingParams(max_new_tokens=4))
            for L in lens]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    max_prompt = int(lens.max())
    # token-packed prefill flattens a wave of <= max_batch prompts into one
    # (1, T) call, so the bucket axis is total wave tokens, not row length
    bound = max(1, math.ceil(math.log2(max_batch * max_prompt)))
    return {"n_requests": n, "distinct_prompt_lens": int(len(set(lens))),
            "max_prompt": max_prompt,
            "prefill_compiles": eng.n_prefill_compiles,
            "prefill_shapes": sorted(eng._prefill_shapes),
            "bound_log2_max_wave_tokens": bound,
            "within_bound": eng.n_prefill_compiles <= bound,
            "run_seconds": round(dt, 2),
            "note": "pre-refactor engine retraced once per distinct "
                    "prompt length; packed prefill pads no batch rows — "
                    "shapes are (1, pow2_total_tokens)"}


# --------------------------------------------------------------------- #
# 6. cluster: 2-instance real fleet smoke + ClusterSim conservation
# --------------------------------------------------------------------- #
def bench_cluster(n_reqs: int = 8, sim_reqs: int = 300,
                  seed: int = 0) -> Dict:
    """Structural gates for the cluster layer, both backends:

      * a 2-instance real-engine fleet (tiny model) serves ``n_reqs``
        online requests — every submitted request must complete exactly
        once with zero double-routes;
      * a disaggregated prefill/decode pair must migrate every request
        (KV export → inject) and stay greedy-token-equal to a single
        engine serving the same stream;
      * a 3-instance ClusterSim over a sharegpt trace must conserve rids.

    All counter-based — immune to wall-clock noise, gated by --check.
    """
    import numpy as np
    from repro.cluster import EngineFleet
    from repro.configs import get_config
    from repro.core import registry
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")

    def mk_reqs():
        rng = np.random.default_rng(seed + 11)
        return [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(8, 24)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(4, 10)),
                                  temperature=0.0))
            for _ in range(n_reqs)]

    out: Dict = {}
    t0 = time.perf_counter()
    fleet = EngineFleet(cfg, n_instances=2, router="least-kvc", seed=seed,
                        max_batch=4, capacity=256, rl_accuracy=1.0)
    fleet.run(mk_reqs())
    cons = fleet.conservation()
    out["fleet_2x"] = {**cons, "router": "least-kvc",
                       "seconds": round(time.perf_counter() - t0, 2)}

    ref = ServingEngine(cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=seed)
    ref_reqs = mk_reqs()
    ref.run(ref_reqs)
    ref_out = [g.output for g in ref_reqs]
    t0 = time.perf_counter()
    disagg = EngineFleet(cfg, n_instances=2, roles=("prefill", "decode"),
                         router="least-kvc", seed=seed, max_batch=4,
                         capacity=256, rl_accuracy=1.0)
    dreqs = disagg.run(mk_reqs())
    dcons = disagg.conservation()
    out["fleet_disagg"] = {
        **dcons, "kv_fallbacks": disagg.n_kv_fallbacks,
        "tokens_equal_single_engine":
            [g.output for g in dreqs] == ref_out,
        "seconds": round(time.perf_counter() - t0, 2)}

    t0 = time.perf_counter()
    res = registry.run_cluster("econoserve", _cluster_trace(sim_reqs, seed),
                               n_instances=3, router="least-kvc", seed=seed)
    out["sim_3x"] = {**res.conservation(),
                     "goodput": round(res.goodput, 3),
                     "seconds": round(time.perf_counter() - t0, 2)}
    out["conservation_ok"] = bool(out["fleet_2x"]["ok"]
                                  and out["fleet_disagg"]["ok"]
                                  and out["sim_3x"]["ok"])
    return out


def _cluster_trace(n: int, seed: int):
    reqs = traces.generate(traces.SHAREGPT, n, seed=seed, rate=6.0)
    return reqs


# --------------------------------------------------------------------- #
# 6b. chaos: seeded fault injection + crash recovery gates
# --------------------------------------------------------------------- #
def bench_chaos(n_reqs: int = 8, seed: int = 0) -> Dict:
    """Fault-tolerance battery (counter-based, gated by --check):

      * a 3-instance fleet loses instance 1 mid-run (scripted kill):
        every request must still reach exactly one terminal state with
        zero aborts, >= 1 request must actually take the recovery path,
        the post-run invariant audit must find no KVC/slot/ring leaks,
        and the recovered greedy token streams must be bitwise-equal to
        a fault-free single-engine run of the same stream;
      * a disaggregated prefill/decode pair has a KV migration payload
        corrupted in flight: the inject-side checksum must reject it
        (>= 1 kv_reject), degrade to the recompute fallback, and keep
        the token streams equal anyway;
      * a KVC-saturated 2-instance fleet takes a mid-run ``squeeze``
        (capacity cut to half): the cut must land and fully drain on
        both instances, the pressure ladder must absorb it (zero
        aborts, zero sheds), and the recovered greedy streams must stay
        bitwise-equal to a pressure-free single-engine run.
    """
    import numpy as np
    from repro.cluster import (EngineFleet, FaultEvent, FaultInjector,
                               RecoveryConfig, check_fleet_invariants)
    from repro.configs import get_config
    from repro.core.scheduler import SchedulerConfig
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")

    def mk_reqs():
        rng = np.random.default_rng(seed + 23)
        return [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(8, 24)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(6, 14)),
                                  temperature=0.0))
            for _ in range(n_reqs)]

    out: Dict = {}
    t0 = time.perf_counter()
    fleet = EngineFleet(
        cfg, n_instances=3, router="least-kvc", seed=seed,
        max_batch=4, capacity=256, rl_accuracy=1.0,
        faults=FaultInjector(
            schedule=[FaultEvent(t=6.0, kind="kill", target=1)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=1.0))
    ref = ServingEngine(cfg, params=fleet.params, max_batch=4,
                        capacity=256, rl_accuracy=1.0, seed=seed)
    ref_reqs = mk_reqs()
    ref.run(ref_reqs)
    ref_out = [g.output for g in ref_reqs]
    reqs = fleet.run(mk_reqs())
    cons = fleet.conservation()
    try:
        inv_ok = bool(check_fleet_invariants(fleet)["ok"])
    except AssertionError as e:
        inv_ok = False
        out["invariant_failure"] = str(e)
    out["kill_recovery"] = {
        **cons, "invariants_ok": inv_ok,
        "fault_log": [list(ev) for ev in fleet.faults.log],
        "tokens_equal_no_fault_run":
            [g.output for g in reqs] == ref_out,
        "seconds": round(time.perf_counter() - t0, 2)}

    t0 = time.perf_counter()
    disagg = EngineFleet(
        cfg, n_instances=2, roles=("prefill", "decode"),
        router="least-kvc", seed=seed, max_batch=4, capacity=256,
        rl_accuracy=1.0,
        faults=FaultInjector(
            schedule=[FaultEvent(t=1.0, kind="corrupt_kv", count=2)]),
        recovery=RecoveryConfig())
    dreqs = disagg.run(mk_reqs())
    dcons = disagg.conservation()
    out["corrupt_kv"] = {
        **dcons, "n_corrupted": disagg.faults.n_corrupted,
        "tokens_equal_no_fault_run":
            [g.output for g in dreqs] == ref_out,
        "seconds": round(time.perf_counter() - t0, 2)}

    t0 = time.perf_counter()

    def mk_sq_reqs():
        rng = np.random.default_rng(5)
        return [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(8, 24)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(8, 16)),
                                  temperature=0.0))
            for _ in range(10)]

    sq = EngineFleet(
        cfg, n_instances=2, router="least-kvc", seed=seed,
        max_batch=4, capacity=128, rl_accuracy=1.0,
        scheduler_cfg=SchedulerConfig(kvc_tokens=224, block_size=16,
                                      tfs=128, max_model_len=128,
                                      max_batch_reqs=4),
        faults=FaultInjector(schedule=[
            FaultEvent(t=3.0, kind="squeeze", target=0, frac=0.5),
            FaultEvent(t=3.0, kind="squeeze", target=1, frac=0.5)]),
        recovery=RecoveryConfig(max_retries=3, backoff_base=1.0))
    sref = ServingEngine(cfg, params=sq.params, max_batch=4,
                         capacity=128, rl_accuracy=1.0, seed=seed)
    sref_reqs = mk_sq_reqs()
    sref.run(sref_reqs)
    sreqs = sq.run(mk_sq_reqs())
    scons = sq.conservation()
    try:
        sq_inv_ok = bool(check_fleet_invariants(sq)["ok"])
    except AssertionError as e:
        sq_inv_ok = False
        out["squeeze_invariant_failure"] = str(e)
    sq_drained = all(
        i.engine.scheduler.kvc.total_blocks <= 7
        and i.engine.scheduler.kvc.pending_shrink == 0
        for i in sq.instances)
    sq_pressure = sum(i.engine.scheduler.n_preempt_swap
                      + i.engine.scheduler.kvc.n_swap_outs
                      for i in sq.instances)
    out["squeeze"] = {
        **scons, "invariants_ok": sq_inv_ok,
        "cut_drained": sq_drained, "pressure_events": sq_pressure,
        "tokens_equal_no_fault_run":
            [g.output for g in sreqs] == [g.output for g in sref_reqs],
        "seconds": round(time.perf_counter() - t0, 2)}

    out["chaos_ok"] = bool(
        cons["ok"] and inv_ok and cons["aborted"] == 0
        and cons["recovered"] >= 1
        and out["kill_recovery"]["tokens_equal_no_fault_run"]
        and dcons["ok"] and dcons["kv_rejects"] >= 1
        and out["corrupt_kv"]["tokens_equal_no_fault_run"]
        and scons["ok"] and scons["aborted"] == 0
        and scons["shed"] == 0 and sq_inv_ok and sq_drained
        and sq_pressure >= 1
        and out["squeeze"]["tokens_equal_no_fault_run"])
    return out


def bench_detector(seed: int = 0) -> Dict:
    """Detected-failure substrate (counter-based, gated by --check):

      * **identity** — a detector-on fleet (heartbeats, transport,
        lease detection armed; zero fault windows) must produce token
        streams bitwise-equal to a plain fleet of the same seed AND add
        zero host syncs in total (beats are host-side bookkeeping; a
        clean transport delivers same-tick FIFO with zero rng draws).
        Gated on ``sum(sync_counts.values())`` like bench_swap's steady
        gate: the ready/backpressure/blocking split of a drain depends
        on device timing, but the *number* of drains/flushes/readbacks
        is fixed by the call sequence, which must be identical;
      * **chaos** — a 3-instance fleet takes a total beat-drop window on
        instance 1 (long enough to suspect, shorter than the lease: the
        false suspect must be *reinstated* without losing work), a KVC
        squeeze on instance 0 whose rung-4 ``kvc-infeasible`` sheds the
        fleet retry tier must re-route to a feasible peer (>= 1
        rescued), and a silent kill of instance 2 the detector must
        declare dead from missed beats alone — with every non-shed
        stream bitwise-equal to a fault-free single-engine run and the
        exactly-once/zero-leak audit green.
    """
    import numpy as np
    from repro.cluster import (DetectorConfig, EngineFleet, FaultInjector,
                               RecoveryConfig, check_fleet_invariants,
                               parse_chaos_spec)
    from repro.configs import get_config
    from repro.core.scheduler import SchedulerConfig
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")

    def mk_reqs(n=8, seed_=23, lo=6, hi=14):
        rng = np.random.default_rng(seed + seed_)
        return [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(8, 24)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(lo, hi)),
                                  temperature=0.0))
            for _ in range(n)]

    out: Dict = {}

    # -- identity: detector on, zero faults ----------------------------- #
    t0 = time.perf_counter()
    plain = EngineFleet(cfg, n_instances=2, router="least-kvc", seed=seed,
                        max_batch=4, capacity=256, rl_accuracy=1.0)
    arrivals = [0.5 * i for i in range(8)]
    p_reqs = plain.run(mk_reqs(), arrivals=arrivals)
    p_sync = sum(sum(i.engine.sync_counts.values())
                 for i in plain.instances)

    det = EngineFleet(cfg, n_instances=2, router="least-kvc", seed=seed,
                      max_batch=4, capacity=256, rl_accuracy=1.0,
                      detector=DetectorConfig())
    d_reqs = det.run(mk_reqs(), arrivals=arrivals)
    d_sync = sum(sum(i.engine.sync_counts.values())
                 for i in det.instances)
    out["identity"] = {
        "tokens_equal_plain_fleet":
            [g.output for g in d_reqs] == [g.output for g in p_reqs],
        "total_syncs_plain": p_sync,
        "total_syncs_detector": d_sync,
        "added_syncs": d_sync - p_sync,
        "detector_transitions": len(det.detector.transitions),
        "seconds": round(time.perf_counter() - t0, 2)}

    # -- chaos: false suspect + silent kill + shed rescue ---------------- #
    t0 = time.perf_counter()
    scfg = SchedulerConfig(kvc_tokens=224, block_size=16, tfs=128,
                           max_model_len=128, max_batch_reqs=4)
    spec = "drop@2:1/1.0,squeeze@3:0/0.6,kill@6:2"
    fleet = EngineFleet(
        cfg, n_instances=3, router="least-kvc", seed=seed,
        max_batch=4, capacity=128, rl_accuracy=1.0, scheduler_cfg=scfg,
        faults=FaultInjector(schedule=parse_chaos_spec(spec), seed=seed,
                             min_alive=1),
        recovery=RecoveryConfig(max_retries=4, backoff_base=1.0,
                                shed_retry=True),
        detector=DetectorConfig())
    ref = ServingEngine(cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=seed,
                        scheduler_cfg=scfg)
    ref_reqs = mk_reqs(n=10, seed_=5, lo=8, hi=16)
    ref.run(ref_reqs)
    reqs = fleet.run(mk_reqs(n=10, seed_=5, lo=8, hi=16))
    cons = fleet.conservation()
    try:
        inv_ok = bool(check_fleet_invariants(fleet)["ok"])
    except AssertionError as e:
        inv_ok = False
        out["invariant_failure"] = str(e)
    declared_dead = [tr for tr in fleet.detector.transitions
                    if tr[3] == "dead"]
    out["chaos"] = {
        **cons, "invariants_ok": inv_ok,
        "false_suspects_reinstated": fleet.detector.n_reinstated,
        "declared_dead": [tr[1] for tr in declared_dead],
        "transitions": [list(tr) for tr in fleet.detector.transitions],
        "transport": {"dropped": fleet.transport.n_dropped,
                      "duplicated": fleet.transport.n_duplicated,
                      "retransmits": fleet.transport.n_retransmits},
        "tokens_equal_no_fault_run":
            all(g.output == r.output for g, r in zip(reqs, ref_reqs)
                if g.status != "shed"),
        "seconds": round(time.perf_counter() - t0, 2)}

    out["detector_ok"] = bool(
        out["identity"]["tokens_equal_plain_fleet"]
        and out["identity"]["added_syncs"] <= 0
        and cons["ok"] and inv_ok
        and fleet.detector.n_reinstated >= 1
        and 2 in out["chaos"]["declared_dead"]
        and cons["shed_rescued"] >= 1
        and cons["dup_completions"] == 0
        and out["chaos"]["tokens_equal_no_fault_run"])
    return out


def bench_hedge(seed: int = 0) -> Dict:
    """Hedged-execution tier under straggler + partition chaos
    (counter-based, gated by --check):

      * **identity** — hedging *off* must be bitwise-free: a fleet with
        ``HedgeConfig(enabled=False)`` produces token streams equal to
        one built with ``hedge=None``, adds zero host syncs, and fires
        zero hedges (the coordinator exists but never issues a verdict);
      * **fleet chaos** — a 3-instance fleet takes a 6x slowdown on
        instance 1 plus an asymmetric partition of instance 2
        (``part@6:2|0/12``: beats lost, data held to heal, the zombie
        keeps stepping). The watchdog must race >= 1 stalled request on
        a live peer and >= 1 hedge must *win*; >= 1 zombie completion
        must be fenced (counted, never delivered); every winning stream
        must be bitwise-equal to a fault-free single-engine run with
        zero duplicate completions and the exactly-once audit green;
      * **sim latency** — a 3-instance ClusterSim over a 120-request
        sharegpt trace takes a 25x slowdown on instance 1 and then a
        partition of that same (still-slowed) instance. Hedging on must
        cut p99 JCT to <= ``P99_GATE`` of the hedging-off run — the
        tail-latency claim itself, gated on the deterministic backend
        where it is noise-free.
    """
    import numpy as np
    from repro.cluster import (DetectorConfig, EngineFleet, FaultInjector,
                               HedgeConfig, RecoveryConfig,
                               check_fleet_invariants, parse_chaos_spec)
    from repro.cluster.sim import ClusterSim
    from repro.configs import get_config
    from repro.core import predictor, traces
    from repro.core.costmodel import CostModel
    from repro.core.scheduler import SchedulerConfig, make_econoserve
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    P99_GATE = 0.92     # hedging must cut sim p99 JCT by >= 8%

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")

    def mk_reqs(n=8, seed_=23, lo=6, hi=14):
        rng = np.random.default_rng(seed + seed_)
        return [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(8, 24)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(lo, hi)),
                                  temperature=0.0))
            for _ in range(n)]

    out: Dict = {}

    # -- identity: hedging off is bitwise-free -------------------------- #
    t0 = time.perf_counter()
    plain = EngineFleet(cfg, n_instances=2, router="least-kvc", seed=seed,
                        max_batch=4, capacity=256, rl_accuracy=1.0,
                        detector=DetectorConfig())
    arrivals = [0.5 * i for i in range(8)]
    p_reqs = plain.run(mk_reqs(), arrivals=arrivals)
    p_sync = sum(sum(i.engine.sync_counts.values())
                 for i in plain.instances)

    off = EngineFleet(cfg, n_instances=2, router="least-kvc", seed=seed,
                      max_batch=4, capacity=256, rl_accuracy=1.0,
                      detector=DetectorConfig(),
                      hedge=HedgeConfig(enabled=False))
    o_reqs = off.run(mk_reqs(), arrivals=arrivals)
    o_sync = sum(sum(i.engine.sync_counts.values())
                 for i in off.instances)
    out["identity"] = {
        "tokens_equal_no_hedge_fleet":
            [g.output for g in o_reqs] == [g.output for g in p_reqs],
        "added_syncs": o_sync - p_sync,
        "hedge_counters": off.hedge.counters(),
        "seconds": round(time.perf_counter() - t0, 2)}

    # -- fleet chaos: straggler + partition, first-winner fencing ------- #
    t0 = time.perf_counter()
    scfg = SchedulerConfig(kvc_tokens=224, block_size=16, tfs=128,
                           max_model_len=128, max_batch_reqs=4)
    spec = "slow@2:1/40x6,part@6:2|0/12"
    fleet = EngineFleet(
        cfg, n_instances=3, router="least-kvc", seed=seed,
        max_batch=4, capacity=128, rl_accuracy=1.0, scheduler_cfg=scfg,
        faults=FaultInjector(schedule=parse_chaos_spec(spec, 3), seed=seed,
                             min_alive=1),
        recovery=RecoveryConfig(max_retries=4, backoff_base=1.0,
                                shed_retry=True),
        detector=DetectorConfig(), hedge=HedgeConfig())
    ref = ServingEngine(cfg, params=fleet.params, max_batch=4,
                        capacity=128, rl_accuracy=1.0, seed=seed,
                        scheduler_cfg=scfg)
    ref_reqs = mk_reqs(n=10, seed_=5, lo=8, hi=16)
    ref.run(ref_reqs)
    reqs = fleet.run(mk_reqs(n=10, seed_=5, lo=8, hi=16))
    cons = fleet.conservation()
    try:
        inv_ok = bool(check_fleet_invariants(fleet)["ok"])
    except AssertionError as e:
        inv_ok = False
        out["invariant_failure"] = str(e)
    hcnt = fleet.hedge.counters()
    out["chaos"] = {
        **cons, "invariants_ok": inv_ok, **hcnt,
        "fleet_fenced_completions": fleet.n_fenced_completions,
        "transport": {"partition_lost": fleet.transport.n_partition_lost,
                      "partition_held": fleet.transport.n_partition_held},
        "tokens_equal_no_fault_run":
            all(g.output == r.output for g, r in zip(reqs, ref_reqs)
                if g.status != "shed"),
        "seconds": round(time.perf_counter() - t0, 2)}

    # -- sim latency: hedging must buy back the chaos tail -------------- #
    t0 = time.perf_counter()

    def sim_trace():
        rs = traces.generate(traces.SHAREGPT, 120, seed=seed, rate=6.0)
        predictor.annotate(rs, predictor.NoisyPredictor(
            accuracy=0.75, seed=seed), 0.15)
        return rs

    def mk_sim(hedge):
        cost = CostModel()
        sc = SchedulerConfig()
        # instance 1 crawls at 25x, then gets partitioned while still
        # slowed: its fenced work is exactly what hedging must rescue
        sspec = "slow@5:1/30x25,part@15:1|0/15"
        return ClusterSim(
            lambda i: make_econoserve(sc, cost), cost, n_instances=3,
            router="least-kvc", seed=seed,
            faults=FaultInjector(schedule=parse_chaos_spec(sspec, 3),
                                 seed=seed, min_alive=1),
            recovery=RecoveryConfig(max_retries=4, backoff_base=1.0),
            detector=DetectorConfig(), hedge=hedge)

    def p99_jct(res):
        jct = sorted(r.t_complete - r.arrival for r in res.requests
                     if r.t_complete is not None)
        return jct[int(0.99 * (len(jct) - 1))] if jct else float("inf")

    s_off = mk_sim(None).run(sim_trace())
    # the fleet clock ticks in iterations; the sim clock in cost-model
    # time units — the stall floor must be rescaled to stay meaningful
    s_on = mk_sim(HedgeConfig(floor=0.5)).run(sim_trace())
    ratio = p99_jct(s_on) / p99_jct(s_off)
    out["sim"] = {
        "p99_jct_hedge_off": round(p99_jct(s_off), 2),
        "p99_jct_hedge_on": round(p99_jct(s_on), 2),
        "p99_ratio": round(ratio, 3),
        "p99_gate": P99_GATE,
        "conservation_off": s_off.conservation(),
        "conservation_on": s_on.conservation(),
        "hedges_fired": s_on.n_hedges_fired,
        "hedges_won": s_on.n_hedges_won,
        "fenced_completions": s_on.n_fenced_completions,
        "seconds": round(time.perf_counter() - t0, 2)}

    out["hedge_ok"] = bool(
        out["identity"]["tokens_equal_no_hedge_fleet"]
        and out["identity"]["added_syncs"] <= 0
        and sum(out["identity"]["hedge_counters"].values()) == 0
        and cons["ok"] and inv_ok
        and cons["dup_completions"] == 0
        and hcnt["hedges_fired"] >= 1 and hcnt["hedges_won"] >= 1
        and fleet.n_fenced_completions >= 1
        and out["chaos"]["tokens_equal_no_fault_run"]
        and s_off.conservation()["ok"] and s_on.conservation()["ok"]
        and s_on.conservation()["duplicate_completions"] == 0
        and s_on.n_hedges_won >= 1
        and s_on.n_fenced_completions >= 1
        and ratio <= P99_GATE)
    return out


def bench_swap(seed: int = 0) -> Dict:
    """Host-offload KV swap tier (counter-based, gated by --check):

      * a KVC-starved single engine must take the swap rung of the
        pressure ladder: >= 1 preempted request captured to the bounded
        host pool and restored by page re-seed (``n_swap_restores``, no
        recompute re-prefill for it), greedy streams bitwise-equal to a
        pressure-free run, and the swap ledger / image store empty when
        the run drains;
      * the tier must be free when idle: a pressure-free run with
        ``host_swap`` on performs exactly the same blocking syncs as one
        with it off — the capture sync is only ever paid on the
        preemption path, never in the no-swap steady state.
    """
    import numpy as np
    from repro.configs import get_config
    from repro.core.scheduler import SchedulerConfig
    from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                               ServingEngine)

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")

    def mk_reqs():
        rng = np.random.default_rng(seed + 3)
        return [GenRequest(
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     int(rng.integers(12, 28)))),
            params=SamplingParams(max_new_tokens=int(rng.integers(8, 20)),
                                  temperature=0.0))
            for _ in range(10)]

    def run(kvc_tokens, host_swap=True):
        scfg = SchedulerConfig(kvc_tokens=kvc_tokens, block_size=16,
                               tfs=128, max_model_len=128,
                               max_batch_reqs=4)
        eng = ServingEngine(cfg, max_batch=4, capacity=128,
                            scheduler_cfg=scfg, rl_accuracy=0.5,
                            seed=seed,
                            engine_cfg=EngineConfig(host_swap=host_swap))
        reqs = mk_reqs()
        eng.run(reqs)
        return eng, [tuple(g.output) for g in reqs]

    t0 = time.perf_counter()
    _, free_streams = run(6 * 128)              # pressure-free reference
    eng, out = run(160)                         # starved: swap rung fires
    s = eng.scheduler
    pressure = {
        "preempt_swaps": s.n_preempt_swap,
        "captures": eng.n_swap_captures,
        "restores": eng.n_swap_restores,
        "drops": eng.n_swap_drops,
        "rejects": eng.n_swap_rejects,
        "tokens_equal_pressure_free": out == free_streams,
        "ledger_empty": not s.kvc.swapped and not eng._host_swap
                        and not s.swap_hold,
    }
    # steady state: identical blocking-sync profile with the tier on/off
    on, out_on = run(6 * 128, host_swap=True)
    off, out_off = run(6 * 128, host_swap=False)
    steady = {
        "syncs_swap_on": dict(on.sync_counts),
        "syncs_swap_off": dict(off.sync_counts),
        "extra_syncs": sum(on.sync_counts.values())
                       - sum(off.sync_counts.values()),
        "tokens_equal": out_on == out_off,
    }
    return {
        "pressure": pressure, "steady": steady,
        "swap_ok": bool(
            pressure["restores"] >= 1
            and pressure["restores"] == pressure["captures"]
            and pressure["rejects"] == 0
            and pressure["tokens_equal_pressure_free"]
            and pressure["ledger_empty"]
            and steady["extra_syncs"] == 0 and steady["tokens_equal"]),
        "seconds": round(time.perf_counter() - t0, 2),
    }


def bench_metrics(decode_iters: int = 120, seed: int = 0) -> Dict:
    """Metrics plane is free (counter-based, gated by --check): the same
    online stream runs twice — metrics-off and with a per-iteration
    ``MetricsSampler`` attached — and three things must hold:

      * **bitwise identity** — the greedy token streams are equal: the
        sampler reads engine state, never influences control flow;
      * **zero added syncs** — total ``sync_counts`` are identical.
        Drain classification is enqueue-time deterministic (dispatch
        sequence numbers, PR 9), so totals compare exactly, not just in
        aggregate bands: a sampler that snuck in a fresh ``device_get``
        would show up as +1 here;
      * **bounded overhead** — the sampler's self-measured wall clock
        (``sample_time``, accumulated inside ``on_step``) stays under 5%
        of the steady decode-loop section it ran in. Self-measurement is
        robust on noisy shared runners where a paired A/B wall-clock
        comparison of two ~identical runs is not.

    The metrics-on registry must also export: Prometheus text that
    parses back and contains the headline families, and a frozen
    snapshot whose counters match the engine's own totals.
    """
    import numpy as np
    from repro.configs import get_config
    from repro.obs import (MetricsRegistry, MetricsSampler,
                           parse_prometheus_text, to_prometheus_text)
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")
    mb, warmup = 8, 10

    out: Dict = {}
    streams = {}
    reg = None
    eng_on = sampler_on = None
    for label in ("metrics_off", "metrics_on"):
        eng = ServingEngine(cfg, max_batch=mb, capacity=512,
                            rl_accuracy=1.0, seed=seed)
        sampler = None
        if label == "metrics_on":
            reg = MetricsRegistry()
            sampler = MetricsSampler(reg, instance="0").attach(eng)
            eng_on, sampler_on = eng, sampler
        rng = np.random.default_rng(seed)
        reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 16)),
                           params=SamplingParams(
                               max_new_tokens=decode_iters + warmup + 48))
                for _ in range(mb)]
        t = 0.0
        for g in reqs:
            eng.submit(g, t)
        for _ in range(warmup):                 # prefill + compile
            t += 1.0
            eng.step(t)
        base_sample_s = sampler.sample_time if sampler else 0.0
        t0 = time.perf_counter()
        for _ in range(decode_iters):           # steady decode section
            t += 1.0
            eng.step(t)
        wall = time.perf_counter() - t0
        while eng.has_work() and t < 5000:      # drain for token equality
            t += 1.0
            eng.step(t)
        eng.flush()
        streams[label] = [g.output for g in reqs]
        out[label] = {
            "decode_wall_s": round(wall, 4),
            "total_syncs": sum(eng.sync_counts.values()),
            "sync_counts": dict(eng.sync_counts),
        }
        if sampler is not None:
            sample_s = sampler.sample_time - base_sample_s
            out[label]["sampler_ticks"] = sampler.n_samples
            out[label]["sampler_seconds_in_section"] = round(sample_s, 5)
            out[label]["sampler_overhead_frac"] = round(sample_s / wall, 5)

    out["tokens_equal"] = streams["metrics_off"] == streams["metrics_on"]
    out["added_syncs"] = (out["metrics_on"]["total_syncs"]
                          - out["metrics_off"]["total_syncs"])

    sampler_on.on_step(eng_on, 0.0)    # final scrape: cover flush()
    snap = reg.snapshot()
    text = to_prometheus_text(snap)
    try:
        parsed = parse_prometheus_text(text)
        prom_ok = all(any(k.startswith(fam) for k in parsed) for fam in (
            "engine_kvc_occupied_blocks", "scheduler_queue_depth",
            "megastep_dispatch_amortization", "engine_host_syncs_total",
            "engine_blocking_syncs_total"))
    except ValueError as e:
        prom_ok = False
        out["prometheus_error"] = str(e)
    # registry counters must agree with the engine's own totals
    snap_syncs = sum(
        snap.get("engine_host_syncs_total", instance="0", kind=k) or 0
        for k in eng_on.sync_counts)
    out["prometheus_parses"] = prom_ok
    out["snapshot_syncs_match_engine"] = \
        snap_syncs == sum(eng_on.sync_counts.values())
    out["metrics_ok"] = bool(
        out["tokens_equal"] and out["added_syncs"] == 0
        and out["metrics_on"]["sampler_overhead_frac"] < 0.05
        and prom_ok and out["snapshot_syncs_match_engine"])
    return out


# --------------------------------------------------------------------- #
# 7. kernel: single- vs multi-page step time + DMA early-exit accounting
# --------------------------------------------------------------------- #
def bench_kernel(reps: int = 3) -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops

    B, H, K, hd, page, MP = 4, 8, 2, 64, 16, 8
    P = B * MP
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, K, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, K, hd), jnp.float32)
    bt = jnp.arange(P, dtype=jnp.int32).reshape(B, MP)
    cl = jnp.array([17, 40, 70, MP * page], jnp.int32)

    out = {}
    for label, pps in (("single_page", 1), ("multi_page_8", 8)):
        r = ops.paged_decode_attention(q, kp, vp, bt, cl,
                                       pages_per_step=pps)
        r.block_until_ready()              # compile outside the timing
        t0 = time.perf_counter()
        for _ in range(reps):
            ops.paged_decode_attention(q, kp, vp, bt, cl,
                                       pages_per_step=pps
                                       ).block_until_ready()
        out[label] = {"pages_per_step": pps,
                      "step_ms": round((time.perf_counter() - t0)
                                       / reps * 1e3, 2)}
    # DMA accounting: the old BlockSpec pipeline fetched B*K*MP page tiles;
    # the early-exit kernel fetches only in-context pages
    ctx_pages = int(np.sum(-(-np.asarray(cl) // page)))
    out["pages_dma_old"] = B * MP * K
    out["pages_dma_new"] = ctx_pages * K
    out["dma_saved_frac"] = round(1 - ctx_pages / (B * MP), 3)
    if jax.default_backend() != "tpu":
        out["note"] = ("step_ms is interpret-mode (python) time on this "
                       "backend — the DMA savings are the architectural "
                       "number; re-run on TPU for real step times")
    return out


def _quickref_measure() -> Dict:
    """The two relative speedups the CI guard anchors on, measured in the
    exact order ``check_regression`` measures them — the scheduler bench
    reads several× lower after the engine benches churn the process
    (thread state, allocator fragmentation), so the order is part of the
    measurement and reference and rerun must share it."""
    dl = bench_decode_loop(decode_iters=60)["speedup"]
    bench_decode_megastep(decode_iters=60)
    bench_chunked_prefill(plen=128, chunk_tfs=32)
    return {
        "form_batch_speedup": bench_form_batch(
            n_reqs=2_000, iters=15)["speedup"],
        # clamp freak-high regimes (healthy runs swing ~2-8x with host
        # thread scheduling): the gate this anchors only needs to separate
        # healthy (>1.5x worst-regime) from a reintroduced per-iteration
        # sync (~1x) — the megastep bench's counter-based blocking gate is
        # the primary detector for that anyway
        "decode_loop_speedup": round(min(dl, 4.0), 2),
    }


def _quickref_subprocess() -> Dict:
    """Measure the quick references in a fresh interpreter (how CI runs
    them); falls back to in-process on any spawn failure."""
    import subprocess
    import sys
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.hotpath_micro",
             "--quickref-json"],
            capture_output=True, text=True, timeout=600,
            env={**os.environ, "JAX_PLATFORMS": "cpu"},
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        return json.loads(proc.stdout.strip().splitlines()[-1])
    except Exception as e:                          # noqa: BLE001
        print(f"note: fresh-process quickref failed ({e}); "
              f"measuring in-process (biases the CI gate lenient)")
        return _quickref_measure()


def main(quick: bool = False, write: bool = True) -> Dict:
    n, iters = (2_000, 15) if quick else (10_000, 40)
    # the engine decode benches run first: they are the recorded headline
    # numbers and a fresh process is how users (and CI) invoke the bench;
    # the 10k-request scheduler bench churns enough Python objects /
    # thread state to perturb the engines' measured regime in-process
    results: Dict = {
        "bench": "hotpath_micro",
        "decode_loop": bench_decode_loop(decode_iters=60 if quick else 300),
        "decode_megastep": bench_decode_megastep(
            decode_iters=60 if quick else 240),
        "pressure_megastep": bench_pressure_megastep(
            measure_iters=40 if quick else 60),
        "packed_chunk": bench_packed_chunk(),
        "chunked_prefill": bench_chunked_prefill(
            plen=128 if quick else 256, chunk_tfs=32 if quick else 64),
        "form_batch": bench_form_batch(n_reqs=n, iters=iters),
        "prefill": bench_prefill_retraces(n=8 if quick else 24),
        "cluster": bench_cluster(n_reqs=8, sim_reqs=200 if quick else 400),
        "swap": bench_swap(),
        "metrics": bench_metrics(decode_iters=60 if quick else 120),
        "chaos": bench_chaos(n_reqs=8),
        "detector": bench_detector(),
        "hedge": bench_hedge(),
        "kernel": bench_kernel(reps=2 if quick else 3),
    }
    # speedups scale with problem size (a 10k-queue amplifies the
    # O(n)-vs-O(1) gap), so the CI guard compares against a reference at
    # its own quick parameters. In quick mode the main results already are
    # quick-parameterized; in full mode the references are measured last,
    # in the churned process — that biases them slightly LOW relative to
    # CI's fresh rerun, which only makes the guard more lenient (the safe
    # failure direction for a wall-clock gate on shared runners).
    if quick:
        results["quick_reference"] = {
            "form_batch_speedup": results["form_batch"]["speedup"],
            # same clamp as _quickref_measure (see there)
            "decode_loop_speedup": round(
                min(results["decode_loop"]["speedup"], 4.0), 2),
        }
    else:
        # CI's --check reruns the quick benches in a FRESH process, so the
        # committed references must be measured the same way: an in-process
        # measurement after the 10k-queue churn reads several× low (thread
        # state, allocator fragmentation), anchoring the gate too leniently
        results["quick_reference"] = _quickref_subprocess()
    # quick mode is a smoke run and must never clobber the committed
    # full-scale baseline (benchmarks.run invokes main(quick=True))
    if write and not quick:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=1)
    print(json.dumps(results, indent=1))
    return results


def check_regression(factor: float = 2.0,
                     decode_factor: float = 3.0) -> int:
    """CI guard. Reruns the scheduler + decode-loop + megastep + chunked
    benches at quick parameters (no JSON rewrite) and fails when:

      * the form_batch *relative* speedup (incremental vs legacy on the
        same machine, so absolute runner speed cancels out) regressed more
        than ``factor`` against the committed quick_reference;
      * the decode-loop relative speedup regressed more than
        ``decode_factor`` — a hard gate with a deliberately generous
        threshold: runner thread-scheduling swings runs ~1.5-3x, but a
        reintroduced per-iteration blocking sync costs far more;
      * a structural invariant broke: megastep must amortize dispatches
        (<= 0.5/iter in steady state, ~1/K expected) with zero blocking
        syncs, a long prompt must complete via >= 2 engine-executed
        chunks with tokens equal to the whole-prompt run, and the cluster
        layer must conserve requests (every routed request completes
        exactly once across instances; a migrated prefill→decode stream
        stays greedy-token-equal to a single engine), the host-offload
        swap tier must restore >= 1 page image without recompute while
        adding zero blocking syncs to the no-swap steady state, and the
        chaos battery (kill recovery, KV-corruption rejection, mid-run
        capacity squeeze) must stay green. These are counter-based and
        immune to wall-clock noise.
    """
    with open(OUT_PATH) as f:
        base = json.load(f)
    ref = base.get("quick_reference")
    res = {"decode_loop": bench_decode_loop(decode_iters=60),
           "decode_megastep": bench_decode_megastep(decode_iters=60),
           "pressure_megastep": bench_pressure_megastep(measure_iters=40),
           "packed_chunk": bench_packed_chunk(),
           "chunked_prefill": bench_chunked_prefill(plen=128, chunk_tfs=32)}
    res["cluster"] = bench_cluster(n_reqs=8, sim_reqs=200)
    res["form_batch"] = bench_form_batch(n_reqs=2_000, iters=15)
    res["swap"] = bench_swap()
    res["metrics"] = bench_metrics(decode_iters=60)
    # chaos runs LAST: it spins up several fleets of engines, and that
    # churn collapses the scheduler bench's measured regime (the
    # quick_reference order must stay a prefix of this rerun's order)
    res["chaos"] = bench_chaos(n_reqs=8)
    res["detector"] = bench_detector()
    res["hedge"] = bench_hedge()
    print(json.dumps(res, indent=1))
    failures = []
    if ref is None:
        # full-scale speedups are not comparable to a quick rerun (the
        # 10k queue amplifies the O(n)-vs-O(1) gap), so a baseline without
        # the quick_reference section cannot anchor the relative guard
        print("note: baseline has no quick_reference — speedup comparison "
              "skipped; refresh BENCH_hotpath.json to restore it")
    else:
        want = ref["form_batch_speedup"] / factor
        got = res["form_batch"]["speedup"]
        if got < want:
            failures.append(f"form_batch: speedup {got} < baseline/"
                            f"{factor} = {want:.2f}")
        want_dl = ref["decode_loop_speedup"] / decode_factor
        got_dl = res["decode_loop"]["speedup"]
        if got_dl < want_dl:
            failures.append(f"decode_loop: speedup {got_dl} < baseline/"
                            f"{decode_factor} = {want_dl:.2f}")
    # structural gates: counter-based, stable on any runner
    dpi = res["decode_megastep"]["megastep_8"]["dispatches_per_iter"]
    if dpi > 0.5:
        failures.append(f"decode_megastep: {dpi} dispatches/iter "
                        f"(expected ~{1 / 8:.3f}, gate 0.5) — windows "
                        f"not fusing")
    mega_blocking = res["decode_megastep"]["megastep_8"][
        "blocking_syncs_per_iter"]
    if mega_blocking > 0.05:
        failures.append(f"decode_megastep: {mega_blocking} blocking "
                        f"syncs/iter in steady state (expected 0)")
    # pressure megastep: fused windows under a KVC-saturated workload
    # whose queues stay non-empty throughout (pre-PR5 this ran at ~1
    # dispatch/iteration), tokens equal to the per-iteration path
    pm = res["pressure_megastep"]
    if not pm["queues_nonempty_throughout"]:
        failures.append("pressure_megastep: workload lost pressure (a "
                        "queue drained during the measured window) — the "
                        "gate no longer tests the saturated regime")
    pdpi = pm["megastep_8"]["dispatches_per_iter"]
    if pdpi > 0.5:
        failures.append(f"pressure_megastep: {pdpi} dispatches/iter under "
                        f"KVC pressure (expected ~{1 / 8:.3f}, gate 0.5) "
                        f"— windows collapsing when queues are non-empty")
    if pm["dispatch_amortization"] < 4.0:
        failures.append(f"pressure_megastep: amortization "
                        f"{pm['dispatch_amortization']}x < 4x under "
                        f"KVC pressure")
    if not pm["tokens_equal"]:
        failures.append("pressure_megastep: token streams diverged from "
                        "the per-iteration path")
    # packed chunk prefill: a >= 2-chunked-request wave must run as ONE
    # dispatch with tokens equal to the per-chunk-call reference
    pc = res["packed_chunk"]
    if not pc["wave_packed"]:
        failures.append("packed_chunk: no multi-request chunk wave ran as "
                        "a single dispatch (max items/dispatch "
                        f"{pc['packed']['max_chunk_items_per_dispatch']})")
    if pc["dispatches_saved"] < 1:
        failures.append("packed_chunk: packing saved no dispatches vs the "
                        "per-chunk-call path")
    if not pc["tokens_equal"]:
        failures.append("packed_chunk: token streams diverged from the "
                        "per-chunk-call path")
    ck = res["chunked_prefill"]
    chunk_key = next(k for k in ck if k.startswith("chunked_"))
    if ck[chunk_key]["n_chunks"] < 2:
        failures.append(f"chunked_prefill: long prompt ran in "
                        f"{ck[chunk_key]['n_chunks']} chunks (expected "
                        f">= 2)")
    if not ck["tokens_equal"]:
        failures.append("chunked_prefill: token streams diverged from the "
                        "whole-prompt run")
    cl = res["cluster"]
    if not cl["conservation_ok"]:
        failures.append(f"cluster: conservation gate failed — every routed "
                        f"request must complete exactly once "
                        f"(fleet={cl['fleet_2x']}, "
                        f"disagg={cl['fleet_disagg']}, sim={cl['sim_3x']})")
    if not cl["fleet_disagg"]["tokens_equal_single_engine"]:
        failures.append("cluster: migrated (prefill→decode) token streams "
                        "diverged from the single-engine run")
    if cl["fleet_disagg"]["migrations"] < 1:
        failures.append("cluster: disaggregated fleet performed no KV "
                        "migrations")
    # chaos battery: a mid-run instance kill must be fully absorbed —
    # exactly-once terminal states, >= 1 recovery, zero leaks, and token
    # streams bitwise-equal to a fault-free run; a corrupted KV payload
    # must be checksum-rejected and degrade to recompute without
    # poisoning the stream. Hard gates, counter-based.
    ch = res["chaos"]
    if not ch["chaos_ok"]:
        failures.append(f"chaos: fault-tolerance gate failed — "
                        f"kill_recovery={ch['kill_recovery']}, "
                        f"corrupt_kv={ch['corrupt_kv']}, "
                        f"squeeze={ch['squeeze']}")
    # detector battery: detector-on fault-free must be bitwise-identical
    # to the plain fleet with zero added blocking syncs; under beat-drop
    # + silent-kill + squeeze chaos, a false suspect must be reinstated,
    # the kill detected from missed beats alone, >= 1 rung-4 shed
    # rescued by fleet re-route, and exactly-once delivery must hold
    dt = res["detector"]
    if not dt["detector_ok"]:
        failures.append(f"detector: detected-failure gate failed — "
                        f"identity={dt['identity']}, "
                        f"chaos={dt['chaos']}")
    # hedge battery: hedging off must be bitwise-free; under straggler +
    # partition chaos >= 1 hedge must fire AND win with >= 1 zombie
    # completion fenced, winning streams bitwise-equal to fault-free,
    # zero duplicate deliveries, and the sim p99-JCT tail must shrink by
    # the hard-gated margin when hedging turns on
    hd = res["hedge"]
    if not hd["hedge_ok"]:
        failures.append(f"hedge: hedged-execution gate failed — "
                        f"identity={hd['identity']}, "
                        f"chaos={hd['chaos']}, "
                        f"sim={hd['sim']}")
    # swap tier: >= 1 host-pool capture restored by page re-seed (no
    # recompute), streams bitwise-equal under pressure, ledger drained,
    # and ZERO blocking syncs added to the no-swap steady state
    sw = res["swap"]
    if not sw["swap_ok"]:
        failures.append(f"swap: host-offload KV swap gate failed — "
                        f"pressure={sw['pressure']}, "
                        f"steady={sw['steady']}")
    # metrics plane: metrics-on must be bitwise-identical to metrics-off
    # (token streams AND total sync counts — zero added blocking syncs),
    # sampler overhead < 5% of the decode-loop section, and the registry
    # must export parseable Prometheus text whose counters match the
    # engine's own totals. Hard gates, counter-based.
    mt = res["metrics"]
    if not mt["metrics_ok"]:
        failures.append(
            f"metrics: zero-overhead sampler gate failed — "
            f"tokens_equal={mt['tokens_equal']}, "
            f"added_syncs={mt['added_syncs']}, "
            f"overhead={mt['metrics_on']['sampler_overhead_frac']}, "
            f"prometheus_parses={mt['prometheus_parses']}, "
            f"snapshot_syncs_match_engine="
            f"{mt['snapshot_syncs_match_engine']}")
    blocking = res["decode_loop"]["async_device"]["blocking_syncs_per_iter"]
    if blocking > 0.05:
        # warn-only: blocking drains also happen when a slow/loaded runner
        # makes device compute outpace host dispatch (the ring tops out at
        # max_pending), which is machine load, not a code regression — a
        # *reintroduced* per-iteration host sync fails the decode_loop
        # speedup gate above
        print(f"warning: async decode loop blocked on the host "
              f"({blocking} syncs/iter, expected ~0 on an idle machine)")
    if failures:
        print("REGRESSION GUARD FAILED:\n  " + "\n  ".join(failures))
        return 1
    print("regression guard OK: "
          f"form_batch {res['form_batch']['speedup']}x, "
          f"decode_loop {res['decode_loop']['speedup']}x, "
          f"megastep {res['decode_megastep']['dispatch_amortization']}x "
          f"dispatch amortization "
          f"({res['pressure_megastep']['dispatch_amortization']}x under "
          f"KVC pressure), packed chunk wave saved "
          f"{res['packed_chunk']['dispatches_saved']} dispatches, chunked "
          f"TTFT bounded, cluster conservation + migration equality hold, "
          f"swap tier restored {res['swap']['pressure']['restores']} "
          f"host images sync-free, metrics sampler bitwise-free "
          f"({res['metrics']['metrics_on']['sampler_overhead_frac']:.1%} "
          f"of the decode loop, 0 added syncs), chaos battery (kill "
          f"recovery + "
          f"KV-corruption rejection + squeeze absorption) green, "
          f"detector battery (bitwise identity + false-suspect "
          f"reinstatement + {res['detector']['chaos']['shed_rescued']} "
          f"shed rescues) green, hedge battery "
          f"({res['hedge']['chaos']['hedges_won']} fleet hedge wins, "
          f"{res['hedge']['sim']['fenced_completions']} sim fenced, sim "
          f"p99 JCT ratio {res['hedge']['sim']['p99_ratio']}) green "
          f"(quick baselines: {ref})")
    return 0


if __name__ == "__main__":
    import sys
    if "--quickref-json" in sys.argv:
        # internal: fresh-process quick-reference measurement for main()
        print(json.dumps(_quickref_measure()))
        sys.exit(0)
    if "--check" in sys.argv:
        sys.exit(check_regression())
    quick = "--quick" in sys.argv
    # quick mode is a smoke run: never clobber the committed full-scale
    # baseline the CI regression guard anchors against
    main(quick=quick, write=not quick)
