"""Hot-path microbenchmarks: scheduler form_batch throughput (legacy full
re-sort vs incremental OrderedQueue with O(1) removal), steady-state
decode-loop throughput (legacy host-synced vs fused async device-resident)
with host-blocking-sync counts per iteration, engine prefill retrace count
under token packing, and paged-attention kernel step time single- vs
multi-page.

Emits before/after numbers to ``BENCH_hotpath.json`` at the repo root —
the baseline the acceptance criteria check against:

  * >= 5x form_batch ops/sec on a 10k-request synthetic trace,
  * >= 2x steady-state decode iterations/s at full batch, with zero
    blocking host syncs per steady-state async iteration,
  * <= ceil(log2(max_total_prompt_tokens)) distinct prefill compilations.

Run:  PYTHONPATH=src python -m benchmarks.hotpath_micro [--quick]
      (--quick is a smoke run and does NOT rewrite BENCH_hotpath.json;
      only full runs refresh the committed baseline)
CI:   PYTHONPATH=src python -m benchmarks.hotpath_micro --check
      (quick mode, no JSON rewrite; exits 1 when the scheduler microbench
      regresses >2x against the committed baseline's relative speedup)
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict

from repro.core import predictor, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_hotpath.json")


# --------------------------------------------------------------------- #
# 1. scheduler form_batch throughput
# --------------------------------------------------------------------- #
def bench_form_batch(n_reqs: int = 10_000, iters: int = 40,
                     seed: int = 0) -> Dict:
    """All requests arrive at t=0 (a worst-case standing queue): time
    form_batch+finish_iteration cycles with both queue implementations."""
    out = {}
    for label, incremental in (("legacy_sort", False),
                               ("incremental", True)):
        reqs = traces.generate(traces.SHAREGPT, n_reqs, seed=seed, rate=1e9)
        predictor.annotate(reqs, predictor.NoisyPredictor(seed=seed), 0.15)
        cfg = dataclasses.replace(SchedulerConfig(),
                                  incremental_queues=incremental)
        cost = CostModel()
        sched = make_econoserve(cfg, cost, "full")
        for r in reqs:
            sched.on_arrival(r, 0.0)
        t = 0.0
        t0 = time.perf_counter()
        done = 0
        for _ in range(iters):
            plan = sched.form_batch(t)
            if plan.empty:
                break
            t += plan.sched_time + plan.extra_time + 0.05
            sched.finish_iteration(t)
            done += 1
        dt = time.perf_counter() - t0
        out[label] = {"iters": done, "seconds": round(dt, 4),
                      "form_batch_per_s": round(done / dt, 2)}
    out["speedup"] = round(out["incremental"]["form_batch_per_s"]
                           / out["legacy_sort"]["form_batch_per_s"], 2)
    return out


# --------------------------------------------------------------------- #
# 2. steady-state decode loop: legacy host-synced vs fused async
# --------------------------------------------------------------------- #
def bench_decode_loop(decode_iters: int = 300, seed: int = 0) -> Dict:
    """Full-batch steady-state decode (no admissions, no completions inside
    the timed window): iterations/s plus blocking host syncs per iteration.
    The legacy path materializes every iteration's sampled batch and then
    reads tokens per request; the async path carries state on device and
    drains tokens with a readback lag, so its steady-state blocking-sync
    count is zero."""
    import numpy as np
    from repro.configs import get_config
    from repro.serving import (EngineConfig, GenRequest, SamplingParams,
                               ServingEngine)

    # deliberately tiny model: the quantity under test is the *per-
    # iteration host overhead* (dispatches, transfers, readbacks), which
    # this PR removes — a large model would bury it under compute that is
    # identical on both paths
    cfg = get_config("qwen3_8b").reduced(layers=1).with_(
        d_model=64, num_heads=2, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=256, dtype="float32", param_dtype="float32")
    # batch 16 is "full batch" here: big enough that the sync path's O(B)
    # per-iteration host work (the per-request int() reads this PR removes)
    # is visible, small enough that the tiny model still fits the 2-core
    # CI-class containers without saturating them
    mb, warmup, n_windows = 16, 8, 5
    # each path gets its own engine measured alone (as it runs in
    # production — back-to-back alternation lets the async path's constant
    # device activity keep the XLA threadpool spinning through the sync
    # path's blocking waits, flattering the sync number). The median over
    # N windows discards thread-handoff spike and stall windows alike;
    # regimes persist for seconds on small shared boxes, so individual
    # runs still swing — compare medians across fresh processes.
    per_window = max(1, decode_iters // n_windows)
    out = {}
    for label, ecfg in (
            ("sync_legacy", EngineConfig(async_decode=False,
                                         packed_prefill=False)),
            ("async_device", EngineConfig(async_decode=True,
                                          packed_prefill=True))):
        eng = ServingEngine(cfg, max_batch=mb, capacity=512,
                            rl_accuracy=1.0, seed=seed, engine_cfg=ecfg)
        rng = np.random.default_rng(seed)
        reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, 16)),
                           params=SamplingParams(
                               max_new_tokens=decode_iters + warmup + 64))
                for _ in range(mb)]
        t = 0.0
        for g in reqs:
            eng.submit(g, t)
        for _ in range(warmup):                 # prefill + compile
            t += 1.0
            eng.step(t)
        base_iters = eng.decode_iters
        base_counts = dict(eng.sync_counts)
        rates, total_s = [], 0.0
        for _ in range(n_windows):
            t0 = time.perf_counter()
            for _ in range(per_window):
                t += 1.0
                eng.step(t)
            dt = time.perf_counter() - t0
            total_s += dt
            rates.append(per_window / dt)
        n = eng.decode_iters - base_iters
        window = {k: eng.sync_counts[k] - base_counts[k]
                  for k in eng.sync_counts}
        blocking = window["eos_flags"] + window["drain_blocking"]
        rates.sort()
        out[label] = {
            "iters": n, "seconds": round(total_s, 4),
            "iters_per_s": round(rates[len(rates) // 2], 1),
            "blocking_syncs_per_iter": round(blocking / n, 4),
            "host_sync_counts": window,
        }
    out["speedup"] = round(out["async_device"]["iters_per_s"]
                           / out["sync_legacy"]["iters_per_s"], 2)
    return out


# --------------------------------------------------------------------- #
# 3. engine prefill retraces under token packing
# --------------------------------------------------------------------- #
def bench_prefill_retraces(n: int = 24, seed: int = 0) -> Dict:
    import numpy as np
    from repro.configs import get_config
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    max_batch = 4
    eng = ServingEngine(cfg, max_batch=max_batch, capacity=256,
                        rl_accuracy=1.0, seed=seed)
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 120, n)          # many distinct prompt lengths
    reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, L)),
                       params=SamplingParams(max_new_tokens=4))
            for L in lens]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    max_prompt = int(lens.max())
    # token-packed prefill flattens a wave of <= max_batch prompts into one
    # (1, T) call, so the bucket axis is total wave tokens, not row length
    bound = max(1, math.ceil(math.log2(max_batch * max_prompt)))
    return {"n_requests": n, "distinct_prompt_lens": int(len(set(lens))),
            "max_prompt": max_prompt,
            "prefill_compiles": eng.n_prefill_compiles,
            "prefill_shapes": sorted(eng._prefill_shapes),
            "bound_log2_max_wave_tokens": bound,
            "within_bound": eng.n_prefill_compiles <= bound,
            "run_seconds": round(dt, 2),
            "note": "pre-refactor engine retraced once per distinct "
                    "prompt length; packed prefill pads no batch rows — "
                    "shapes are (1, pow2_total_tokens)"}


# --------------------------------------------------------------------- #
# 4. kernel: single- vs multi-page step time + DMA early-exit accounting
# --------------------------------------------------------------------- #
def bench_kernel(reps: int = 3) -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops

    B, H, K, hd, page, MP = 4, 8, 2, 64, 16, 8
    P = B * MP
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, K, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, K, hd), jnp.float32)
    bt = jnp.arange(P, dtype=jnp.int32).reshape(B, MP)
    cl = jnp.array([17, 40, 70, MP * page], jnp.int32)

    out = {}
    for label, pps in (("single_page", 1), ("multi_page_8", 8)):
        r = ops.paged_decode_attention(q, kp, vp, bt, cl,
                                       pages_per_step=pps)
        r.block_until_ready()              # compile outside the timing
        t0 = time.perf_counter()
        for _ in range(reps):
            ops.paged_decode_attention(q, kp, vp, bt, cl,
                                       pages_per_step=pps
                                       ).block_until_ready()
        out[label] = {"pages_per_step": pps,
                      "step_ms": round((time.perf_counter() - t0)
                                       / reps * 1e3, 2)}
    # DMA accounting: the old BlockSpec pipeline fetched B*K*MP page tiles;
    # the early-exit kernel fetches only in-context pages
    ctx_pages = int(np.sum(-(-np.asarray(cl) // page)))
    out["pages_dma_old"] = B * MP * K
    out["pages_dma_new"] = ctx_pages * K
    out["dma_saved_frac"] = round(1 - ctx_pages / (B * MP), 3)
    if jax.default_backend() != "tpu":
        out["note"] = ("step_ms is interpret-mode (python) time on this "
                       "backend — the DMA savings are the architectural "
                       "number; re-run on TPU for real step times")
    return out


def main(quick: bool = False, write: bool = True) -> Dict:
    n, iters = (2_000, 15) if quick else (10_000, 40)
    # the engine decode bench runs first: it is the recorded headline
    # number and a fresh process is how users (and CI) invoke the bench;
    # the 10k-request scheduler bench churns enough Python objects /
    # thread state to perturb the engines' measured regime in-process
    results: Dict = {
        "bench": "hotpath_micro",
        "decode_loop": bench_decode_loop(decode_iters=60 if quick else 300),
        "form_batch": bench_form_batch(n_reqs=n, iters=iters),
        "prefill": bench_prefill_retraces(n=8 if quick else 24),
        "kernel": bench_kernel(reps=2 if quick else 3),
    }
    # speedups scale with problem size (a 10k-queue amplifies the
    # O(n)-vs-O(1) gap), so the CI guard compares against a reference at
    # its own quick parameters. In quick mode the main results already are
    # quick-parameterized; in full mode the references are measured last,
    # in the churned process — that biases them slightly LOW relative to
    # CI's fresh rerun, which only makes the guard more lenient (the safe
    # failure direction for a wall-clock gate on shared runners).
    if quick:
        results["quick_reference"] = {
            "form_batch_speedup": results["form_batch"]["speedup"],
            "decode_loop_speedup": results["decode_loop"]["speedup"],
        }
    else:
        dl = bench_decode_loop(decode_iters=60)["speedup"]
        results["quick_reference"] = {
            "form_batch_speedup": bench_form_batch(
                n_reqs=2_000, iters=15)["speedup"],
            "decode_loop_speedup": dl,
        }
    if write:
        with open(OUT_PATH, "w") as f:
            json.dump(results, f, indent=1)
    print(json.dumps(results, indent=1))
    return results


def check_regression(factor: float = 2.0) -> int:
    """CI wall-clock guard. Reruns just the scheduler and decode-loop
    benches at quick parameters (no JSON rewrite) and fails when the
    *relative* speedup — incremental vs legacy on the same machine, so
    absolute CI-runner speed cancels out — has regressed more than
    ``factor`` against the committed baseline's quick_reference."""
    with open(OUT_PATH) as f:
        base = json.load(f)
    ref = base.get("quick_reference")
    res = {"decode_loop": bench_decode_loop(decode_iters=60)}
    res["form_batch"] = bench_form_batch(n_reqs=2_000, iters=15)
    print(json.dumps(res, indent=1))
    failures = []
    if ref is None:
        # full-scale speedups are not comparable to a quick rerun (the
        # 10k queue amplifies the O(n)-vs-O(1) gap), so a baseline without
        # the quick_reference section cannot anchor the relative guard
        print("note: baseline has no quick_reference — speedup comparison "
              "skipped; refresh BENCH_hotpath.json to restore it")
    else:
        # only the scheduler microbench gates hard: it is pure Python and
        # stable on shared runners. The engine decode loop depends on how
        # the host OS schedules the XLA threadpool, so it warns instead of
        # failing (a reintroduced per-iteration sync would also show up in
        # local full-bench refreshes).
        want = ref["form_batch_speedup"] / factor
        got = res["form_batch"]["speedup"]
        if got < want:
            failures.append(f"form_batch: speedup {got} < baseline/"
                            f"{factor} = {want:.2f}")
        want_dl = ref["decode_loop_speedup"] / factor
        got_dl = res["decode_loop"]["speedup"]
        if got_dl < want_dl:
            print(f"warning: decode_loop speedup {got_dl} < quick baseline/"
                  f"{factor} = {want_dl:.2f} (not gating; likely runner "
                  f"scheduling noise)")
    blocking = res["decode_loop"]["async_device"]["blocking_syncs_per_iter"]
    if blocking > 0.05:
        # warn-only: blocking drains also happen when a slow/loaded runner
        # makes device compute outpace host dispatch (the ring tops out at
        # max_pending), which is machine load, not a code regression — a
        # *reintroduced* per-iteration host sync shows up as a decode_loop
        # speedup regression above and fails there
        print(f"warning: async decode loop blocked on the host "
              f"({blocking} syncs/iter, expected ~0 on an idle machine)")
    if failures:
        print("REGRESSION GUARD FAILED:\n  " + "\n  ".join(failures))
        return 1
    print("regression guard OK: "
          f"form_batch {res['form_batch']['speedup']}x, "
          f"decode_loop {res['decode_loop']['speedup']}x "
          f"(quick baselines: {ref})")
    return 0


if __name__ == "__main__":
    import sys
    if "--check" in sys.argv:
        sys.exit(check_regression())
    quick = "--quick" in sys.argv
    # quick mode is a smoke run: never clobber the committed full-scale
    # baseline the CI regression guard anchors against
    main(quick=quick, write=not quick)
