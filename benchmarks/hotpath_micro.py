"""Hot-path microbenchmarks: scheduler form_batch throughput (legacy full
re-sort vs incremental OrderedQueue), engine prefill retrace count under
bucketing, and paged-attention kernel step time single- vs multi-page.

Emits before/after numbers to ``BENCH_hotpath.json`` at the repo root —
the baseline the acceptance criteria check against:

  * >= 5x form_batch ops/sec on a 10k-request synthetic trace,
  * <= ceil(log2(max_prompt)) distinct prefill compilations per run.

Run:  PYTHONPATH=src python -m benchmarks.hotpath_micro [--quick]
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict

from repro.core import predictor, traces
from repro.core.costmodel import CostModel
from repro.core.scheduler import SchedulerConfig, make_econoserve

OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_hotpath.json")


# --------------------------------------------------------------------- #
# 1. scheduler form_batch throughput
# --------------------------------------------------------------------- #
def bench_form_batch(n_reqs: int = 10_000, iters: int = 40,
                     seed: int = 0) -> Dict:
    """All requests arrive at t=0 (a worst-case standing queue): time
    form_batch+finish_iteration cycles with both queue implementations."""
    out = {}
    for label, incremental in (("legacy_sort", False),
                               ("incremental", True)):
        reqs = traces.generate(traces.SHAREGPT, n_reqs, seed=seed, rate=1e9)
        predictor.annotate(reqs, predictor.NoisyPredictor(seed=seed), 0.15)
        cfg = dataclasses.replace(SchedulerConfig(),
                                  incremental_queues=incremental)
        cost = CostModel()
        sched = make_econoserve(cfg, cost, "full")
        for r in reqs:
            sched.on_arrival(r, 0.0)
        t = 0.0
        t0 = time.perf_counter()
        done = 0
        for _ in range(iters):
            plan = sched.form_batch(t)
            if plan.empty:
                break
            t += plan.sched_time + plan.extra_time + 0.05
            sched.finish_iteration(t)
            done += 1
        dt = time.perf_counter() - t0
        out[label] = {"iters": done, "seconds": round(dt, 4),
                      "form_batch_per_s": round(done / dt, 2)}
    out["speedup"] = round(out["incremental"]["form_batch_per_s"]
                           / out["legacy_sort"]["form_batch_per_s"], 2)
    return out


# --------------------------------------------------------------------- #
# 2. engine prefill retraces under length bucketing
# --------------------------------------------------------------------- #
def bench_prefill_retraces(n: int = 24, seed: int = 0) -> Dict:
    import numpy as np
    from repro.configs import get_config
    from repro.serving import GenRequest, SamplingParams, ServingEngine

    cfg = get_config("qwen3_8b").reduced().with_(dtype="float32",
                                                 param_dtype="float32")
    eng = ServingEngine(cfg, max_batch=4, capacity=256, rl_accuracy=1.0,
                        seed=seed)
    rng = np.random.default_rng(seed)
    lens = rng.integers(4, 120, n)          # many distinct prompt lengths
    reqs = [GenRequest(prompt=list(rng.integers(0, cfg.vocab_size, L)),
                       params=SamplingParams(max_new_tokens=4))
            for L in lens]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    max_prompt = int(lens.max())
    bound = max(1, math.ceil(math.log2(max_prompt)))
    return {"n_requests": n, "distinct_prompt_lens": int(len(set(lens))),
            "max_prompt": max_prompt,
            "prefill_compiles": eng.n_prefill_compiles,
            "bound_log2_max_prompt": bound,
            "within_bound": eng.n_prefill_compiles <= bound,
            "run_seconds": round(dt, 2),
            "note": "pre-refactor engine retraced once per distinct "
                    "prompt length (= distinct_prompt_lens compiles)"}


# --------------------------------------------------------------------- #
# 3. kernel: single- vs multi-page step time + DMA early-exit accounting
# --------------------------------------------------------------------- #
def bench_kernel(reps: int = 3) -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops

    B, H, K, hd, page, MP = 4, 8, 2, 64, 16, 8
    P = B * MP
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (P, page, K, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (P, page, K, hd), jnp.float32)
    bt = jnp.arange(P, dtype=jnp.int32).reshape(B, MP)
    cl = jnp.array([17, 40, 70, MP * page], jnp.int32)

    out = {}
    for label, pps in (("single_page", 1), ("multi_page_8", 8)):
        r = ops.paged_decode_attention(q, kp, vp, bt, cl,
                                       pages_per_step=pps)
        r.block_until_ready()              # compile outside the timing
        t0 = time.perf_counter()
        for _ in range(reps):
            ops.paged_decode_attention(q, kp, vp, bt, cl,
                                       pages_per_step=pps
                                       ).block_until_ready()
        out[label] = {"pages_per_step": pps,
                      "step_ms": round((time.perf_counter() - t0)
                                       / reps * 1e3, 2)}
    # DMA accounting: the old BlockSpec pipeline fetched B*K*MP page tiles;
    # the early-exit kernel fetches only in-context pages
    ctx_pages = int(np.sum(-(-np.asarray(cl) // page)))
    out["pages_dma_old"] = B * MP * K
    out["pages_dma_new"] = ctx_pages * K
    out["dma_saved_frac"] = round(1 - ctx_pages / (B * MP), 3)
    if jax.default_backend() != "tpu":
        out["note"] = ("step_ms is interpret-mode (python) time on this "
                       "backend — the DMA savings are the architectural "
                       "number; re-run on TPU for real step times")
    return out


def main(quick: bool = False) -> Dict:
    n, iters = (2_000, 15) if quick else (10_000, 40)
    results = {
        "bench": "hotpath_micro",
        "form_batch": bench_form_batch(n_reqs=n, iters=iters),
        "prefill": bench_prefill_retraces(n=8 if quick else 24),
        "kernel": bench_kernel(reps=2 if quick else 3),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=1)
    print(json.dumps(results, indent=1))
    return results


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
