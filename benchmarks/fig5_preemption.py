"""Figure 5b: under-provision handling strategies — offload-based vs
offload-free preemption vs reserved-KVC rescue: preemption-time share of
JCT for the affected requests (O4)."""
from __future__ import annotations

import numpy as np

from .common import Emitter, TRACE_RATES, run, sched_config


def main(quick: bool = True) -> None:
    em = Emitter("fig5_preemption")
    n = 200 if quick else 600
    tr = "sharegpt"
    variants = [
        ("offload", dict(offload_free=False, reserve_frac=0.0)),
        ("offload_free", dict(offload_free=True, reserve_frac=0.0)),
        ("reserved_kvc", dict(offload_free=True, reserve_frac=0.05)),
    ]
    for name, kw in variants:
        cfg = sched_config(tr, **kw)
        res = run("econoserve", tr, n, TRACE_RATES[tr][0], cfg=cfg)
        affected = [r for r in res.completed
                    if r.n_preemptions > 0 or r.swap_time > 0]
        if affected:
            share = float(np.mean([
                (r.preempt_time + r.swap_time) / max(1e-9, r.jct)
                for r in affected]))
        else:
            share = 0.0
        em.row(strategy=name,
               preempt_share_of_jct=share,
               n_affected=float(len(affected)),
               reserve_rescues=float(res.n_reserve_rescues),
               jct=res.mean_jct)
    em.finish()


if __name__ == "__main__":
    main()
