"""Roofline report (deliverable g): reads the dry-run JSONs and derives the
three terms per (arch x shape), the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs, and a one-line what-would-move-it-down note.

Run the dry-run first:  python -m repro.launch.dryrun --all
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.core.costmodel import ModelProfile
from repro.launch.shapes import SHAPES

from .common import Emitter

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN", "experiments/dryrun")

HINTS = {
    "compute": "raise per-chip work only via batch; already MXU-bound",
    "memory": "cut HBM traffic: fuse cache read/update, avoid fp32 "
              "spills, larger effective arithmetic intensity per token",
    "collective": "reshard to remove all-gathers (sequence-parallel "
                  "residuals / expert-parallel dispatch), overlap with "
                  "compute",
}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    prof = ModelProfile.from_config(cfg)
    sh = SHAPES[shape_name]
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * prof.n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * prof.n_active * tokens
    return 2.0 * prof.n_active * sh.global_batch      # decode: 1 token/req


def main(quick: bool = True) -> None:
    em = Emitter("roofline")
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        print("roofline,status=no_dryrun_artifacts,count,0")
        em.finish()
        return
    for fn in files:
        rec = json.load(open(fn))
        if rec.get("status") != "ok":
            em.row(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                   status=rec.get("status", "?"), note=rec.get("reason", ""))
            continue
        rl = rec["roofline"]
        chips = rec["chips"]
        mf = model_flops(rec["arch"], rec["shape"])
        # compiled (analytic-calibrated) global flops implied by the term
        compiled_global = float(rl["compute_s"]) * chips * 197e12
        em.row(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
               compute_s=float(rl["compute_s"]),
               memory_s=float(rl["memory_s"]),
               collective_s=float(rl["collective_s"]),
               bottleneck=rec["bottleneck"],
               model_flops_ratio=float(mf / max(1.0, compiled_global)),
               mem_per_device_gib=float(rec.get("mem_per_device", 0))
               / 2 ** 30,
               hint=HINTS[rec["bottleneck"]])
    em.finish()


if __name__ == "__main__":
    main()
