"""Figures 9/10/11: normalized latency, SSR, and KVC/GPU utilization vs
request rate, per scheduler — steady-state (pre-drain) metrics. The paper's
headline '2.5-4x sustainable rate vs vLLM at the same latency' is read off
this sweep."""
from __future__ import annotations

from .common import Emitter, TRACE_RATES, make_trace, run, steady_metrics

SCHEDS = ["orca", "vllm", "sarathi", "distserve", "econoserve", "oracle"]


def main(quick: bool = True) -> None:
    em = Emitter("fig9_rate_sweep")
    n = 300 if quick else 800
    scheds = ["vllm", "sarathi", "econoserve"] if quick else SCHEDS
    traces_ = ["sharegpt"] if quick else ["alpaca", "sharegpt", "bookcorpus"]
    for tr in traces_:
        for rate in TRACE_RATES[tr]:
            reqs = make_trace(tr, n, rate)
            t_end = max(r.arrival for r in reqs)
            for sched in scheds:
                res = run(sched, tr, n, rate)
                sm = steady_metrics(res, t_end)
                s = res.summary()
                em.row(trace=tr, rate=rate, sched=sched,
                       norm_latency=sm["norm_latency"], ssr=sm["ssr"],
                       steady_tput=sm["steady_tput"], jct=sm["jct"],
                       kvc_util=s["kvc_util"], gpu_util=s["gpu_util"])
    em.finish()


if __name__ == "__main__":
    main()
