"""Figure 13: ablation — EconoServe-D / -SD / -SDO / full / Oracle on JCT,
TBT, SSR and throughput."""
from __future__ import annotations

from .common import Emitter, TRACE_RATES, make_trace, run, steady_metrics

VARIANTS = ["econoserve-d", "econoserve-sd", "econoserve-sdo",
            "econoserve", "oracle"]


def main(quick: bool = True) -> None:
    em = Emitter("fig13_ablation")
    n = 250 if quick else 700
    for tr in (["sharegpt"] if quick else ["alpaca", "sharegpt",
                                           "bookcorpus"]):
        rate = TRACE_RATES[tr][1]
        reqs = make_trace(tr, n, rate)
        t_end = max(r.arrival for r in reqs)
        for v in VARIANTS:
            res = run(v, tr, n, rate)
            sm = steady_metrics(res, t_end)
            s = res.summary()
            em.row(trace=tr, variant=v, jct=sm["jct"], ssr=sm["ssr"],
                   steady_tput=sm["steady_tput"], tbt=s["mean_tbt_s"],
                   kvc_util=s["kvc_util"])
    em.finish()


if __name__ == "__main__":
    main()
