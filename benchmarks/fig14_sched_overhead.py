"""Figure 14: scheduling-time overhead of each method (share of total)."""
from __future__ import annotations

from .common import Emitter, TRACE_RATES, run

SCHEDS = ["orca", "vllm", "sarathi", "fastserve", "multires",
          "econoserve-d", "econoserve-sd", "econoserve-sdo", "econoserve"]


def main(quick: bool = True) -> None:
    em = Emitter("fig14_sched_overhead")
    n = 150 if quick else 500
    tr = "sharegpt"
    for sched in SCHEDS:
        res = run(sched, tr, n, TRACE_RATES[tr][0])
        em.row(sched=sched, sched_overhead=res.sched_overhead_frac,
               jct=res.mean_jct)
    em.finish()


if __name__ == "__main__":
    main()
