"""Figure 1: motivation comparison of all schedulers on the three traces —
throughput, KVC utilization, forward size, allocation failures, JCT
decomposition, completions-per-iteration distribution."""
from __future__ import annotations

from .common import Emitter, TRACE_RATES, run

SCHEDS = ["srtf", "orca", "fastserve", "vllm", "sarathi", "multires",
          "synccoupled", "econoserve-sd", "econoserve"]


def main(quick: bool = True) -> None:
    em = Emitter("fig1_schedulers")
    n = 150 if quick else 600
    traces_ = ["sharegpt"] if quick else ["alpaca", "sharegpt", "bookcorpus"]
    for tr in traces_:
        rate = TRACE_RATES[tr][1]
        for sched in SCHEDS:
            res = run(sched, tr, n, rate)
            s = res.summary()
            bd = res.jct_breakdown()
            em.row(trace=tr, sched=sched,
                   throughput_tok_s=s["throughput_tok_s"],
                   jct=s["mean_jct_s"], kvc_util=s["kvc_util"],
                   fwd_size=s["fwd_size"],
                   alloc_fail_rate=s["alloc_fail_rate"],
                   sched_overhead=s["sched_overhead"],
                   jct_waiting=bd.get("waiting", 0.0),
                   jct_exec=bd.get("exec", 0.0),
                   jct_preempt=bd.get("preempt", 0.0))
            # fig 1f: completions per iteration (EconoServe only, compact)
            if sched == "econoserve":
                dist = res.completion_count_dist()
                tot = sum(dist.values())
                em.row(trace=tr, sched=sched,
                       frac_iters_zero_completions=dist.get(0, 0) / tot,
                       frac_iters_multi_completions=sum(
                           v for k, v in dist.items() if k >= 2) / tot)
    em.finish()


if __name__ == "__main__":
    main()
