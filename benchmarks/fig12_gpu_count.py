"""Figure 12: GPUs needed by EconoServe to match DistServe's goodput.

Both sides now run through the cluster subsystem (``ClusterSim``), not the
old hand-rolled deepcopy round-robin loop:

  * DistServe is a *real configuration* — a 2-instance disaggregated
    cluster (one prefill role, one decode role, KV transfer in between),
    one KVC per instance = 2 GPUs. Per-instance scheduling uses
    ``econoserve-d`` (decoupled queues, no sync groups / ordering /
    pipelining — i.e. FCFS exact-allocation), the closest model of
    DistServe's per-engine FCFS scheduling among the schedulers that
    support gt_queue migration;
  * EconoServe on k GPUs is a k-instance unified cluster behind the
    EconoServe-aware ``least-kvc`` router; we report the smallest k
    (up to DistServe's 2 — parity) whose fleet goodput >= DistServe's.

Every row also carries the structural conservation check (each routed
request completes exactly once across instances) — the gate the cluster
microbench enforces in CI.
"""
from __future__ import annotations

from repro.core import registry

from .common import ACCURACY, Emitter, TRACE_RATES, cost_model, make_trace, \
    sched_config


def main(quick: bool = True) -> None:
    em = Emitter("fig12_gpu_count")
    n = 240 if quick else 600
    tr = "sharegpt"
    for rate in (TRACE_RATES[tr] if not quick else TRACE_RATES[tr][:2]):
        reqs = make_trace(tr, n, rate)
        ds = registry.run_cluster(
            "econoserve-d", reqs, n_instances=2, router="least-kvc",
            roles=("prefill", "decode"), cfg=sched_config(tr),
            cost=cost_model(), accuracy=ACCURACY[tr])
        target = ds.goodput
        cons_ok = ds.conservation()["ok"]
        k_needed = None
        g = 0.0
        for k in (1, 2):
            res = registry.run_cluster(
                "econoserve", reqs, n_instances=k, router="least-kvc",
                cfg=sched_config(tr), cost=cost_model(),
                accuracy=ACCURACY[tr])
            cons_ok = cons_ok and res.conservation()["ok"]
            g = res.goodput
            if g >= target * 0.98:
                k_needed = k
                break
        k_needed = k_needed or 2         # no k matched: report parity (2)
        em.row(trace=tr, rate=rate, distserve_gpus=2.0,
               distserve_goodput=target,
               econoserve_gpus=float(k_needed),
               econoserve_goodput=g,
               gpu_reduction=1.0 - k_needed / 2.0,
               migrations=float(ds.n_migrations),
               conservation_ok=float(cons_ok))
    em.finish()


if __name__ == "__main__":
    main()
