"""Figure 12: GPUs needed by EconoServe to match DistServe's goodput.

DistServe uses 2 GPUs (disaggregated prefill/decode). EconoServe on k GPUs
is modeled as k independent engines with round-robin request assignment;
we report the smallest k whose aggregate goodput >= DistServe's."""
from __future__ import annotations

import copy

from repro.core import baselines, predictor, registry, simulator
from repro.core.registry import make_scheduler

from .common import ACCURACY, Emitter, TRACE_RATES, cost_model, make_trace, \
    sched_config


def _econoserve_goodput_k(reqs, tr, k: int) -> float:
    cost = cost_model()
    total = 0.0
    for i in range(k):
        part = copy.deepcopy(reqs[i::k])
        predictor.annotate(part, predictor.NoisyPredictor(
            accuracy=ACCURACY[tr], seed=i), 0.15)
        sched = make_scheduler("econoserve", sched_config(tr), cost)
        res = simulator.simulate(part, sched, cost)
        total += res.goodput
    return total


def main(quick: bool = True) -> None:
    em = Emitter("fig12_gpu_count")
    n = 240 if quick else 600
    tr = "sharegpt"
    for rate in (TRACE_RATES[tr] if not quick else TRACE_RATES[tr][:2]):
        reqs = make_trace(tr, n, rate)
        ds = registry.run_one("distserve", reqs, sched_config(tr),
                              cost_model(), accuracy=ACCURACY[tr])
        target = ds.goodput
        k_needed = None
        for k in (1, 2):
            g = _econoserve_goodput_k(reqs, tr, k)
            if g >= target * 0.98:
                k_needed = k
                break
        k_needed = k_needed or 2
        em.row(trace=tr, rate=rate, distserve_gpus=2.0,
               distserve_goodput=target,
               econoserve_gpus=float(k_needed),
               gpu_reduction=1.0 - k_needed / 2.0)
    em.finish()


if __name__ == "__main__":
    main()
