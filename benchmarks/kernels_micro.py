"""Kernel microbenchmarks: wall time per call of the Pallas kernels (CPU
interpret mode — correctness-path latency, NOT TPU performance) and the
pure-jnp oracle for scale."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_attention
from repro.kernels.paged_attention import paged_decode_attention

from .common import Emitter


def _time(fn, *args, reps=3):
    fn(*args)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def main(quick: bool = True) -> None:
    em = Emitter("kernels_micro")
    key = jax.random.PRNGKey(0)
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(key, (B, S, K, hd), jnp.float32)
    v = jax.random.normal(key, (B, S, K, hd), jnp.float32)
    fa = jax.jit(lambda q, k, v: flash_attention(q, k, v, interpret=True,
                                                 block_q=64, block_k=64))
    fr = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v))
    em.row(kernel="flash_prefill", impl="pallas_interpret",
           us_per_call=_time(fa, q, k, v))
    em.row(kernel="flash_prefill", impl="jnp_ref",
           us_per_call=_time(fr, q, k, v))

    P, page, MP = 16, 16, 4
    qd = jax.random.normal(key, (2, H, hd), jnp.float32)
    kp = jax.random.normal(key, (P, page, K, hd), jnp.float32)
    bt = jnp.arange(2 * MP, dtype=jnp.int32).reshape(2, MP)
    cl = jnp.array([40, 64], jnp.int32)
    pa = jax.jit(lambda *a: paged_decode_attention(*a, interpret=True))
    pr = jax.jit(ref.paged_decode_attention)
    em.row(kernel="paged_decode", impl="pallas_interpret",
           us_per_call=_time(pa, qd, kp, kp, bt, cl))
    em.row(kernel="paged_decode", impl="jnp_ref",
           us_per_call=_time(pr, qd, kp, kp, bt, cl))
    em.finish()


if __name__ == "__main__":
    main()
