"""Shared benchmark harness: traces, scheduler runs, CSV emission."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import registry, traces
from repro.core.costmodel import CostModel, ModelProfile
from repro.core.metrics import SimResult
from repro.core.scheduler import SchedulerConfig
from repro.configs import get_config

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "experiments/results")

TRACE_RATES = {  # near/above the simulated system's knee per trace
    "alpaca": (20.0, 30.0, 40.0),
    "sharegpt": (3.0, 5.0, 7.0),
    "bookcorpus": (0.4, 0.7, 1.0),
}
PAD_RATIOS = {"alpaca": 0.10, "sharegpt": 0.15, "bookcorpus": 0.20}
ACCURACY = {"alpaca": 0.775, "sharegpt": 0.732, "bookcorpus": 0.698}
RESERVE = {"alpaca": 0.02, "sharegpt": 0.03, "bookcorpus": 0.04}
BUFFER = {"alpaca": 0.15, "sharegpt": 0.15, "bookcorpus": 0.10}


def cost_model(arch: str = "opt-13b") -> CostModel:
    return CostModel(model=ModelProfile.from_config(get_config(arch)))


def sched_config(trace: str, **kw) -> SchedulerConfig:
    base = dict(pad_ratio=PAD_RATIOS[trace], reserve_frac=RESERVE[trace],
                buffer_frac=BUFFER[trace])
    base.update(kw)
    return SchedulerConfig(**base)


def make_trace(name: str, n: int, rate: float, seed: int = 0):
    return traces.generate(traces.TRACES[name], n, seed=seed, rate=rate)


def run(sched: str, trace_name: str, n: int, rate: float,
        seed: int = 0, cfg: Optional[SchedulerConfig] = None,
        cost: Optional[CostModel] = None, **kw) -> SimResult:
    reqs = make_trace(trace_name, n, rate, seed)
    cfg = cfg or sched_config(trace_name)
    cost = cost or cost_model()
    return registry.run_one(sched, reqs, cfg, cost,
                            pad_ratio=cfg.pad_ratio,
                            accuracy=ACCURACY[trace_name], seed=seed, **kw)


def steady_metrics(res: SimResult, t_end: float) -> Dict[str, float]:
    done = [r for r in res.completed if r.t_complete <= t_end]
    if not done:
        return {"steady_tput": 0.0, "jct": float("nan"),
                "norm_latency": float("nan"), "ssr": 0.0}
    return {
        "steady_tput": len(done) / t_end,
        "jct": float(np.mean([r.jct for r in done])),
        "norm_latency": float(np.mean([r.jct / max(1, r.true_rl)
                                       for r in done])),
        "ssr": float(np.mean([r.met_slo for r in done])),
    }


class Emitter:
    """Collects rows, prints `bench,metric,value` CSV, saves JSON."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []
        self.t0 = time.time()

    def row(self, **kw) -> None:
        self.rows.append(kw)

    def finish(self) -> List[Dict]:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, f"{self.name}.json")
        with open(path, "w") as f:
            json.dump({"bench": self.name,
                       "elapsed_s": round(time.time() - self.t0, 1),
                       "rows": self.rows}, f, indent=1, default=str)
        for r in self.rows:
            key = ",".join(f"{k}={v}" for k, v in r.items()
                           if not isinstance(v, float))
            for k, v in r.items():
                if isinstance(v, float):
                    print(f"{self.name},{key},{k},{v:.6g}")
        return self.rows
