"""Benchmark driver — one module per paper figure/table plus the hot-path
microbench (whose --check mode CI gates on, covering the cluster
conservation invariant) and the roofline report. Prints
``bench,key...,metric,value`` CSV lines; JSON artifacts land in
experiments/results/.

Usage:
  python -m benchmarks.run                # quick defaults (CI-sized)
  python -m benchmarks.run --full         # paper-sized sweeps
  python -m benchmarks.run --bench fig12_gpu_count

Note: ``hotpath_micro`` in quick mode never rewrites BENCH_hotpath.json —
only a full run (``--full`` or the module's own CLI) refreshes the
committed baseline the CI regression guard anchors on.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    "fig1_schedulers",
    "fig2_group_sizes",
    "fig4_padding",
    "fig5_preemption",
    "fig6_occupied_kvc",
    "fig9_rate_sweep",
    "fig12_gpu_count",
    "fig13_ablation",
    "fig14_sched_overhead",
    "fig15_sensitivity",
    "hotpath_micro",
    "kernels_micro",
    "roofline",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=None, choices=BENCHES)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    benches = [args.bench] if args.bench else BENCHES
    failures = 0
    for name in benches:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main(quick=not args.full)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
