"""Figure 2: CDF of same-(predicted)-RL group sizes among queued GTs —
validates O2 (groupable requests exist)."""
from __future__ import annotations

from collections import Counter

import numpy as np

from .common import ACCURACY, Emitter, make_trace, TRACE_RATES
from repro.core import predictor


def main(quick: bool = True) -> None:
    em = Emitter("fig2_group_sizes")
    n = 400 if quick else 2000
    for tr in (["sharegpt"] if quick else ["alpaca", "sharegpt",
                                           "bookcorpus"]):
        reqs = make_trace(tr, n, TRACE_RATES[tr][1])
        p = predictor.NoisyPredictor(accuracy=ACCURACY[tr], seed=0)
        predictor.annotate(reqs, p, pad_ratio=0.15)
        # sliding window of queued requests (arrival order, window ~ the
        # number that queue while a batch is processing)
        window = 64
        sizes = []
        for i in range(0, len(reqs) - window, window // 2):
            groups = Counter(r.padded_rl for r in reqs[i:i + window])
            sizes.extend(groups.values())
        sizes = np.array(sizes)
        em.row(trace=tr,
               frac_groups_ge2=float(np.mean(sizes >= 2)),
               frac_groups_ge4=float(np.mean(sizes >= 4)),
               frac_groups_ge12=float(np.mean(sizes >= 12)),
               mean_group_size=float(sizes.mean()))
    em.finish()


if __name__ == "__main__":
    main()
