"""Figure 6: occupied KVC of queued tasks (new GTs / preempted GTs /
chunked prompts) — validates O5 (prioritize large occupiers)."""
from __future__ import annotations

import numpy as np

from repro.core import predictor, simulator
from repro.core.registry import make_scheduler
from repro.core.request import State

from .common import ACCURACY, Emitter, TRACE_RATES, cost_model, make_trace, \
    sched_config


def main(quick: bool = True) -> None:
    em = Emitter("fig6_occupied_kvc")
    n = 200 if quick else 600
    for tr in (["sharegpt"] if quick else ["alpaca", "sharegpt",
                                           "bookcorpus"]):
        reqs = make_trace(tr, n, TRACE_RATES[tr][1])
        predictor.annotate(reqs, predictor.NoisyPredictor(
            accuracy=ACCURACY[tr], seed=0), 0.15)
        cost = cost_model()
        sched = make_scheduler("econoserve", sched_config(tr), cost)
        samples = {"new_gt": [], "preempted_gt": [], "chunked_pt": []}
        orig = sched.form_batch

        def wrapped(t):
            for r in sched.gt_queue:
                key = "preempted_gt" if r.n_preemptions else "new_gt"
                samples[key].append(r.occupied_kvc)
            for r in sched.pt_queue:
                if 0 < r.prompt_done < r.prompt_len:
                    samples["chunked_pt"].append(r.occupied_kvc)
            return orig(t)

        sched.form_batch = wrapped
        simulator.simulate(reqs, sched, cost)
        cap = sched.kvc.capacity_tokens
        for key, vals in samples.items():
            if vals:
                em.row(trace=tr, category=key,
                       mean_frac=float(np.mean(vals)) / cap,
                       p95_frac=float(np.percentile(vals, 95)) / cap,
                       n=float(len(vals)))
    em.finish()


if __name__ == "__main__":
    main()
