"""Figure 15: sensitivity to SLO scale, padding ratio, reserved-KVC
fraction and the KVCPipe buffer."""
from __future__ import annotations

from repro.core import traces

from .common import Emitter, TRACE_RATES, make_trace, run, sched_config


def main(quick: bool = True) -> None:
    em = Emitter("fig15_sensitivity")
    n = 150 if quick else 400
    tr = "sharegpt"
    rate = TRACE_RATES[tr][0]

    for slo_scale in ((0.5, 1.5, 2.5) if quick else (0.5, 1.0, 1.5, 2.0, 2.5)):
        reqs = traces.generate(traces.TRACES[tr], n, seed=0, rate=rate,
                               slo_scale=slo_scale)
        from repro.core import registry
        res = registry.run_one("econoserve", reqs, sched_config(tr),
                               accuracy=0.732)
        em.row(factor="slo_scale", value=float(slo_scale), ssr=res.ssr,
               jct=res.mean_jct, tput=res.throughput_reqs)

    for reserve in (0.01, 0.03, 0.06) if quick else (0.01, 0.02, 0.03,
                                                     0.04, 0.06):
        res = run("econoserve", tr, n, rate,
                  cfg=sched_config(tr, reserve_frac=reserve))
        em.row(factor="reserve_frac", value=float(reserve), ssr=res.ssr,
               jct=res.mean_jct, tput=res.throughput_reqs)

    for buf in (0.05, 0.15, 0.30):
        res = run("econoserve", tr, n, rate,
                  cfg=sched_config(tr, buffer_frac=buf))
        em.row(factor="buffer_frac", value=float(buf), ssr=res.ssr,
               jct=res.mean_jct, tput=res.throughput_reqs)

    for pad in (0.0, 0.15, 0.3):
        res = run("econoserve", tr, n, rate,
                  cfg=sched_config(tr, pad_ratio=pad))
        em.row(factor="pad_ratio", value=float(pad), ssr=res.ssr,
               jct=res.mean_jct, tput=res.throughput_reqs)
    em.finish()


if __name__ == "__main__":
    main()
