"""Figure 4 / 15b: sweet-spot padding-ratio sweep — JCT (waiting vs
processing), KVC utilization, under-provisioned request fraction."""
from __future__ import annotations

from .common import Emitter, TRACE_RATES, run, sched_config


def main(quick: bool = True) -> None:
    em = Emitter("fig4_padding")
    n = 150 if quick else 500
    ratios = (0.0, 0.1, 0.2, 0.3) if quick else (0.0, 0.05, 0.1, 0.15,
                                                 0.2, 0.25, 0.3)
    for tr in (["sharegpt"] if quick else ["alpaca", "sharegpt",
                                           "bookcorpus"]):
        for pad in ratios:
            cfg = sched_config(tr, pad_ratio=pad)
            res = run("econoserve-sd", tr, n, TRACE_RATES[tr][0], cfg=cfg)
            s = res.summary()
            bd = res.jct_breakdown()
            em.row(trace=tr, pad_ratio=pad, jct=s["mean_jct_s"],
                   waiting=bd.get("waiting", 0.0),
                   kvc_util=s["kvc_util"],
                   underprov_frac=s["underprov"] / max(1, s["completed"]))
    em.finish()


if __name__ == "__main__":
    main()
