"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

Each module defines ``CONFIG`` with the exact assigned full-scale
configuration (citation in ``source``), exercised via the dry-run only.
Smoke tests use ``CONFIG.reduced()``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_ARCHS = [
    "stablelm_12b",
    "phi3_vision_4_2b",
    "deepseek_coder_33b",
    "qwen3_8b",
    "musicgen_large",
    "arctic_480b",
    "zamba2_7b",
    "phi3_5_moe_42b",
    "mistral_nemo_12b",
    "xlstm_125m",
    "opt_13b",  # the paper's own serving model
]

_ALIASES = {
    "stablelm-12b": "stablelm_12b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen3-8b": "qwen3_8b",
    "musicgen-large": "musicgen_large",
    "arctic-480b": "arctic_480b",
    "zamba2-7b": "zamba2_7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "xlstm-125m": "xlstm_125m",
    "opt-13b": "opt_13b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def list_archs(include_paper_model: bool = True) -> List[str]:
    archs = list(_ARCHS)
    if not include_paper_model:
        archs.remove("opt_13b")
    return archs


def all_configs(include_paper_model: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in list_archs(include_paper_model)}
