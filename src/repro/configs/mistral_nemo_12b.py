"""Mistral-Nemo-12B: dense GQA, 128k context (long-context decode uses the
sliding-window attention variant). [hf:mistralai/Mistral-Nemo-Base-2407]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,          # Nemo uses head_dim 128 (< d_model/num_heads)
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
