"""Zamba2-7B: Mamba2 backbone with a single shared attention block applied
every 6th layer (weights shared across invocations). [arXiv:2411.15242]
"""
from repro.models.config import MAMBA, ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,       # shared block is MHA
    head_dim=112,
    d_ff=14336,            # shared block MLP
    vocab_size=32000,
    layer_pattern=MAMBA * 81,
    shared_attention_every=6,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
)
