"""StableLM-2-12B: dense GQA decoder. [hf:stabilityai/stablelm-2-1_6b family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,          # 5120 / 32
    d_ff=13824,
    vocab_size=100352,
    rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
)
