"""OPT-13B-shaped dense model — the paper's own serving model (§2/§4).

We model it as a modern GQA-free (MHA) decoder with the OPT-13B dims;
used by the serving benchmarks and examples. [arXiv:2205.01068]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="opt-13b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=20480,
    vocab_size=50272,
    rope_theta=10_000.0,
    source="arXiv:2205.01068",
)
