"""MusicGen-large: decoder-only transformer over EnCodec tokens.

The EnCodec conv codec is a stub frontend — ``input_specs`` supplies
precomputed conditioning-frame embeddings; the decoder generates audio
tokens from vocab 2048. [arXiv:2306.05284]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,       # MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    frontend_tokens=256,   # text/melody conditioning frames
    rope_theta=10_000.0,
    source="arXiv:2306.05284",
)
