"""xLSTM-125M: sLSTM + mLSTM blocks (7:1-style mix at small scale).
d_ff = 0 — projections live inside the xLSTM blocks. [arXiv:2405.04517]
"""
from repro.models.config import MLSTM, SLSTM, ModelConfig

# 12 layers, sLSTM at positions 3 and 9 (paper places a few sLSTM blocks
# among mLSTM blocks)
_PATTERN = "".join(SLSTM if i in (3, 9) else MLSTM for i in range(12))

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    layer_pattern=_PATTERN,
    xlstm_proj_factor=2.0,
    source="arXiv:2405.04517",
)
