"""Snowflake Arctic 480B: dense-MoE hybrid — every layer has a dense
residual FFN in parallel with a 128-expert top-2 MoE.
[hf:Snowflake/snowflake-arctic-base]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,             # dense residual FFN
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    moe_dense_residual=True,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    source="hf:Snowflake/snowflake-arctic-base",
)
