"""Phi-3.5-MoE 42B (6.6B active): 16-expert top-2 MoE transformer.
[hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=6400,
    capacity_factor=1.25,
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
