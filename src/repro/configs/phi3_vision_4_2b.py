"""Phi-3-Vision 4.2B: phi3-mini text backbone + CLIP frontend (stub).

The vision encoder is a stub — ``input_specs`` supplies precomputed patch
embeddings of shape (B, frontend_tokens, d_model).
[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,       # MHA (GQA kv=32)
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision",
    frontend_tokens=1024,  # ~ one 1024-patch image per request
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)
