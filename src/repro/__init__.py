"""repro: EconoServe (Shen & Sen, 2024) on JAX/TPU — serving framework."""
__version__ = "0.1.0"
