"""Jit'd public wrappers around the Pallas kernels.

On non-TPU backends (this container is CPU-only) the kernels run in
interpret mode, which executes the kernel body in Python — bit-accurate
for correctness tests, not for speed. On TPU the same code lowers to
Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_prefill import flash_attention as _flash_pallas
from .paged_attention import paged_decode_attention as _paged_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, segment_ids=None, q_positions=None,
                    kv_positions=None, kv_segment_ids=None, *,
                    causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    """Prefill/training attention. q (B,Sq,H,hd); k/v (B,Sk,K,hd).

    ``segment_ids`` (B,S) int32 (optional) makes the mask block-diagonal —
    the token-packed prefill path, where a wave of prompts runs as one
    flattened sequence with no batch- or length-padding.

    ``q_positions`` (B,Sq) / ``kv_positions`` (B,Sk) (optional, together)
    switch to explicit-position masking and allow Sq != Sk — the
    chunked-prefill path, where the key axis is a seeded cache-prefix view
    concatenated with the chunk (invalid prefix slots carry
    ``flash_prefill.POS_INVALID``).

    ``kv_segment_ids`` (B,Sk) (optional, with ``segment_ids``) gives the
    key axis its own segment array — the packed multi-request chunk path,
    where several requests' prefix views plus their packed chunks share
    one call."""
    bq = min(block_q, max(16, q.shape[1]))
    bk = min(block_k, max(16, k.shape[1]))
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         softcap=softcap, segment_ids=segment_ids,
                         kv_segment_ids=kv_segment_ids,
                         q_positions=q_positions, kv_positions=kv_positions,
                         block_q=bq, block_k=bk,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("softcap", "pages_per_step"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, context_lens,
                           *, softcap: Optional[float] = None,
                           pages_per_step: int = 8):
    """Decode attention over an explicitly paged cache."""
    return _paged_pallas(q, k_pages, v_pages, block_tables, context_lens,
                         softcap=softcap, pages_per_step=pages_per_step,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("softcap", "pages_per_step"))
def decode_attention(q, cache_k, cache_v, context_lens, *,
                     softcap: Optional[float] = None,
                     pages_per_step: int = 8):
    """Decode attention over a contiguous per-request cache row.

    q (B,H,hd); cache_k/v (B,C,K,hd); context_lens (B,) — number of valid
    slots (for ring buffers every written slot is valid; softmax is
    permutation-invariant so slot order does not matter).

    Implemented by viewing each row as pages of the paged kernel.
    """
    B, C, K, hd = cache_k.shape
    for ps in (128, 64, 32, 16, 8):
        if C % ps == 0:
            break
    else:
        ps = C
    mp = C // ps
    kp = cache_k.reshape(B * mp, ps, K, hd)
    vp = cache_v.reshape(B * mp, ps, K, hd)
    bt = (jnp.arange(B)[:, None] * mp + jnp.arange(mp)[None, :]).astype(jnp.int32)
    return _paged_pallas(q, kp, vp, bt, context_lens.astype(jnp.int32),
                         softcap=softcap, pages_per_step=pages_per_step,
                         interpret=_interpret())


# re-export oracles for convenience
flash_attention_ref = ref.flash_attention
paged_decode_attention_ref = ref.paged_decode_attention
kv_page_append = jax.jit(ref.kv_page_append)
