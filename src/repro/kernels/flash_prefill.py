"""Flash attention (prefill/training) as a Pallas TPU kernel.

Canonical TPU pattern: grid = (batch, q_head, q_blocks, k_blocks) with the
k-block axis innermost; running max / sum / accumulator live in VMEM
scratch that persists across the sequential k steps, and the output block
is written on the last k step. BlockSpecs keep one (block_q, head_dim) Q
tile and one (block_k, head_dim) K/V tile in VMEM per step — MXU-aligned.

Two masking modes:
  * implicit (default): causal/window masks built from the global iota —
    requires Sq == Sk and contiguous positions.
  * explicit positions: ``q_positions`` (B, Sq) / ``kv_positions`` (B, Sk)
    operands drive the mask (kv <= q, window on position deltas). This is
    the chunked-prefill path: the key axis is a seeded cache-prefix view
    concatenated with the chunk itself, so Sq != Sk and key positions are
    not an iota (invalid prefix slots carry the ``POS_INVALID`` sentinel,
    which the causal term masks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
POS_INVALID = 2 ** 30          # key position sentinel: masked by causality


def _kernel(q_ref, k_ref, v_ref, *rest,
            scale: float, block_q: int, block_k: int, seq_len: int,
            causal: bool, window: Optional[int], softcap: Optional[float],
            num_kblocks: int, has_segments: bool, has_positions: bool):
    rest = list(rest)
    sq_ref = sk_ref = pq_ref = pk_ref = None
    if has_segments:
        sq_ref, sk_ref = rest[0], rest[1]
        rest = rest[2:]
    if has_positions:
        pq_ref, pk_ref = rest[0], rest[1]
        rest = rest[2:]
    o_ref, m_scr, l_scr, acc_scr = rest
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # skip fully-masked tiles (causal: k block entirely after q block;
    # window: k block entirely before the window). Only valid when the
    # iota IS the position — explicit positions disable the static skip.
    run = True
    if causal and not has_positions:
        run = k_start <= q_start + block_q - 1
    if window is not None and not has_positions:
        run = jnp.logical_and(run,
                              k_start + block_k - 1 > q_start - window)

    @pl.when(run if isinstance(run, jax.Array) else bool(run))
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        if has_positions:
            # explicit token positions: the key axis may be a cache-prefix
            # view (invalid slots carry POS_INVALID and mask causally)
            ii = pq_ref[0, :][:, None]
            jj = pk_ref[0, :][None, :]
            mask = jj < POS_INVALID
        else:
            ii = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            jj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            mask = jj < seq_len
        if causal:
            mask &= jj <= ii
        if window is not None:
            mask &= jj > ii - window
        if has_segments:
            # block-diagonal (token-packed) masking: tokens attend only
            # within their own segment; global iota order == within-segment
            # order, so the causal/window terms above stay exact
            mask &= sq_ref[0, :][:, None] == sk_ref[0, :][None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == num_kblocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    segment_ids: Optional[jax.Array] = None,
                    kv_segment_ids: Optional[jax.Array] = None,
                    q_positions: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,K,hd), H multiple of K (GQA).

    The q-head grid axis indexes query heads; the K/V BlockSpec maps it to
    the owning kv head (h // G), so GQA costs no extra K/V traffic.

    ``segment_ids`` (B,S) int32 restricts attention to equal segments
    (block-diagonal mask for token-packed prefill). Padded tail positions
    get segment -1, which still never leaks into real rows because the
    ``jj < seq_len`` bound masks them first.

    ``q_positions`` (B,Sq) / ``kv_positions`` (B,Sk) switch the mask to
    explicit token positions (chunked prefill: the key axis is a seeded
    cache-prefix view plus the chunk, so Sq != Sk is allowed and invalid
    key slots carry ``POS_INVALID``). Both must be given together.

    ``kv_segment_ids`` (B,Sk) gives the key axis its own segment array
    (packed *multi-request* chunked prefill: the key axis is several
    requests' cache-prefix views plus the packed chunk wave, so segment
    arrays differ per side). Requires ``segment_ids``; defaults to it.
    """
    assert (q_positions is None) == (kv_positions is None)
    has_positions = q_positions is not None
    assert kv_segment_ids is None or segment_ids is not None
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    assert has_positions or Sq == Sk, \
        "rectangular attention requires explicit positions"
    K = k.shape[2]
    G = H // K
    orig_Sq, orig_Sk = Sq, Sk
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k

    def _pad1(a, n, fill):
        return jnp.concatenate(
            [a.astype(jnp.int32), jnp.full((B, n), fill, jnp.int32)],
            axis=1) if n else a.astype(jnp.int32)

    seg_q = seg_k = None
    if segment_ids is not None:
        # pad q segment -1 / pad k segment -2: pad rows never match
        seg_q = _pad1(segment_ids, pad_q, -1)
        seg_k = _pad1(kv_segment_ids if kv_segment_ids is not None
                      else segment_ids, pad_k, -2)
    if has_positions:
        # pad queries attend nothing (their rows are sliced off); pad keys
        # carry the invalid sentinel, masked by causality
        q_positions = _pad1(q_positions, pad_q, -1)
        kv_positions = _pad1(kv_positions, pad_k, POS_INVALID)
    if pad_q:
        q = jnp.concatenate(
            [q, jnp.zeros((B, pad_q, H, hd), q.dtype)], axis=1)
        Sq = q.shape[1]
    if pad_k:
        zk = jnp.zeros((B, pad_k, K, hd), k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
        Sk = k.shape[1]
    nq = Sq // block_q
    nk = Sk // block_k
    scale = 1.0 / (hd ** 0.5)
    has_segments = segment_ids is not None

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=orig_Sk, causal=causal, window=window, softcap=softcap,
        num_kblocks=nk, has_segments=has_segments,
        has_positions=has_positions)
    in_specs = [
        pl.BlockSpec((1, block_q, 1, hd),
                     lambda b, h, i, j: (b, i, h, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, i, j, G=G: (b, j, h // G, 0)),
        pl.BlockSpec((1, block_k, 1, hd),
                     lambda b, h, i, j, G=G: (b, j, h // G, 0)),
    ]
    operands = [q, k, v]
    if has_segments:
        # the same (B,S) array is fed twice: once tiled along the q-block
        # axis, once along the k-block axis
        in_specs.append(pl.BlockSpec((1, block_q),
                                     lambda b, h, i, j: (b, i)))
        in_specs.append(pl.BlockSpec((1, block_k),
                                     lambda b, h, i, j: (b, j)))
        operands += [seg_q, seg_k]
    if has_positions:
        in_specs.append(pl.BlockSpec((1, block_q),
                                     lambda b, h, i, j: (b, i)))
        in_specs.append(pl.BlockSpec((1, block_k),
                                     lambda b, h, i, j: (b, j)))
        operands += [q_positions, kv_positions]
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(*operands)
    return out[:, :orig_Sq]
