"""Paged decode attention as a Pallas TPU kernel — the serving hot spot.

One query token per request attends to its paged KV cache. TPU adaptation
of vLLM's CUDA paged-attention: the grid walks (request, kv_head,
page-tile) with page ids resolved from a scalar-prefetched block table.
GQA query heads of one kv head are processed together as the tile's
sublane dimension; flash-style running max/sum scratch merges tiles.

Each grid step processes ``pages_per_step`` pages: the K/V pages live in
HBM (``memory_space=ANY``) and the kernel issues one manual async copy per
needed page into a double-buffered VMEM scratch tile, so

  * a step whose tile lies fully past ``context_lens[b]`` issues *no* DMA
    at all (the old BlockSpec pipeline prefetched every page of every
    request up to ``max_pages`` regardless of context length),
  * short contexts stop paying per-page grid-step overhead, and
  * tile ``s+1``'s copies are issued before tile ``s`` is consumed
    (revolving buffers), keeping the DMA/compute overlap the BlockSpec
    pipeline provided.

The per-page flash update loop is ordered exactly like the one-page-per-
step kernel, so results are bit-identical for any ``pages_per_step``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables, context_lens, q_ref, k_hbm, v_hbm, o_ref,
            m_scr, l_scr, acc_scr, k_tile, v_tile, sem, *,
            page: int, pages_per_step: int, scale: float,
            softcap: Optional[float], max_pages: int, n_steps: int):
    b = pl.program_id(0)
    kh = pl.program_id(1)
    s = pl.program_id(2)
    ctx = context_lens[b]

    def tile_dma(t, buf, start):
        """Issue (or wait on) the copies for page tile ``t`` into revolving
        buffer ``buf``. Pages past the context or the table issue nothing —
        ``t == n_steps`` (the last step's prefetch) self-guards because its
        page indices are all >= max_pages."""
        for i in range(pages_per_step):
            pi = t * pages_per_step + i

            @pl.when((pi * page < ctx) & (pi < max_pages))
            def _(i=i, pi=pi):
                pid = block_tables[b, pi]
                ck = pltpu.make_async_copy(k_hbm.at[pid, :, kh, :],
                                           k_tile.at[buf, i],
                                           sem.at[buf, 0, i])
                cv = pltpu.make_async_copy(v_hbm.at[pid, :, kh, :],
                                           v_tile.at[buf, i],
                                           sem.at[buf, 1, i])
                if start:
                    ck.start()
                    cv.start()
                else:
                    ck.wait()
                    cv.wait()

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        tile_dma(0, 0, start=True)

    base = s * pages_per_step * page

    @pl.when(base < ctx)
    def _work():
        buf = jax.lax.rem(s, 2)
        tile_dma(s + 1, jax.lax.rem(s + 1, 2), start=True)   # prefetch
        tile_dma(s, buf, start=False)                        # arrive

        # flash updates page-by-page, in the exact op order of the
        # single-page kernel -> bit-identical output for any tile size
        for i in range(pages_per_step):
            pi = s * pages_per_step + i

            @pl.when((pi * page < ctx) & (pi < max_pages))
            def _step(i=i, pi=pi):
                q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
                k = k_tile[buf, i].astype(jnp.float32)        # (page, hd)
                v = v_tile[buf, i].astype(jnp.float32)
                s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                         preferred_element_type=jnp.float32
                                         ) * scale
                if softcap is not None:
                    s_ = softcap * jnp.tanh(s_ / softcap)     # (G, page)
                tok = pi * page + jax.lax.broadcasted_iota(jnp.int32,
                                                           s_.shape, 1)
                s_ = jnp.where(tok < ctx, s_, NEG_INF)

                m_prev = m_scr[...]
                m_new = jnp.maximum(m_prev,
                                    jnp.max(s_, axis=1, keepdims=True))
                pexp = jnp.exp(s_ - m_new)
                alpha = jnp.exp(m_prev - m_new)
                l_scr[...] = alpha * l_scr[...] + jnp.sum(pexp, axis=1,
                                                          keepdims=True)
                acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
                    pexp, v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                m_scr[...] = m_new

    @pl.when(s == n_steps - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           context_lens: jax.Array, *,
                           softcap: Optional[float] = None,
                           pages_per_step: int = 8,
                           interpret: bool = False) -> jax.Array:
    """q (B,H,hd); k/v_pages (P,page,K,hd); block_tables (B,MP) int32;
    context_lens (B,) int32. Returns (B,H,hd)."""
    B, H, hd = q.shape
    P, page, K, _ = k_pages.shape
    G = H // K
    MP = block_tables.shape[1]
    pps = max(1, min(pages_per_step, MP))
    n_steps = -(-MP // pps)
    qg = q.reshape(B, K, G, hd)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, page=page, pages_per_step=pps,
                               scale=scale, softcap=softcap,
                               max_pages=MP, n_steps=n_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_steps),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, kh, s, bt, cl: (b, kh, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, kh, s, bt, cl: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((2, pps, page, hd), k_pages.dtype),   # double buffer
            pltpu.VMEM((2, pps, page, hd), v_pages.dtype),
            pltpu.SemaphoreType.DMA((2, 2, pps)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
