"""Paged decode attention as a Pallas TPU kernel — the serving hot spot.

One query token per request attends to its paged KV cache. TPU adaptation
of vLLM's CUDA paged-attention: instead of a thread block walking the page
list, the *grid* walks (request, kv_head, page) with the page id resolved
by a scalar-prefetched block table inside the K/V BlockSpec index_map —
each step DMAs exactly one (page_size, head_dim) tile from HBM into VMEM.
Flash-style running max/sum scratch merges pages; GQA query heads of one
kv head are processed together as the tile's sublane dimension.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(block_tables, context_lens, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page: int, scale: float,
            softcap: Optional[float], max_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = context_lens[b]

    @pl.when(p * page < ctx)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)               # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (page, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)           # (G, page)
        tok = p * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < ctx, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(pexp, axis=1,
                                                  keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(p == max_pages - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           context_lens: jax.Array, *,
                           softcap: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q (B,H,hd); k/v_pages (P,page,K,hd); block_tables (B,MP) int32;
    context_lens (B,) int32. Returns (B,H,hd)."""
    B, H, hd = q.shape
    P, page, K, _ = k_pages.shape
    G = H // K
    MP = block_tables.shape[1]
    qg = q.reshape(B, K, G, hd)
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, page=page, scale=scale,
                               softcap=softcap, max_pages=MP)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, MP),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, kh, p, bt, cl: (b, kh, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, kh, p, bt, cl: (bt[b, p], 0, kh, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda b, kh, p, bt, cl: (bt[b, p], 0, kh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, kh, p, bt, cl: (b, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, qg, k_pages, v_pages)
    return out.reshape(B, H, hd)
