"""Pallas TPU kernels for the serving hot spots (validated in interpret
mode on CPU): flash prefill attention and paged decode attention."""
