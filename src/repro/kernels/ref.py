"""Pure-jnp oracles for the Pallas kernels.

These define the semantics the kernels are tested against (tests sweep
shapes/dtypes and assert_allclose kernel-vs-ref).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _softcap(x, cap: Optional[float]):
    return x if cap is None else cap * jnp.tanh(x / cap)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    segment_ids: Optional[jax.Array] = None,
                    kv_segment_ids: Optional[jax.Array] = None,
                    q_positions: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """q (B,Sq,H,hd); k/v (B,Sk,K,hd) with H a multiple of K (GQA).
    Causal (optionally sliding-window) attention. fp32 accumulation.
    ``segment_ids`` (B,S) makes the mask block-diagonal (token packing).
    ``q_positions``/``kv_positions`` (B,Sq)/(B,Sk) drive the mask instead
    of the iota and allow Sq != Sk (chunked prefill over a cache prefix;
    invalid key slots carry a huge sentinel that causality masks).
    ``kv_segment_ids`` (B,Sk) gives the key axis its own segment array
    (packed multi-request chunked prefill)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32).reshape(B, Sq, K, G, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bskgh,btkh->bkgst", qf, kf) / jnp.sqrt(hd)
    logits = _softcap(logits, softcap)
    if q_positions is not None:
        ii = q_positions[:, :, None]                   # (B,Sq,1)
        jj = kv_positions[:, None, :]                  # (B,1,Sk)
        mask = jnp.ones((B, Sq, Sk), bool)
    else:
        assert Sq == Sk
        ii = jnp.arange(Sq)[:, None]
        jj = jnp.arange(Sk)[None, :]
        mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= jj <= ii
    if window is not None:
        mask &= jj > ii - window
    if mask.ndim == 2:
        mask = jnp.broadcast_to(mask[None], (B, Sq, Sk))
    if segment_ids is not None:
        seg_k = kv_segment_ids if kv_segment_ids is not None else segment_ids
        mask &= segment_ids[:, :, None] == seg_k[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w, vf)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           context_lens: jax.Array, *,
                           softcap: Optional[float] = None) -> jax.Array:
    """One-token decode attention over a paged KV cache.

    q             (B, H, hd)
    k_pages       (P, page_size, K, hd)   pooled pages
    v_pages       (P, page_size, K, hd)
    block_tables  (B, max_pages) int32    page ids per request (row-major)
    context_lens  (B,) int32              valid tokens per request
    returns       (B, H, hd)
    """
    B, H, hd = q.shape
    P, page, K, _ = k_pages.shape
    G = H // K
    max_pages = block_tables.shape[1]

    # gather each request's pages -> (B, max_pages*page, K, hd)
    kg = k_pages[block_tables]                     # (B, mp, page, K, hd)
    vg = v_pages[block_tables]
    kg = kg.reshape(B, max_pages * page, K, hd).astype(jnp.float32)
    vg = vg.reshape(B, max_pages * page, K, hd).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(B, K, G, hd)
    logits = jnp.einsum("bkgh,btkh->bkgt", qf, kg) / jnp.sqrt(hd)
    logits = _softcap(logits, softcap)
    valid = jnp.arange(max_pages * page)[None, :] < context_lens[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, vg)
    return out.reshape(B, H, hd).astype(q.dtype)


def kv_page_append(k_pages: jax.Array, v_pages: jax.Array,
                   k_new: jax.Array, v_new: jax.Array,
                   block_tables: jax.Array, positions: jax.Array):
    """Scatter one new token's K/V into the paged cache.

    k_new/v_new (B, K, hd); positions (B,) absolute token index.
    Returns updated (k_pages, v_pages)."""
    page = k_pages.shape[1]
    page_idx = positions // page
    slot = positions % page
    bidx = jnp.arange(k_new.shape[0])
    pids = block_tables[bidx, page_idx]
    k_pages = k_pages.at[pids, slot].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[pids, slot].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages
