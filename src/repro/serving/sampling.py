"""Token sampling for the serving engine."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 → greedy
    top_k: int = 0                # 0 → disabled
    max_new_tokens: int = 64
    eos_token: Optional[int] = None


def sample(logits: jax.Array, key: jax.Array,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (B, V) -> token ids (B,). Scalar params applied to all rows."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_in_graph(logits, key, temps, top_ks, need_sample, need_topk):
    """Traceable sampling body: per-row temperature / top-k over (B, V)
    logits. ``need_sample`` / ``need_topk`` must be Python bools (trace-time
    constants). Called directly inside the engine's fused async decode step
    (so sampling stays in the same XLA program as the forward pass) and
    wrapped by the standalone jit below for the legacy host-driven path."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if not need_sample:
        return greedy
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    if need_topk:
        # per-row k-th largest value via one descending sort (k varies)
        srt = jnp.sort(scaled, axis=-1)[:, ::-1]
        kth_idx = jnp.clip(top_ks, 1, V) - 1
        kth = jnp.take_along_axis(srt, kth_idx[:, None], axis=-1)
        scaled = jnp.where((top_ks[:, None] > 0) & (scaled < kth),
                           -1e30, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)


_sample_per_request = functools.partial(
    jax.jit, static_argnames=("need_sample", "need_topk"))(sample_in_graph)


def sample_per_request(logits: jax.Array, key: jax.Array,
                       temps, top_ks) -> jax.Array:
    """Batched sampling with *per-row* temperature and top-k.

    logits (B, V); temps (B,) float (<=0 -> greedy); top_ks (B,) int
    (0 -> disabled). One fused call for the whole decode batch — no
    per-request host round-trips, no collapsing distinct temperatures.
    All-greedy batches compile to a bare argmax (no O(V log V) sort on
    the decode hot path); the vocab sort only exists when some row
    actually uses top-k.
    """
    need_sample = bool(np.any(np.asarray(temps) > 0.0))
    need_topk = need_sample and bool(np.any(np.asarray(top_ks) > 0))
    return _sample_per_request(logits, key, jnp.asarray(temps),
                               jnp.asarray(top_ks), need_sample, need_topk)
