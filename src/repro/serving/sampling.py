"""Token sampling for the serving engine."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 → greedy
    top_k: int = 0                # 0 → disabled
    max_new_tokens: int = 64
    eos_token: Optional[int] = None


def sample(logits: jax.Array, key: jax.Array,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (B, V) -> token ids (B,)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
