"""Continuous-batching serving engine: real JAX model execution driven by
any `repro.core` scheduler (EconoServe by default).

The scheduler owns KVC block accounting, batching policy, SLO ordering,
and KVC pipelining; the engine owns slots, caches, jitted prefill/decode
steps and sampling. Completion is EOS- or max-tokens-driven; when EOS
fires early the request's `true_rl` is clamped so the scheduler sees the
real completion (the RL predictor only ever saw the prompt).

Hot-path layout (why the shapes look the way they do):

  * Decode is *fully asynchronous and device-resident* (default,
    ``EngineConfig.async_decode``): per-slot ``last_tok`` / ``pos`` /
    sampling params live as device arrays carried across iterations, and
    decode -> sample -> EOS-check -> pos-update run as ONE jitted,
    buffer-donated step (XLA reuses the cache buffers in place). Sampled
    tokens are drained to the host with a lag of
    ``EngineConfig.readback_lag`` iterations — the host appends tokens for
    iteration t-k while iteration t runs on device, so the steady-state
    loop issues zero blocking host syncs (``sync_counts`` /
    ``n_blocking_syncs`` instrument this). Only when an *active* request
    carries an ``eos_token`` does the engine read back a (B,) flag vector
    per iteration, because the scheduler's completion accounting needs EOS
    at the iteration it fires to stay bitwise-equal to the sync path.
  * Prefill is *token-packed* (default, ``EngineConfig.packed_prefill``):
    all PT items of an iteration are concatenated into one flattened token
    axis with per-segment positions and a block-diagonal segment mask —
    no batch-dim padding and no per-row length padding; the only padding
    left is rounding the total token count up to a pow2 bucket, so XLA
    compiles <= ceil(log2(max_total_tokens)) programs per engine lifetime.
    Models with recurrent blocks (SSM/xLSTM) fall back to exact-shape
    prefill, where foreign segments would corrupt the recurrent state; the
    legacy (max_batch, pow2-seq) padded-batch path is kept behind
    ``packed_prefill=False`` for the equivalence tests.
  * Decode *megasteps* (default, ``EngineConfig.decode_megastep`` > 1):
    when the scheduler proves a horizon of K iterations with fixed batch
    membership (``BaseScheduler.decode_horizon``), the engine runs
    K fused iterations as ONE dispatched ``lax.while_loop`` program and
    the host replays the K scheduler iterations against the precomputed
    (K, B) token matrix — decisions stay bitwise-identical to the
    per-iteration path while steady-state dispatch cost is amortized K×
    (``n_decode_dispatches`` / ``decode_iters`` instruments it). The
    horizon survives *memory pressure*: non-empty waiting queues are
    certified KVC-blocked from O(1) counters
    (``_admission_horizon``), so windows keep fusing exactly where the
    saturated steady state lives. EOS may fire inside a window: with
    empty queues completions only shrink the batch and the replay
    handles them; under pressure the freed KVC could admit a waiter, so
    the fused loop early-exits right after the EOS iteration
    (``stop_on_eos``) and admission lands at the exact iteration the
    K=1 path would admit.
  * Prefill is *chunk-capable*: the engine executes the scheduler's
    per-chunk PT grants (``_fill_pts``) instead of requiring TFS >= max
    prompt length. A chunk attends over the request's already-seeded
    cache prefix via a KV-prefix view threaded through ``model.prefill``
    → ``attn_prefill`` → the flash kernel and both jnp fallbacks, and its
    K/V seed the cache incrementally at [start, start+len). A wave of
    >= 2 chunk grants in one iteration runs as ONE token-packed call
    (default, ``EngineConfig.packed_chunk_prefill``): per-segment
    positions and segment ids over the packed chunks, each segment's
    own cache-prefix view prepended to the key axis, and one donated
    per-segment seed scatter. Pure-recurrent stacks (SSM/xLSTM) resume
    chunks from a carried per-request state snapshot (O(n) total);
    hybrid stacks fall back to recomputing the whole prefix each chunk
    (correct, O(n^2) across chunks); ``incremental_chunk_prefill=False``
    forces that reference path everywhere for the equivalence tests.
  * Cache seeding is one jitted, buffer-donated scatter over the whole
    item batch (a per-segment gather for the packed path) — not a
    per-layer host-side pytree rebuild.
  * Sampling is vectorized with per-slot temperature / top-k vectors and,
    on the async path, runs inside the decode program itself (no separate
    dispatch, no host round-trip).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel, ModelProfile
from repro.core.predictor import NoisyPredictor, apply_padding
from repro.core.pressure import WatermarkGuard
from repro.core.request import Request, State
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.models import model
from repro.models.attention import POS_INVALID
from repro.models.config import ATTN, ModelConfig

from .sampling import SamplingParams, sample_in_graph, sample_per_request

MIN_SEQ_BUCKET = 16


class InvalidRequestError(ValueError):
    """Typed rejection for malformed ``GenRequest``s: the engine fails
    fast at ``submit`` instead of surfacing a deep scatter/shape error
    iterations later."""


class RequestShed(RuntimeError):
    """Typed admission rejection: the fleet's projected goodput says the
    request cannot meet its deadline, so it is fast-failed (marked
    ``status="shed"``) instead of queued into certain SLO violation.
    Carries the request as ``.request``."""

    def __init__(self, request, reason: str):
        super().__init__(reason)
        self.request = request
        self.reason = reason


class FleetStalled(RuntimeError):
    """``serve_stream`` watchdog: work remains but N consecutive steps
    made no progress (no completions, drains, dispatches, or deliveries).
    Carries a per-instance diagnostic snapshot as ``.debug``."""

    def __init__(self, msg: str, debug=None):
        super().__init__(msg)
        self.debug = debug or {}


def kv_checksum(kv: dict) -> int:
    """CRC over a KV-migration image, computed at export and verified at
    inject — a corrupted payload (fault injection, or a real transport
    bug) must degrade to the recompute fallback, never poison a cache."""
    import zlib
    crc = 0
    for kind in sorted(kv):
        for n in ("k", "v"):
            crc = zlib.crc32(np.ascontiguousarray(kv[kind][n]).tobytes(),
                             crc)
    return crc


def seq_bucket(n: int) -> int:
    """Power-of-two padded length (floor MIN_SEQ_BUCKET)."""
    b = MIN_SEQ_BUCKET
    while b < n:
        b <<= 1
    return b


@dataclass
class EngineConfig:
    """Engine hot-path toggles, mirroring the PR 1
    ``SchedulerConfig.incremental_queues`` convention: the fast paths are
    the default and ``False`` keeps the reference implementation for
    equivalence tests and benchmarks.

    ``readback_lag`` is how many decode iterations sampled tokens may trail
    on device before the host materializes them; ``max_pending`` is the
    hard cap on undrained *dispatches* (a K-iteration megastep window
    counts once; beyond it the host accepts one blocking sync rather than
    queueing unboundedly).

    ``decode_megastep`` is the max fused decode iterations per dispatch
    (1 = the per-iteration async path; requires ``async_decode``).
    ``incremental_chunk_prefill=False`` makes every prompt chunk recompute
    its full prefix instead of attending over the seeded cache view — the
    reference path the incremental one is equivalence-tested against
    (it also covers the recurrent state-carry chunk path).
    ``packed_chunk_prefill=False`` keeps the one-call-per-chunk reference
    path: by default a wave of >= 2 chunk grants in one iteration runs as
    ONE token-packed dispatch with per-segment prefix views.

    ``host_swap`` enables the host-offload KV swap tier (rung 2 of the
    pressure-degradation ladder): when a swapped/evicted GT loses its
    engine slot its live cache pages are captured to a bounded host pool
    and restored on next schedule instead of recomputed. It only replaces
    recompute with a bitwise-identical page restore, so it is on by
    default; ``host_pool_frac`` sizes the pool relative to device KVC.
    ``swap_watermarks`` additionally arms the proactive
    ``WatermarkGuard`` controller (EWMA'd occupancy, high/low hysteresis)
    that swaps waiting GTs out *before* allocation failures force
    reactive preemption, holding them out of admission until pressure
    releases — at most ``guard_max_swaps`` victims per trip observation.
    """
    async_decode: bool = True
    packed_prefill: bool = True
    readback_lag: int = 2
    max_pending: int = 8
    decode_megastep: int = 8
    incremental_chunk_prefill: bool = True
    packed_chunk_prefill: bool = True
    # --- tiered KVC degradation (host swap + watermark guard) ----------
    host_swap: bool = True
    host_pool_frac: float = 1.0
    swap_watermarks: bool = False
    guard_high: float = 0.92
    guard_low: float = 0.70
    guard_alpha: float = 0.5
    guard_patience: int = 2
    guard_max_swaps: int = 2


@dataclass
class GenRequest:
    prompt: List[int]
    params: SamplingParams = field(default_factory=SamplingParams)
    rid: int = -1
    output: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None
    # --- fault tolerance / SLO enforcement -----------------------------
    deadline: float = float("inf")   # absolute (iteration-clock) deadline
    status: Optional[str] = None     # terminal: completed | aborted | shed
    fail_reason: Optional[str] = None

    @property
    def finished(self) -> bool:
        return self.status is not None or self.t_done is not None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[dict] = None, *,
                 max_batch: int = 8, capacity: int = 512,
                 scheduler_cfg: Optional[SchedulerConfig] = None,
                 variant: str = "full", impl: str = "xla",
                 rl_accuracy: float = 0.8, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.impl = impl
        self.max_batch = max_batch
        self.capacity = capacity
        self.ecfg = engine_cfg or EngineConfig()
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else model.init(cfg, key)
        self.key = jax.random.PRNGKey(seed + 1)

        scfg = scheduler_cfg or SchedulerConfig(
            kvc_tokens=max_batch * capacity, block_size=32,
            tfs=capacity, max_model_len=capacity,
            max_batch_reqs=max_batch)
        cost = CostModel(model=ModelProfile.from_config(cfg))
        self.scheduler = make_econoserve(scfg, cost, variant)
        self.predictor = NoisyPredictor(accuracy=rl_accuracy, seed=seed,
                                        bucket=scfg.bucket)

        # slot-based caches
        self.caches = model.init_cache(cfg, max_batch, capacity)
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_batch))
        # host mirrors of per-slot state. On the legacy sync path they are
        # authoritative; on the async path last_tok/pos are device-resident
        # (carried through the fused step) and the mirrors only hold
        # prefill-time values (temps/top_ks/eos drive the static sampling
        # flags without any device readback).
        self.pos = np.zeros(max_batch, np.int64)      # next absolute position
        self.last_tok = np.zeros(max_batch, np.int64)
        self.temps = np.zeros(max_batch, np.float32)  # per-slot sampling
        self.top_ks = np.zeros(max_batch, np.int32)
        self.requests: Dict[int, GenRequest] = {}
        self._rid = 0

        # right-padded / token-packed prefill is exact only for
        # pure-attention stacks (masking ignores pad positions and foreign
        # segments); recurrent blocks would fold them into their state, so
        # they get exact shapes
        self._pad_prefill = set(cfg.pattern()) <= {ATTN}
        self._async = self.ecfg.async_decode
        self._packed = self.ecfg.packed_prefill and self._pad_prefill
        self._prefill_shapes: Set[Tuple[int, int]] = set()
        # chunked prefill: incremental (prefix-view) execution needs an
        # attention-pure stack and non-ring caches (a ring prefix has no
        # identity-placement view); otherwise chunks recompute their prefix
        win = cfg.sliding_window
        self._chunk_incremental = (self.ecfg.incremental_chunk_prefill
                                   and self._pad_prefill
                                   and (win is None or capacity < win))
        # packed multi-request chunking: all of an iteration's chunk
        # grants flatten into one token-packed call with per-segment
        # prefix views (needs the incremental prefix path + packing)
        self._chunk_packed = (self.ecfg.packed_chunk_prefill
                              and self._chunk_incremental and self._packed)
        # pure-recurrent stacks (SSM/xLSTM, no attention or shared-attn
        # layers) chunk by carrying the per-request recurrent-state
        # snapshot across chunks — O(n) total instead of the O(n^2)
        # recompute fallback
        kinds = set(cfg.pattern())
        self._chunk_rec = (self.ecfg.incremental_chunk_prefill
                           and not (kinds & {ATTN})
                           and not model.num_shared_invocations(cfg))
        self._rec_state: Dict[int, dict] = {}       # rid -> state snapshot
        self._chunk_progress: Dict[int, int] = {}   # rid -> ctx tokens seeded
        self.n_prefill_chunks = 0
        self.n_chunk_calls = 0                      # chunk-prefill dispatches
        self.max_chunk_items_per_call = 0
        # decode megastep: K fused iterations per dispatch (async only)
        self._mega_max = max(1, int(self.ecfg.decode_megastep)) \
            if self.ecfg.async_decode else 1
        self._mega_toks: Optional[jax.Array] = None   # (Kmax, B) window
        self._mega_eos: Optional[np.ndarray] = None   # host (Kmax, B) flags
        self._mega_row = 0
        self._mega_left = 0
        # arrivals submitted while a window is open wait here (delivered
        # with their true arrival time once the window drains), as do KV
        # injections from a peer engine (cluster prefill→decode migration)
        self._arrivals: List[Tuple[Request, float]] = []
        self._pending_injects: List[Tuple[dict, float]] = []
        # aborts requested while a window is open are deferred the same
        # way (mutating batch membership mid-window would desync the
        # device state the window already computed against)
        self._pending_aborts: List[Tuple[int, float, str]] = []
        self.n_decode_dispatches = 0
        self.n_kv_exports = 0
        self.n_kv_injects = 0
        self.n_kv_rejects = 0        # corrupted KV images refused at inject
        self.n_aborted = 0
        self.n_shed = 0              # rung-4 terminal sheds (kvc-infeasible)
        self.n_prefill_waves = 0     # whole-prompt prefill dispatch waves

        # idempotent at-least-once delivery: every fleet-routed message
        # (submit / KV inject) carries a delivery key; a duplicated or
        # retransmitted copy of an already-accepted key is dropped here,
        # at the instance boundary, making delivery effectively
        # exactly-once. ``n_dup_completions`` counts second terminal
        # writers suppressed first-writer-wins — always zero unless the
        # dedup boundary leaked (audited by check_fleet_invariants).
        self._delivered: set = set()
        self.n_dup_deliveries = 0
        self.n_dup_completions = 0
        # fleet-level shed-retry tier: when the owning fleet enables
        # hand-back, rung-4 kvc-infeasible sheds are cancelled locally
        # (slot/KVC freed) but parked here non-terminal for the fleet to
        # re-route instead of being shed terminally
        self.fleet_shed_handback = False
        self.shed_handback: List[GenRequest] = []

        # host-offload KV swap tier (tiered KVC degradation, rung 2):
        # rid -> {"kv", "ctx", "crc"} page images captured when a
        # swapped/evicted GT loses its slot; restored by ``_swap_in``
        # instead of the rung-3 recompute re-prefill. Extents are
        # budgeted by the scheduler-side ``BlockKVC`` swap ledger.
        self._host_swap: Dict[int, dict] = {}
        kvc = self.scheduler.kvc
        kvc.host_pool_tokens = int(kvc.capacity_tokens
                                   * max(0.0, self.ecfg.host_pool_frac))
        self.guard = WatermarkGuard(
            high=self.ecfg.guard_high, low=self.ecfg.guard_low,
            alpha=self.ecfg.guard_alpha,
            patience=self.ecfg.guard_patience) \
            if self.ecfg.swap_watermarks else None
        self.n_swap_captures = 0     # page images offloaded to host
        self.n_swap_restores = 0     # restored via swap-in (no recompute)
        self.n_swap_rejects = 0      # corrupt host image -> recompute rung
        self.n_swap_drops = 0        # budget-refused capture -> recompute
        # chaos ``squeeze`` arriving inside an open megastep window is
        # deferred: eating free blocks mid-window could invalidate the
        # extension headroom the fused rows were certified against
        self._pending_squeeze = 0.0

        # async bookkeeping: device slot state carried across the fused
        # steps, plus the lag-N readback ring of (tokens, [(row, rid)]).
        # The PRNG key rides along so the steady-state loop does not even
        # dispatch a host-side split — the fused step splits in-graph,
        # consuming the exact same key stream as the sync path (prefill
        # swaps the carried leaf without materializing it).
        self._dev = {
            "last_tok": jnp.zeros(max_batch, jnp.int32),
            "pos": jnp.zeros(max_batch, jnp.int32),
            "temps": jnp.zeros(max_batch, jnp.float32),
            "top_ks": jnp.zeros(max_batch, jnp.int32),
            "eos": jnp.full(max_batch, -1, jnp.int32),
            "key": self.key,
        }
        self._active_bytes: Optional[bytes] = None
        self._active_dev: Optional[jax.Array] = None
        # ring entries: (tokens, row, [(slot_row, rid)]). ``tokens`` is a
        # (B,) sampled batch (row None) or a (Kmax, B) megastep window
        # matrix shared by K entries, with ``row`` selecting the iteration.
        self._pending_drain: Deque[Tuple[jax.Array, Optional[int],
                                         List[Tuple[int, int]]]] = deque()
        # host-sync instrumentation (what the hot-path microbench reports).
        # Drain categories are classified deterministically at ENQUEUE
        # time from the dispatch sequence alone (PR 8 classified at drain
        # time via ``is_ready()``, which races with device timing and made
        # the per-category split machine-dependent; the total was stable):
        # eos_flags          — EOS-flag readbacks: one (B,) vector per
        #                      iteration, or one (K, B) matrix per megastep
        #                      window (only when an active request has an
        #                      eos_token)
        # drain_blocking     — pipeline-serializing materializations: the
        #                      legacy sync path's per-iteration sample
        #                      readback (the async ring never serializes —
        #                      this stays 0 on the async path)
        # drain_backpressure — ring entries enqueued while an *older
        #                      distinct dispatch* was still inside the lag
        #                      window: any wait their drain takes happens
        #                      with the device already fed
        # drain_ready        — ring entries whose whole lag window is
        #                      their own dispatch (or empty): lag-aged
        #                      copies by construction
        # flush              — forced full drains (completion/preemption/
        #                      idle)
        self.sync_counts = {"eos_flags": 0, "drain_blocking": 0,
                            "drain_backpressure": 0,
                            "drain_ready": 0, "flush": 0}
        # enqueue-time drain classification state: a monotone dispatch
        # sequence plus the last ``readback_lag`` enqueued sequence ids
        # (never buffer identities — Python id() reuse is allocator-timing
        # dependent)
        self._drain_seq = 0
        self._recent_drain_seqs: Deque[int] = deque(
            maxlen=max(1, self.ecfg.readback_lag))
        self.n_tokens_drained = 0    # tokens materialized through the ring
        self.decode_iters = 0
        # metrics plane (repro.obs): an attached MetricsSampler is invoked
        # at the end of every step — host-side reads only, no device ops,
        # so metrics-on stays bitwise-identical with zero added syncs
        self.metrics = None

        def _decode_fn(p, tok, pos, caches, active):
            """Legacy sync decode step with inactive slots masked out of the
            cache update. Attention writes to idle slots were merely
            wasteful (idempotent); recurrent states (SSM/xLSTM) would be
            silently corrupted by spurious h <- f(h, x) advances."""
            logits, new_caches = model.decode_step(cfg, p, tok, pos, caches,
                                                   impl=impl)

            def sel(old, new):
                m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)

            return logits, jax.tree.map(sel, caches, new_caches)

        self._decode = jax.jit(_decode_fn)

        def _one_iter(p, caches, st, active, need_sample, need_topk):
            """One fused async decode iteration: forward pass, masked cache
            update, in-graph RNG split + sampling, EOS check and pos
            advance in one traced body. Shared verbatim by the single-step
            program and the megastep while_loop so both produce bitwise-
            identical results."""
            toks = st["last_tok"][:, None]
            logits, new_caches = model.decode_step(cfg, p, toks, st["pos"],
                                                   caches, impl=impl)

            def sel(old, new):
                m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)

            new_caches = jax.tree.map(sel, caches, new_caches)
            temps = jnp.where(active, st["temps"], 0.0)
            top_ks = jnp.where(active, st["top_ks"], 0)
            key, sk = jax.random.split(st["key"])
            new = sample_in_graph(logits, sk, temps, top_ks,
                                  need_sample, need_topk)
            eos_hit = active & (st["eos"] >= 0) & (new == st["eos"])
            st = dict(st,
                      last_tok=jnp.where(active, new, st["last_tok"]),
                      pos=st["pos"] + active.astype(st["pos"].dtype),
                      key=key)
            return new_caches, st, new, eos_hit

        self._fused = jax.jit(_one_iter, static_argnums=(4, 5),
                              donate_argnums=(1, 2))

        Kmax = self._mega_max

        def _mega_fn(p, caches, st, active, k_iters, need_sample, need_topk,
                     stop_on_eos):
            """Decode megastep: run up to ``k_iters`` (dynamic, <= Kmax)
            fused iterations in ONE dispatched while_loop, collecting each
            iteration's sampled tokens and EOS flags into (Kmax, B)
            buffers the host replays the scheduler against. ``caches`` and
            ``st`` are donated exactly as in the single-step program.

            ``stop_on_eos`` (static): under memory pressure (non-empty
            queues certified KVC-blocked) an EOS completion frees KVC that
            the K=1 path would hand to a waiter at the very next
            iteration, so the loop exits after the iteration where EOS
            fired — the carried RNG key and caches then advanced exactly
            as many times as the per-iteration path, and the host resumes
            fresh scheduling there (it recovers the executed count from
            the EOS matrix; rows past the exit stay zero)."""
            def cond(c):
                return (c[0] < k_iters) & ~c[1]

            def body(c):
                i, stop, caches, st, tb, eb = c
                caches, st, new, eos_hit = _one_iter(
                    p, caches, st, active, need_sample, need_topk)
                if stop_on_eos:
                    stop = jnp.any(eos_hit)
                return (i + 1, stop, caches, st,
                        tb.at[i].set(new), eb.at[i].set(eos_hit))

            init = (jnp.int32(0), jnp.asarray(False), caches, st,
                    jnp.zeros((Kmax, max_batch), jnp.int32),
                    jnp.zeros((Kmax, max_batch), bool))
            _, _, caches, st, tb, eb = jax.lax.while_loop(cond, body, init)
            return caches, st, tb, eb

        self._mega = jax.jit(_mega_fn, static_argnums=(5, 6, 7),
                             donate_argnums=(1, 2))

        def _seed_slots_fn(st, slots, first, fallback, use_first, poss,
                           temps, top_ks, eos):
            """Scatter prefill results into the carried device slot state
            (async path) — the first sampled token stays on device; rows
            re-prefilled after a preemption restore their last generated
            token from the host-known ``fallback``."""
            last = jnp.where(use_first, first, fallback)
            return dict(
                st,
                last_tok=st["last_tok"].at[slots].set(last, mode="drop"),
                pos=st["pos"].at[slots].set(poss, mode="drop"),
                temps=st["temps"].at[slots].set(temps, mode="drop"),
                top_ks=st["top_ks"].at[slots].set(top_ks, mode="drop"),
                eos=st["eos"].at[slots].set(eos, mode="drop"))

        self._seed_slots = jax.jit(_seed_slots_fn, donate_argnums=(0,))

        def _prefill_fn(p, toks, lens):
            logits, caches = model.prefill(cfg, p, toks, impl=impl)
            last = logits[jnp.arange(toks.shape[0]), lens - 1]
            return last, caches

        self._prefill = jax.jit(_prefill_fn)

        def _prefill_packed_fn(p, toks, pos, seg, last_idx):
            """Token-packed prefill: toks/pos/seg (1, T) with per-segment
            positions and segment ids; last_idx (Bb,) flat indices of each
            prompt's final token (pad rows point at 0 and are dropped by
            the caller's slot scatter)."""
            logits, caches = model.prefill(cfg, p, toks, impl=impl,
                                           positions=pos, segment_ids=seg)
            return logits[0, last_idx], caches

        self._prefill_packed = jax.jit(_prefill_packed_fn)

        def _chunk_fn(p, caches, toks, pos, slot, start, length):
            """Incremental chunk prefill + in-place seed: the chunk's
            queries attend over the slot's already-seeded cache prefix
            (slots [0, start)), and the chunk's K/V land at absolute slots
            [start, start+length) of the same donated cache row. Returns
            (caches, last-real-token logits)."""
            prefix = {kind: {n: jax.lax.dynamic_index_in_dim(
                sub[n], slot, axis=1, keepdims=True) for n in ("k", "v")}
                for kind, sub in caches.items()}
            logits, pf = model.prefill(cfg, p, toks, impl=impl,
                                       positions=pos, prefix_caches=prefix,
                                       prefix_len=start)
            last = logits[0, length - 1]
            Sb = toks.shape[1]
            out = {}
            for kind, sub in caches.items():
                C = sub["k"].shape[2]
                # pad positions (>= length) index C: out of bounds, dropped
                di = jnp.where(jnp.arange(Sb) < length,
                               jnp.minimum(start + jnp.arange(Sb), C), C)
                out[kind] = {n: sub[n].at[:, slot, di].set(
                    pf[kind][n][:, 0].astype(sub[n].dtype), mode="drop")
                    for n in ("k", "v")}
            return out, last

        self._chunk_prefill = jax.jit(_chunk_fn, donate_argnums=(1,))

        def _chunks_packed_fn(p, caches, toks, pos, seg, ppos, pseg, slots,
                              last_idx, src_idx, dst_idx):
            """Packed multi-request chunk prefill + seed: all chunk grants
            of an iteration run as ONE token-packed (1, T) call whose key
            axis prepends every segment's own cache-prefix view (gathered
            from the donated caches and block-diagonally masked via
            ``pseg``/``ppos`` — POS_INVALID beyond each seeded prefix);
            each chunk's K/V then scatter into its slot's row at
            [start, start+len) in the same donated program. Returns
            (caches, per-segment last-real-token logits)."""
            n = slots.shape[0]
            Cp = ppos.shape[1] // n
            prefix = {}
            for kind, sub in caches.items():
                prefix[kind] = {}
                for nm in ("k", "v"):
                    rows = jnp.take(sub[nm], slots, axis=1)  # (L,n,C,K,hd)
                    rows = jax.lax.slice_in_dim(rows, 0, Cp, axis=2)
                    L, _, _, Kh, hd = rows.shape
                    prefix[kind][nm] = rows.reshape(L, 1, n * Cp, Kh, hd)
            logits, pf = model.prefill(cfg, p, toks, impl=impl,
                                       positions=pos, segment_ids=seg,
                                       prefix_caches=prefix,
                                       prefix_positions=ppos,
                                       prefix_segment_ids=pseg)
            last = logits[0, last_idx]
            out = {}
            for kind, sub in caches.items():
                out[kind] = {}
                for nm in ("k", "v"):
                    # (L, n, W, K, hd) spans gathered from the packed axis;
                    # dst positions past each chunk's length index C (drop)
                    rows = jnp.take(pf[kind][nm][:, 0], src_idx, axis=1)
                    out[kind][nm] = sub[nm].at[
                        :, slots[:, None], dst_idx].set(
                        rows.astype(sub[nm].dtype), mode="drop")
            return out, last

        self._chunks_packed = jax.jit(_chunks_packed_fn, donate_argnums=(1,))

        def _rec_chunk_fn(p, states, toks):
            """Recurrent (SSM/xLSTM) chunk prefill resuming from the
            carried per-request state snapshot — the chunk continues the
            recurrence instead of recomputing its prefix. Exact shapes
            (recurrent stacks are not pad-tolerant), donated states."""
            logits, out_states = model.prefill(cfg, p, toks, impl=impl,
                                               prefix_caches=states)
            return out_states, logits[0, toks.shape[1] - 1]

        self._rec_chunk = jax.jit(_rec_chunk_fn, donate_argnums=(1,))

        def _inject_fn(caches, kv, slot, length):
            """Seed a migrated request's KV image into one cache row in a
            single donated program (cluster prefill→decode handoff). kv
            leaves are (L, Sb, K, hd) with real data in [0, length); pad
            positions index C and are dropped."""
            out = {}
            for kind, sub in caches.items():
                C = sub["k"].shape[2]
                Sb = kv[kind]["k"].shape[1]
                di = jnp.where(jnp.arange(Sb) < length, jnp.arange(Sb), C)
                out[kind] = {n: sub[n].at[:, slot, di].set(
                    kv[kind][n].astype(sub[n].dtype), mode="drop")
                    for n in ("k", "v")}
            return out

        self._inject_seed = jax.jit(_inject_fn, donate_argnums=(0,))
        self._seed = jax.jit(self._seed_fn, donate_argnums=(0,))
        self._seed_packed = jax.jit(self._seed_packed_fn,
                                    donate_argnums=(0,))

    @property
    def n_prefill_compiles(self) -> int:
        """Distinct (batch, seq) prefill shapes traced so far."""
        return len(self._prefill_shapes)

    @property
    def n_blocking_syncs(self) -> int:
        """Host syncs that can leave the device idle (EOS-flag readbacks +
        pipeline-serializing token drains). Zero across a steady-state
        async decode window with no EOS-capable requests. Backpressure
        drains — waits taken while newer dispatches were already queued on
        the device — are counted separately (``drain_backpressure``): the
        device stays fed through them."""
        return (self.sync_counts["eos_flags"]
                + self.sync_counts["drain_blocking"])

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float,
               dkey: Optional[tuple] = None) -> int:
        """Register a request. While a fused megastep window is open the
        scheduler must not see the arrival (its admission would change
        batch membership the device already computed past): the arrival is
        buffered — with its true arrival time, so ordering/SLO math is
        unaffected — and delivered when the window drains, at most
        ``decode_megastep - 1`` iterations later. This is the standard
        multi-step-scheduling trade (scheduling decisions every K steps).

        ``dkey`` is the fleet transport's delivery key: a duplicated
        copy of an already-accepted delivery is dropped here (returns
        -1) before it can touch any engine state."""
        if dkey is not None:
            if dkey in self._delivered:
                self.n_dup_deliveries += 1
                return -1
            self._delivered.add(dkey)
        self.validate(req)
        req.rid = self._rid
        self._rid += 1
        req.t_submit = now
        r = Request(rid=req.rid, prompt_len=len(req.prompt),
                    true_rl=req.params.max_new_tokens, arrival=now,
                    slo_deadline=req.deadline)
        r.predicted_rl = self.predictor.predict(r)
        r.padded_rl = apply_padding(r.predicted_rl,
                                    self.scheduler.cfg.pad_ratio,
                                    self.scheduler.cfg.bucket)
        self.requests[req.rid] = req
        if self._mega_left > 0:
            self._arrivals.append((r, now))
        else:
            self.scheduler.on_arrival(r, now)
        return req.rid

    def validate(self, req: GenRequest) -> None:
        """Reject malformed requests with a typed error at the submit
        boundary — the engine's shape machinery assumes a non-empty
        prompt that fits its cache row and KVC, and a positive token
        budget; violating any of these used to surface as a deep
        scatter/shape failure mid-iteration."""
        if req.params.max_new_tokens <= 0:
            raise InvalidRequestError(
                f"max_new_tokens must be >= 1, got "
                f"{req.params.max_new_tokens}")
        if not req.prompt:
            raise InvalidRequestError("empty prompt")
        kvc_cap = self.scheduler.kvc.capacity_tokens
        if len(req.prompt) + 1 > min(self.capacity, kvc_cap):
            raise InvalidRequestError(
                f"prompt of {len(req.prompt)} tokens (+1 response token) "
                f"exceeds capacity (cache row {self.capacity} slots, "
                f"KVC {kvc_cap} tokens)")

    def has_work(self) -> bool:
        """Scheduler work plus arrivals/injections/aborts buffered behind
        an open window."""
        return (self.scheduler.has_work() or bool(self._arrivals)
                or bool(self._pending_injects)
                or bool(self._pending_aborts))

    # ------------------------------------------------------------------ #
    # abort / cancellation (deadline enforcement, crash recovery)
    # ------------------------------------------------------------------ #
    def abort(self, rid: int, now: float, reason: str = "aborted") -> bool:
        """Cancel an in-flight request: force-drain the token ring (lag-N
        entries for the victim must materialize, never drop), detach it
        from the scheduler (freeing KVC) and release its engine slot.

        While a fused megastep window is open the abort is *deferred* —
        mutating batch membership mid-window would desync the device
        state the window precomputed — and applied when the window
        drains, exactly like deferred arrivals/injects. If the request
        completes inside the remaining window rows, completion wins and
        the abort becomes a no-op (terminal state stays exactly-once).

        Returns True when the abort was applied or queued, False when the
        rid is unknown or already terminal."""
        g = self.requests.get(rid)
        if g is None or g.finished:
            return False
        if self._mega_left > 0:
            if not any(p[0] == rid for p in self._pending_aborts):
                self._pending_aborts.append((rid, now, reason))
            return True
        self._apply_abort(rid, now, reason)
        return True

    def _apply_abort(self, rid: int, now: float, reason: str) -> None:
        assert self._mega_left == 0, "abort applied inside an open window"
        g = self.requests.get(rid)
        if g is None or g.finished:
            return                    # completed while the abort waited
        if self._pending_drain:
            # materialize ring tokens first: g.output must be complete
            # before the request leaves the engine (satellite: lag-N ring
            # entries for aborted requests are never dropped)
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        for k, (r, _) in enumerate(self._arrivals):
            if r.rid == rid:          # still buffered behind a window
                self._arrivals.pop(k)
                break
        else:
            self.scheduler.cancel(rid, now)
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self.free_slots.append(slot)
        self._chunk_progress.pop(rid, None)
        self._rec_state.pop(rid, None)
        self._host_swap.pop(rid, None)   # ledger entry dropped by cancel()
        g.status = "aborted"
        g.fail_reason = reason
        self.n_aborted += 1

    # ------------------------------------------------------------------ #
    # KV migration (cluster disaggregated prefill/decode roles)
    # ------------------------------------------------------------------ #
    @property
    def can_migrate_kv(self) -> bool:
        """A portable KV image needs identity cache placement: an
        attention-pure stack (recurrent states are not positionally
        addressable the same way) and non-ring caches (a sliding-window
        ring's layout depends on this engine's capacity)."""
        win = self.cfg.sliding_window
        return self._pad_prefill and (win is None or self.capacity < win)

    def export_kv(self, rid: int) -> dict:
        """Extract a queued GT's KV pages + carried slot state so a peer
        engine can continue decoding it (prefill→decode disaggregation),
        and remove the request from this engine and its scheduler.

        The returned payload feeds ``inject_kv``. ``payload["kv"]`` is the
        per-cache-kind {k, v} image of the request's first ``ctx`` context
        slots, or None when this engine cannot produce a portable image
        (recurrent stack, ring caches, or a request that lost its slot to
        preemption) — the receiver then falls back to the swap-recompute
        path, exactly like a swap-preempted GT.

        Must not be called while a fused megastep window is open: freeing
        the exported request's KVC mid-window could admit a waiter the
        window's precomputed rows never saw (``submit``/``inject_kv``
        defer for the same reason; export must return synchronously, so
        it asserts instead). Fleet callers only export from prefill-role
        instances, which never decode and so never open windows."""
        assert self._mega_left == 0, \
            "export_kv during an open megastep window"
        sched = self.scheduler
        req = next(r for r in sched.gt_queue if r.rid == rid)
        if self._pending_drain:
            # the payload must carry every token generated so far (the
            # receiver's recompute fallback rebuilds context from g.output)
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        g = self.requests.pop(rid)
        slot = self.slot_of.pop(rid, None)
        kv = crc = None
        if slot is not None:
            if self._async:
                ctx = int(jax.device_get(self._dev["pos"][slot]))
                last = int(jax.device_get(self._dev["last_tok"][slot]))
            else:
                ctx = int(self.pos[slot])
                last = int(self.last_tok[slot])
            if self.can_migrate_kv:
                kv = {kind: {n: np.asarray(sub[n][:, slot, :ctx])
                             for n in ("k", "v")}
                      for kind, sub in self.caches.items()}
                crc = kv_checksum(kv)
            self.free_slots.append(slot)
        else:
            ctx = req.prompt_len + req.generated - 1
            last = g.output[req.generated - 1]
            # a host-offloaded image survives the slot loss: ship it (with
            # its capture-time CRC — recomputing here would vouch for a
            # corrupted pool) instead of sentencing the receiver to the
            # recompute fallback
            img = self._host_swap.pop(rid, None)
            if (img is not None and self.can_migrate_kv
                    and img["ctx"] == ctx):
                kv, crc = img["kv"], img["crc"]
        sched.gt_queue.remove(req)
        sched.kvc.free(rid)
        sched.kvc.swap_release(rid)
        sched.swap_hold.pop(rid, None)
        self._chunk_progress.pop(rid, None)
        self._rec_state.pop(rid, None)
        self._host_swap.pop(rid, None)
        req.occupied_kvc = req.prompt_len + req.generated
        self.n_kv_exports += 1
        return {"gen": g, "req": req, "kv": kv, "ctx": ctx,
                "last_tok": last, "kv_crc": crc}

    def inject_kv(self, payload: dict, now: float) -> Optional[int]:
        """Receive a migrated request. With a KV image (and a free slot +
        KVC room) the request becomes a queued GT whose decode continues
        from the injected pages; otherwise it queues with its KV "in host
        memory" and the engine's existing swap-recompute path re-prefills
        prompt + generated on first schedule. Deferred while a fused
        megastep window is open (same contract as ``submit``); returns the
        assigned rid, or None when deferred — or when the payload is a
        duplicated delivery (its ``dkey`` was already accepted — dedup
        happens here, before deferral, so a dup'd inject cannot even be
        double-buffered behind a window)."""
        dkey = payload.get("dkey")
        if dkey is not None:
            if dkey in self._delivered:
                self.n_dup_deliveries += 1
                return None
            self._delivered.add(dkey)
        if self._mega_left > 0:
            self._pending_injects.append((payload, now))
            return None
        return self._apply_inject(payload, now)

    def _apply_inject(self, payload: dict, now: float) -> int:
        g: GenRequest = payload["gen"]
        req: Request = payload["req"]
        rid = self._rid
        self._rid += 1
        g.rid = rid
        req.rid = rid
        self.requests[rid] = g
        sched = self.scheduler
        tokens = req.prompt_len + req.generated
        kv = payload["kv"]
        ctx = payload["ctx"]
        if kv is not None:
            crc = payload.get("kv_crc")
            if crc is not None and kv_checksum(kv) != crc:
                # corrupted in transit: refuse the image and degrade to
                # the recompute fallback — the host-side token stream is
                # the ground truth, so the output stays bitwise-correct
                kv = None
                self.n_kv_rejects += 1
        if (kv is not None and self.can_migrate_kv and self.free_slots
                and ctx <= self.capacity and sched.kvc.can_allocate(tokens)):
            sched.kvc.allocate(rid, tokens)
            sched.kvc.set_used(rid, tokens)
            slot = self.free_slots.pop()
            self.slot_of[rid] = slot
            # pad the image to a pow2 token bucket (clamped to capacity)
            # so the donated seeding program compiles <= log2(capacity)
            # times, mirroring the chunk-prefill shape policy
            Sb = seq_bucket(ctx)
            if Sb > self.capacity:
                Sb = max(ctx, self.capacity)
            padded = {}
            for kind, sub in kv.items():
                L, _, K, hd = sub["k"].shape
                padded[kind] = {}
                for n in ("k", "v"):
                    buf = np.zeros((L, Sb, K, hd), sub[n].dtype)
                    buf[:, :ctx] = sub[n]
                    padded[kind][n] = buf
            self.caches = self._inject_seed(self.caches, padded,
                                            np.int32(slot), np.int32(ctx))
            self.temps[slot] = g.params.temperature
            self.top_ks[slot] = g.params.top_k
            self.pos[slot] = ctx
            last = payload["last_tok"]
            if self._async:
                eos = -1 if g.params.eos_token is None else g.params.eos_token
                one = np.asarray([last], np.int32)
                self._dev = self._seed_slots(
                    self._dev, np.asarray([slot], np.int32),
                    jnp.asarray(one), jnp.asarray(one),
                    np.zeros(1, bool), np.asarray([ctx], np.int32),
                    np.asarray([g.params.temperature], np.float32),
                    np.asarray([g.params.top_k], np.int32),
                    np.asarray([eos], np.int32))
            else:
                self.last_tok[slot] = last
        else:
            # swap-recompute fallback: the request queues holding no KVC,
            # its KV notionally in host memory; when scheduled it arrives
            # in plan.decode_reqs without a slot and the engine re-prefills
            # prompt + generated (the existing preemption path)
            req.prompt_done = req.prompt_len
        req.occupied_kvc = tokens
        req.set_state(State.QUEUED_GT, now)
        sched.enqueue_gt(req)
        self.n_kv_injects += 1
        return rid

    # ------------------------------------------------------------------ #
    # host-offload KV swap tier (pressure ladder rung 2)
    # ------------------------------------------------------------------ #
    def _core_req(self, rid: int):
        """The scheduler-side Request still queued under ``rid`` (None
        when completed/aborted). ``gt_queue`` is an O(1)-indexed
        ``OrderedQueue`` on the default config, a plain list otherwise."""
        q = self.scheduler.gt_queue
        get = getattr(q, "get", None)
        if get is not None:
            return get(rid)
        return next((r for r in q if r.rid == rid), None)

    def _swap_out(self, rid: int, slot: int) -> None:
        """Rung-2 capture: offload a de-slotted GT's live cache pages to
        the bounded host pool before the slot is recycled. A refused
        capture (image over budget, recurrent/ring stack, offload-free
        preemption) falls through to rung 3 — the request recomputes on
        next schedule, exactly the pre-swap behavior."""
        if not (self.ecfg.host_swap and self.can_migrate_kv):
            return
        req = self._core_req(rid)
        if (req is None or req.prompt_done != req.prompt_len
                or req.generated < 1):
            return                     # offload-free preempt or terminal
        # the newest sampled token's KV was never written to cache — it is
        # the pending decode input (same invariant as export_kv/recompute)
        ctx = req.prompt_len + req.generated - 1
        if ctx <= 0 or ctx > self.capacity:
            return
        evicted = self.scheduler.kvc.swap_register(rid, ctx)
        if evicted is None:
            self.n_swap_drops += 1     # budget refusal -> recompute rung
            return
        for old in evicted:            # ledger evictions degrade a rung
            self._host_swap.pop(old, None)
        # blocks until the slot's dispatched decode work has landed, so
        # the image holds exactly ctx tokens of KV — a sync only paid on
        # the preemption path, never in the no-swap steady state
        kv = {kind: {n: np.asarray(sub[n][:, slot, :ctx])
                     for n in ("k", "v")}
              for kind, sub in self.caches.items()}
        self._host_swap[rid] = {"kv": kv, "ctx": ctx,
                                "crc": kv_checksum(kv)}
        self.n_swap_captures += 1

    def _swap_in(self, missing: List[Request], now: float) -> List[Request]:
        """Rung-2 restore: re-seed scheduled GTs whose KV pages are in the
        host pool, instead of the rung-3 recompute re-prefill. A corrupt
        or missing image degrades one rung (the request stays in
        ``missing`` and recomputes); a good image seeds exactly like a
        cluster KV inject, so greedy token streams stay bitwise-equal to
        the pressure-free run. Returns the requests left to recompute."""
        sched = self.scheduler
        left = []
        for r in missing:
            img = self._host_swap.pop(r.rid, None)
            if img is None:
                sched.kvc.swap_release(r.rid)   # evicted image, if any
                left.append(r)
                continue
            ctx = img["ctx"]
            ok = (self.can_migrate_kv and bool(self.free_slots)
                  and 0 < ctx <= self.capacity and r.generated >= 1
                  and kv_checksum(img["kv"]) == img["crc"])
            sched.kvc.swap_release(r.rid, restored=ok)
            if not ok:
                self.n_swap_rejects += 1        # corrupt image -> rung 3
                left.append(r)
                continue
            g = self.requests[r.rid]
            slot = self.free_slots.pop()
            self.slot_of[r.rid] = slot
            Sb = seq_bucket(ctx)
            if Sb > self.capacity:
                Sb = max(ctx, self.capacity)
            padded = {}
            for kind, sub in img["kv"].items():
                L, _, K, hd = sub["k"].shape
                padded[kind] = {}
                for n in ("k", "v"):
                    buf = np.zeros((L, Sb, K, hd), sub[n].dtype)
                    buf[:, :ctx] = sub[n]
                    padded[kind][n] = buf
            self.caches = self._inject_seed(self.caches, padded,
                                            np.int32(slot), np.int32(ctx))
            self.temps[slot] = g.params.temperature
            self.top_ks[slot] = g.params.top_k
            self.pos[slot] = ctx
            last = g.output[r.generated - 1]
            if self._async:
                eos = -1 if g.params.eos_token is None \
                    else g.params.eos_token
                one = np.asarray([last], np.int32)
                self._dev = self._seed_slots(
                    self._dev, np.asarray([slot], np.int32),
                    jnp.asarray(one), jnp.asarray(one),
                    np.zeros(1, bool), np.asarray([ctx], np.int32),
                    np.asarray([g.params.temperature], np.float32),
                    np.asarray([g.params.top_k], np.int32),
                    np.asarray([eos], np.int32))
            else:
                self.last_tok[slot] = last
            t_in = sched.cost.swap_in_time(ctx)    # in leg charged here
            sched.pending_extra_time += t_in
            r.swap_time += t_in
            self.n_swap_restores += 1
        return left

    def _guard_step(self, now: float) -> None:
        """Watermark-guard observation at a window boundary: under
        pressure, proactively swap the heaviest waiting GTs out (their
        pages are captured immediately — slot and KVC free before this
        iteration's admissions run); on release, give held requests back
        to the admission path. Only runs when ``_mega_left == 0``, so a
        K=8 fused run observes the same occupancy sequence as K=1."""
        sched = self.scheduler
        if sched.kvc.total_blocks <= 0:
            return
        if self.guard.observe(sched.kvc.allocated_frac):
            for v in sched.swap_victims(self.ecfg.guard_max_swaps):
                sched.guard_swap_out(v, now)
                slot = self.slot_of.pop(v.rid, None)
                if slot is not None:
                    self.free_slots.append(slot)
                    self._chunk_progress.pop(v.rid, None)
                    self._rec_state.pop(v.rid, None)
                    self._swap_out(v.rid, slot)
        elif sched.swap_hold:
            sched.release_swap_holds()

    def squeeze_kvc(self, frac: float) -> int:
        """Chaos ``squeeze``: permanently remove ``frac`` of the KVC
        capacity. Free blocks go immediately; the remainder is harvested
        as live allocations free (``BlockKVC.pending_shrink``), so no
        holder is evicted mid-decode. Deferred while a fused megastep
        window is open — eating free blocks mid-window could invalidate
        the extension headroom the precomputed rows were certified
        against. Returns blocks removed immediately (0 when deferred)."""
        if self._mega_left > 0:
            self._pending_squeeze += float(frac)
            return 0
        kvc = self.scheduler.kvc
        return kvc.shrink(int(kvc.capacity_tokens * frac))

    # ------------------------------------------------------------------ #
    def _is_ring(self, kind: str, sub) -> bool:
        """A cache row is a sliding-window ring buffer when its capacity
        equals the window (shared-attention caches are always full-size)."""
        win = self.cfg.sliding_window
        return kind == ATTN and win is not None and sub["k"].shape[2] == win

    @staticmethod
    def _ring_index(plen, s_idx, C):
        """Within-sequence source index for seeding a C-slot ring buffer
        from a plen-token prefill: token p of the real tail lands at ring
        slot p % C; rows with plen <= C keep identity placement."""
        return jnp.where(plen > C,
                         (plen - C) + jnp.mod(s_idx - plen, C),
                         jnp.minimum(s_idx, jnp.maximum(plen - 1, 0)))

    def _seed_fn(self, caches, pf_caches, slots, lens):
        """Scatter a whole prefill batch into the decode caches at once.

        slots (Bb,) int32 destination rows; pad rows carry ``max_batch``
        (past-the-end, dropped via mode="drop"); lens (Bb,) true context
        lengths (pad positions beyond them carry junk that decode masking
        never reads).
        """
        def seq_scatter(dst, src, ring):
            # dst (L, B, C, K, hd); src (L, Bb, S, K, hd)
            C, S = dst.shape[2], src.shape[2]
            s_idx = jnp.arange(C)[None, :]                      # (1, C)
            plen = lens[:, None]                                # (Bb, 1)
            if ring and S > C:
                j = self._ring_index(plen, s_idx, C)
            else:
                # identity placement; slots beyond S (or beyond plen, for
                # padded prefill) hold junk that decode masking never reads
                j = jnp.broadcast_to(jnp.minimum(s_idx, S - 1),
                                     (src.shape[1], C))
            rows = jnp.take_along_axis(
                src, j[None, :, :, None, None], axis=2)
            return dst.at[:, slots].set(rows.astype(dst.dtype), mode="drop")

        def plain_scatter(dst, src):
            return dst.at[:, slots].set(src.astype(dst.dtype), mode="drop")

        out = {}
        for kind, sub in caches.items():
            if kind in (ATTN, "shared"):
                ring = self._is_ring(kind, sub)
                out[kind] = {n: seq_scatter(sub[n], pf_caches[kind][n], ring)
                             for n in ("k", "v")}
            else:
                out[kind] = jax.tree.map(plain_scatter, sub, pf_caches[kind])
        return out

    def _seed_packed_fn(self, caches, pf_caches, slots, starts, lens):
        """Seed decode caches from a token-packed prefill: per-item spans
        of the flattened token axis are gathered and scattered into their
        slots. starts/lens (Bb,) flat span starts and true lengths; pad
        rows scatter to row ``max_batch`` (dropped)."""
        def span_scatter(dst, src, ring):
            # dst (L, B, C, K, hd); src (L, 1, T, K, hd)
            C, T = dst.shape[2], src.shape[2]
            s_idx = jnp.arange(C)[None, :]                      # (1, C)
            plen = lens[:, None]                                # (Bb, 1)
            if ring:
                within = self._ring_index(plen, s_idx, C)
            else:
                # identity placement within the span; cache slots beyond
                # plen repeat the last real token — junk the decode
                # masking never reads
                within = jnp.minimum(s_idx, jnp.maximum(plen - 1, 0))
            j = jnp.clip(starts[:, None] + within, 0, T - 1)    # (Bb, C)
            rows = jnp.take(src[:, 0], j, axis=1)   # (L, Bb, C, K, hd)
            return dst.at[:, slots].set(rows.astype(dst.dtype), mode="drop")

        out = {}
        for kind, sub in caches.items():
            assert kind in (ATTN, "shared"), \
                "packed prefill is gated to attention-only stacks"
            out[kind] = {n: span_scatter(sub[n], pf_caches[kind][n],
                                         self._is_ring(kind, sub))
                         for n in ("k", "v")}
        return out

    # ------------------------------------------------------------------ #
    def _run_prefill(self, items, now: float, missing=()) -> None:
        """Execute an iteration's PT items and seed their cache slots.

        Whole prompts (plus ``missing`` recompute re-prefills) run as ONE
        call: token-packed (flattened with a block-diagonal segment mask —
        no batch or length padding) when enabled, else padded
        (max_batch, seq_bucket) when the model tolerates padding;
        otherwise one exact-shape call per item. Partial (chunked) grants
        route through ``_run_chunk_items`` — one prefix-attending call per
        chunk.
        """
        whole = [(r, r.prompt_len) for r in missing]
        chunked = []
        for r, chunk in items:
            if (r.rid not in self._chunk_progress and r.prompt_done == 0
                    and chunk >= r.prompt_len):
                whole.append((r, chunk))
            else:
                chunked.append((r, chunk))
        if whole:
            self.n_prefill_waves += 1
            groups = [whole] if self._pad_prefill \
                else [[it] for it in whole]
            for group in groups:
                self._prefill_group(group, now)
        if chunked:
            self._run_chunk_items(chunked, now)

    def _prefill_group(self, group, now: float) -> None:
        ctxs, slots = [], []
        for r, chunk in group:
            assert chunk == r.prompt_len, \
                "partial chunks are routed through _run_chunk_items"
            g = self.requests[r.rid]
            # after an offload-free preemption the context to recompute is
            # prompt + generated-so-far MINUS the newest token: normal
            # decode writes token t's KV only when t is fed as the next
            # step's input, so the newest token's KV was never in cache —
            # it stays the pending decode input (seeding it too would make
            # the model see it at two positions and shift the stream)
            ctxs.append(list(g.prompt) + g.output[:max(0, r.generated - 1)])
            slot = self.free_slots.pop()
            self.slot_of[r.rid] = slot
            self.temps[slot] = g.params.temperature
            self.top_ks[slot] = g.params.top_k
            slots.append(slot)
        n = len(group)
        lens_true = [len(c) for c in ctxs]
        maxlen = max(lens_true)
        if self._pad_prefill:
            Bb = self.max_batch
        else:
            Bb = n
        # pad rows: len 1 (safe gather), scatter to row `max_batch` —
        # out of bounds, dropped via mode="drop"
        lens = np.ones(Bb, np.int32)
        slot_arr = np.full(Bb, self.max_batch, np.int32)
        for i in range(n):
            lens[i] = lens_true[i]
            slot_arr[i] = slots[i]
        if self._packed:
            starts_np = np.zeros(Bb, np.int32)
            last_idx = np.zeros(Bb, np.int32)
            off = 0
            for i in range(n):
                starts_np[i] = off
                off += lens_true[i]
                last_idx[i] = off - 1
            Tb = seq_bucket(off)
            toks = np.zeros((1, Tb), np.int32)
            pos = np.zeros((1, Tb), np.int32)
            seg = np.full((1, Tb), -1, np.int32)
            for i, ctx in enumerate(ctxs):
                s, L = starts_np[i], lens_true[i]
                toks[0, s:s + L] = ctx
                pos[0, s:s + L] = np.arange(L)
                seg[0, s:s + L] = i
            self._prefill_shapes.add((1, Tb))
            last_logits, pf_caches = self._prefill_packed(
                self.params, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(seg), jnp.asarray(last_idx))
            self.caches = self._seed_packed(
                self.caches, pf_caches, jnp.asarray(slot_arr),
                jnp.asarray(starts_np), jnp.asarray(lens))
        else:
            if self._pad_prefill:
                # pow2 bucket, clamped to capacity (a single extra bucket
                # shape) so the padded shape never exceeds the cache it seeds
                Sb = seq_bucket(maxlen)
                if Sb > self.capacity:
                    Sb = max(maxlen, self.capacity)
            else:
                Sb = maxlen
            toks = np.zeros((Bb, Sb), np.int32)
            for i, ctx in enumerate(ctxs):
                toks[i, :len(ctx)] = ctx
            self._prefill_shapes.add((Bb, Sb))
            last_logits, pf_caches = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens))
            self.caches = self._seed(self.caches, pf_caches,
                                     jnp.asarray(slot_arr),
                                     jnp.asarray(lens))
        if self._async:
            # consume the carried device key — same stream as the sync
            # path's self.key, no host materialization
            key, sk = jax.random.split(self._dev["key"])
            self._dev = dict(self._dev, key=key)
        else:
            self.key, sk = jax.random.split(self.key)
        temps = np.zeros(Bb, np.float32)
        top_ks = np.zeros(Bb, np.int32)
        eos = np.full(Bb, -1, np.int32)
        for i, (r, _) in enumerate(group):
            g = self.requests[r.rid]
            temps[i] = g.params.temperature
            top_ks[i] = g.params.top_k
            eos[i] = -1 if g.params.eos_token is None else g.params.eos_token
        first = sample_per_request(last_logits, sk, temps, top_ks)
        if self._async:
            # device path: the first token never touches the host here —
            # it is scattered into the carried slot state and drained with
            # the regular lag-N ring
            fallback = np.zeros(Bb, np.int32)
            use_first = np.zeros(Bb, bool)
            mapping: List[Tuple[int, int]] = []
            for i, (r, _) in enumerate(group):
                g = self.requests[r.rid]
                self.pos[slots[i]] = lens[i]
                if r.generated == 0:
                    # the PT iteration produces the first response token (§1)
                    use_first[i] = True
                    mapping.append((i, r.rid))
                else:
                    fallback[i] = g.output[r.generated - 1]
            self._dev = self._seed_slots(
                self._dev, jnp.asarray(slot_arr), first,
                jnp.asarray(fallback), jnp.asarray(use_first),
                jnp.asarray(lens), jnp.asarray(temps),
                jnp.asarray(top_ks), jnp.asarray(eos))
            if mapping:
                self._enqueue_drain(first, None, mapping)
        else:
            first_np = np.asarray(first)
            for i, (r, _) in enumerate(group):
                g = self.requests[r.rid]
                slot = slots[i]
                self.pos[slot] = lens[i]
                if r.generated == 0:
                    # the PT iteration produces the first response token (§1)
                    tok = int(first_np[i])
                    g.output.append(tok)
                    self.last_tok[slot] = tok
                else:
                    self.last_tok[slot] = g.output[r.generated - 1]

    # ------------------------------------------------------------------ #
    def _run_chunk_items(self, items, now: float) -> None:
        """Execute partial-prompt (chunked) PT grants. A wave of >= 2
        grants runs as ONE token-packed call with per-segment prefix
        views (``_exec_chunks_packed``, the default); otherwise each
        chunk runs as its own call — attending over the request's
        already-seeded cache prefix (attention-pure stacks), resuming the
        carried recurrent-state snapshot (pure-recurrent stacks), or
        recomputing the whole prefix (the reference path). Only the chunk
        that completes the prompt samples the first response token;
        earlier chunks just extend the cache."""
        infos = []
        for r, chunk in items:
            g = self.requests[r.rid]
            # after an offload-free preemption the context to recompute is
            # prompt + the generated tail minus the newest token (whose KV
            # was never written — it stays the pending decode input, see
            # _prefill_group); the scheduler's grants cover prompt_len
            # tokens, so the tail rides the chunk completing the prompt
            ctx = list(g.prompt) + g.output[:max(0, r.generated - 1)]
            start = self._chunk_progress.get(r.rid, 0)
            completing = r.prompt_done + chunk >= r.prompt_len
            end = len(ctx) if completing else start + chunk
            assert end <= self.capacity, "chunk exceeds cache capacity"
            if r.rid not in self.slot_of:
                slot = self.free_slots.pop()
                self.slot_of[r.rid] = slot
                self.temps[slot] = g.params.temperature
                self.top_ks[slot] = g.params.top_k
            slot = self.slot_of[r.rid]
            self.n_prefill_chunks += 1
            infos.append((r, ctx, start, end, slot, completing))
        if self._chunk_packed and len(infos) >= 2:
            lasts = self._exec_chunks_packed(infos)
        else:
            lasts = []
            for r, ctx, start, end, slot, completing in infos:
                self.n_chunk_calls += 1
                self.max_chunk_items_per_call = max(
                    self.max_chunk_items_per_call, 1)
                if self._chunk_incremental:
                    lasts.append(self._exec_chunk_incremental(
                        ctx, start, end, slot))
                elif self._chunk_rec:
                    lasts.append(self._exec_chunk_state(
                        ctx, start, end, slot, r.rid))
                else:
                    lasts.append(self._exec_chunk_recompute(ctx, end, slot))
        finals = []
        for (r, ctx, start, end, slot, completing), last in zip(infos,
                                                                lasts):
            self._chunk_progress[r.rid] = end
            if completing:
                del self._chunk_progress[r.rid]
                if self._chunk_rec:
                    # the carried snapshot becomes the decode-cache row
                    states = self._rec_state.pop(r.rid)
                    self.caches = self._seed(
                        self.caches, states,
                        jnp.asarray(np.array([slot], np.int32)),
                        jnp.asarray(np.array([end], np.int32)))
                finals.append((r, slot, last, end))
        if not finals:
            return
        # the completing chunks' first-token sampling mirrors
        # _prefill_group: one key split per call, same carried stream
        if self._async:
            key, sk = jax.random.split(self._dev["key"])
            self._dev = dict(self._dev, key=key)
        else:
            self.key, sk = jax.random.split(self.key)
        n = len(finals)
        temps = np.zeros(n, np.float32)
        top_ks = np.zeros(n, np.int32)
        eos = np.full(n, -1, np.int32)
        lens = np.zeros(n, np.int32)
        slot_arr = np.zeros(n, np.int32)
        for i, (r, slot, _, end) in enumerate(finals):
            g = self.requests[r.rid]
            temps[i] = g.params.temperature
            top_ks[i] = g.params.top_k
            eos[i] = -1 if g.params.eos_token is None else g.params.eos_token
            lens[i] = end
            slot_arr[i] = slot
        first = sample_per_request(jnp.stack([f[2] for f in finals]), sk,
                                   temps, top_ks)
        if self._async:
            fallback = np.zeros(n, np.int32)
            use_first = np.zeros(n, bool)
            mapping: List[Tuple[int, int]] = []
            for i, (r, slot, _, end) in enumerate(finals):
                self.pos[slot] = end
                if r.generated == 0:
                    use_first[i] = True
                    mapping.append((i, r.rid))
                else:
                    fallback[i] = self.requests[r.rid].output[r.generated - 1]
            self._dev = self._seed_slots(
                self._dev, jnp.asarray(slot_arr), first,
                jnp.asarray(fallback), jnp.asarray(use_first),
                jnp.asarray(lens), jnp.asarray(temps),
                jnp.asarray(top_ks), jnp.asarray(eos))
            if mapping:
                self._enqueue_drain(first, None, mapping)
        else:
            first_np = np.asarray(first)
            for i, (r, slot, _, end) in enumerate(finals):
                g = self.requests[r.rid]
                self.pos[slot] = end
                if r.generated == 0:
                    tok = int(first_np[i])
                    g.output.append(tok)
                    self.last_tok[slot] = tok
                else:
                    self.last_tok[slot] = g.output[r.generated - 1]

    def _exec_chunks_packed(self, infos):
        """All of an iteration's chunk grants in ONE prefill dispatch: the
        packed token axis concatenates every chunk with per-segment
        absolute positions and segment ids; the key axis prepends each
        segment's own cache-prefix view with per-slot positions
        (POS_INVALID beyond the seeded prefix — first chunks have empty
        views). Only the shared axes are pow2-rounded, so compile count
        stays logarithmic, and pad tokens imply no cache slots (the seed
        scatter drops them). Returns per-segment last-token logits."""
        n = len(infos)
        starts = [i[2] for i in infos]
        lens = [i[3] - i[2] for i in infos]
        Tb = seq_bucket(sum(lens))
        # prefix-view width: pow2 bucket of the deepest seeded prefix,
        # clamped to the cache capacity (chunk grants never reach past it)
        Cp = seq_bucket(max(max(starts), 1))
        if Cp > self.capacity:
            Cp = self.capacity
        toks = np.zeros((1, Tb), np.int32)
        pos = np.zeros((1, Tb), np.int32)
        seg = np.full((1, Tb), -1, np.int32)
        last_idx = np.zeros(n, np.int32)
        offs = np.zeros(n, np.int32)
        off = 0
        for i, (r, ctx, start, end, slot, completing) in enumerate(infos):
            L = end - start
            toks[0, off:off + L] = ctx[start:end]
            pos[0, off:off + L] = start + np.arange(L)
            seg[0, off:off + L] = i
            offs[i] = off
            last_idx[i] = off + L - 1
            off += L
        ppos = np.full((n, Cp), POS_INVALID, np.int32)
        pseg = np.repeat(np.arange(n, dtype=np.int32)[:, None], Cp, axis=1)
        for i, s in enumerate(starts):
            ppos[i, :min(s, Cp)] = np.arange(min(s, Cp))
        # seed-scatter indices: chunk i's tokens land at cache positions
        # [start_i, start_i + len_i); pad columns index capacity (dropped)
        W = min(seq_bucket(max(lens)), Tb)
        w_idx = np.arange(W)[None, :]
        lens_a = np.asarray(lens, np.int32)[:, None]
        starts_a = np.asarray(starts, np.int32)[:, None]
        dst_idx = np.where(w_idx < lens_a, starts_a + w_idx, self.capacity)
        src_idx = offs[:, None] + np.minimum(w_idx, lens_a - 1)
        slots = np.asarray([i[4] for i in infos], np.int32)
        self._prefill_shapes.add((1, Tb))
        self.n_chunk_calls += 1
        self.max_chunk_items_per_call = max(self.max_chunk_items_per_call,
                                            n)
        self.caches, last = self._chunks_packed(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(pos),
            jnp.asarray(seg), jnp.asarray(ppos.reshape(1, n * Cp)),
            jnp.asarray(pseg.reshape(1, n * Cp)), jnp.asarray(slots),
            jnp.asarray(last_idx), jnp.asarray(src_idx.astype(np.int32)),
            jnp.asarray(dst_idx.astype(np.int32)))
        return [last[i] for i in range(n)]

    def _exec_chunk_state(self, ctx, start: int, end: int, slot: int,
                          rid: int):
        """Chunk prefill for pure-recurrent stacks: resume from the
        carried per-request state snapshot — O(n) total across chunks
        instead of the recompute fallback's O(n^2). The snapshot seeds
        the decode cache row when the prompt completes."""
        L = end - start
        toks = np.zeros((1, L), np.int32)
        toks[0, :] = ctx[start:end]
        self._prefill_shapes.add((1, L))
        states = self._rec_state.pop(rid, None)
        if states is None:
            # first chunk: a plain exact-shape prefill from the zero state
            last, states = self._prefill(
                self.params, jnp.asarray(toks),
                jnp.asarray(np.array([L], np.int32)))
            last = last[0]
        else:
            states, last = self._rec_chunk(self.params, states,
                                           jnp.asarray(toks))
        self._rec_state[rid] = states
        return last

    def _exec_chunk_incremental(self, ctx, start: int, end: int,
                                slot: int):
        """Run ctx[start:end) as a prefix-attending chunk and seed its K/V
        into the slot's cache row in one donated program."""
        L = end - start
        Sb = seq_bucket(L)
        # tail-chunk cap: the pow2 round-up must never imply cache slots
        # (and thus KVC pages) past what the scheduler granted — clamp the
        # padded shape to the capacity remaining after ``start``
        if start + Sb > self.capacity:
            Sb = max(L, self.capacity - start)
        toks = np.zeros((1, Sb), np.int32)
        toks[0, :L] = ctx[start:end]
        pos = (start + np.arange(Sb, dtype=np.int32))[None]
        self._prefill_shapes.add((1, Sb))
        self.caches, last = self._chunk_prefill(
            self.params, self.caches, jnp.asarray(toks), jnp.asarray(pos),
            np.int32(slot), np.int32(start), np.int32(L))
        return last

    def _exec_chunk_recompute(self, ctx, end: int, slot: int):
        """Chunk fallback with no resumable prefix view (recurrent stacks,
        or ``incremental_chunk_prefill=False``): re-run positions [0, end)
        and reseed the whole cache row."""
        Sb = end
        if self._pad_prefill:
            Sb = seq_bucket(end)
            if Sb > self.capacity:
                Sb = max(end, self.capacity)
        toks = np.zeros((1, Sb), np.int32)
        toks[0, :end] = ctx[:end]
        lens = np.array([end], np.int32)
        self._prefill_shapes.add((1, Sb))
        last_logits, pf_caches = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        self.caches = self._seed(self.caches, pf_caches,
                                 jnp.asarray(np.array([slot], np.int32)),
                                 jnp.asarray(lens))
        return last_logits[0]

    # ------------------------------------------------------------------ #
    def _run_decode(self, reqs: Sequence[Request], now: float) -> None:
        """Legacy sync decode: one host sync per iteration for the sampled
        batch, then per-request host reads. Kept as the reference the
        async path is equivalence-tested against."""
        if not reqs:
            return
        active = np.zeros(self.max_batch, bool)
        for r in reqs:
            active[self.slot_of[r.rid]] = True
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches, jnp.asarray(active))
        self.key, sk = jax.random.split(self.key)
        # inactive slots are likewise masked to greedy (temp 0) sampling
        # and their tokens never read back
        temps = np.where(active, self.temps, 0.0).astype(np.float32)
        top_ks = np.where(active, self.top_ks, 0).astype(np.int32)
        # this materialization waits on the iteration that was just
        # dispatched — the per-iteration blocking sync the async path removes
        self.sync_counts["drain_blocking"] += 1
        new_toks = np.asarray(sample_per_request(
            logits, sk, jnp.asarray(temps), jnp.asarray(top_ks)))
        self.decode_iters += 1
        self.n_decode_dispatches += 1
        for r in reqs:
            slot = self.slot_of[r.rid]
            g = self.requests[r.rid]
            tok = int(new_toks[slot])
            g.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if g.params.eos_token is not None and tok == g.params.eos_token:
                self.scheduler.notify_eos(r, r.generated + 1)

    def _run_decode_async(self, plan, now: float) -> None:
        """Fused device-resident decode. The host builds the (B,) active
        mask, splits the RNG key (an async device op, identical key stream
        to the sync path) and dispatches the donated fused step; sampled
        tokens land in the lag-N drain ring. EOS flags are only read back
        when an active request actually has an ``eos_token`` — the clamp
        must reach the scheduler at the iteration EOS fires to keep its
        decisions bitwise-equal to the sync path.

        When the scheduler proves a K-iteration horizon with fixed batch
        membership (``decode_horizon``), all K iterations run as ONE
        megastep dispatch and the following K-1 calls are pure host replay
        against the precomputed (K, B) token window."""
        reqs = plan.decode_reqs
        if not reqs:
            return
        # drain first: entries had a whole scheduler cycle to finish on
        # device, so lag-expired drains are copies, not waits
        self._drain_tokens()
        if self._mega_left > 0:
            self._consume_mega_row(reqs)
            return
        active = np.zeros(self.max_batch, bool)
        eos_possible = False
        for r in reqs:
            active[self.slot_of[r.rid]] = True
            if self.requests[r.rid].params.eos_token is not None:
                eos_possible = True
        temps_m = np.where(active, self.temps, 0.0)
        need_sample = bool(np.any(temps_m > 0.0))
        need_topk = need_sample and bool(
            np.any(np.where(active, self.top_ks, 0) > 0))
        # the active mask only changes on admission/completion/preemption;
        # steady state reuses the cached device copy (no transfer dispatch)
        ab = active.tobytes()
        if ab != self._active_bytes:
            self._active_bytes = ab
            self._active_dev = jnp.asarray(active)
        K = self.scheduler.decode_horizon(plan, self._mega_max)
        if K > 1:
            # under pressure (waiters certified KVC-blocked) an EOS
            # completion frees KVC the K=1 path would grant next
            # iteration — the device loop exits right after the EOS
            # iteration and the host truncates the window to match
            sched = self.scheduler
            stop_on_eos = eos_possible and bool(sched.pt_queue
                                                or sched.gt_queue)
            self.caches, self._dev, self._mega_toks, eos_buf = self._mega(
                self.params, self.caches, self._dev, self._active_dev,
                np.int32(K), need_sample, need_topk, stop_on_eos)
            self.n_decode_dispatches += 1
            if eos_possible:
                # ONE blocking readback per window (the per-iteration path
                # pays one per iteration); the scheduler still sees each
                # EOS at the replay iteration it fired
                self.sync_counts["eos_flags"] += 1
                self._mega_eos = np.asarray(eos_buf)
                if stop_on_eos:
                    slots = [self.slot_of[r.rid] for r in reqs]
                    hit = self._mega_eos[:K, slots].any(axis=1)
                    if hit.any():
                        K = int(hit.argmax()) + 1
            else:
                self._mega_eos = None
            self._mega_row = -1
            self._mega_left = K
            self._consume_mega_row(reqs)
            return
        self.caches, self._dev, toks, eos_hit = self._fused(
            self.params, self.caches, self._dev, self._active_dev,
            need_sample, need_topk)
        self.n_decode_dispatches += 1
        self.decode_iters += 1
        self._enqueue_drain(
            toks, None, [(self.slot_of[r.rid], r.rid) for r in reqs])
        if eos_possible:
            self.sync_counts["eos_flags"] += 1
            flags = np.asarray(eos_hit)
            for r in reqs:
                if flags[self.slot_of[r.rid]]:
                    self.scheduler.notify_eos(r, r.generated + 1)

    def _consume_mega_row(self, reqs: Sequence[Request]) -> None:
        """One host-replay iteration of a fused megastep window: push the
        iteration's precomputed token row into the drain ring — mapped
        through the *current* plan, so EOS-shrunken membership stays
        exact — and deliver the row's EOS flags to the scheduler."""
        self._mega_row += 1
        self._mega_left -= 1
        i = self._mega_row
        self.decode_iters += 1
        self._enqueue_drain(
            self._mega_toks, i,
            [(self.slot_of[r.rid], r.rid) for r in reqs],
            new_dispatch=(i == 0))
        if self._mega_eos is not None:
            flags = self._mega_eos[i]
            for r in reqs:
                if flags[self.slot_of[r.rid]]:
                    self.scheduler.notify_eos(r, r.generated + 1)

    def _enqueue_drain(self, toks, row, mapping,
                       new_dispatch: bool = True) -> None:
        """Push one sampled-token entry into the readback ring and
        classify it NOW, from the dispatch sequence alone. An entry whose
        lag window (the last ``readback_lag`` enqueues) already holds an
        older distinct dispatch can only ever wait as backpressure — by
        the time it is lag-expired the device has newer work queued. An
        entry whose whole lag window is its own dispatch (megastep replay
        rows) — or nothing — drains as a lag-aged copy. Neither depends
        on ``is_ready()`` timing, so the per-category counts are
        reproducible across machines (the drain-time classification this
        replaces was not; only the total was)."""
        if new_dispatch:
            self._drain_seq += 1
        seq = self._drain_seq
        if any(s != seq for s in self._recent_drain_seqs):
            self.sync_counts["drain_backpressure"] += 1
        else:
            self.sync_counts["drain_ready"] += 1
        self._recent_drain_seqs.append(seq)
        self._pending_drain.append((toks, row, mapping))

    def _drain_tokens(self, force: bool = False) -> None:
        """Materialize pending sampled-token batches older than the lag.

        Steady state: an entry ``readback_lag`` iterations old has long
        finished on device, so the readback is a copy, not a wait — the
        engine only accepts a potentially-waiting drain when the number of
        undrained *dispatches* (distinct buffers — a K-row megastep window
        counts once) exceeds ``max_pending``, or a flush is forced
        (completion, preemption, idle, end of run). ``is_ready()`` only
        steers this pop policy (performance); sync *accounting* happened
        at enqueue time (``_enqueue_drain``), so counts are deterministic.

        All expired entries materialize through ONE batched
        ``jax.device_get`` (deduplicated by buffer), not one copy per
        entry."""
        dq = self._pending_drain
        lag = 0 if force else self.ecfg.readback_lag
        batch = []
        while len(dq) > lag:
            toks, row, mapping = dq[0]
            if not toks.is_ready() and not force and len(
                    {id(t) for t, _, _ in dq}) <= self.ecfg.max_pending:
                break
            dq.popleft()
            batch.append((toks, row, mapping))
        if not batch:
            return
        uniq: Dict[int, jax.Array] = {}
        for toks, _, _ in batch:
            uniq.setdefault(id(toks), toks)
        mats = jax.device_get(list(uniq.values()))
        mat_of = dict(zip(uniq.keys(), mats))
        for toks, row, mapping in batch:
            arr = mat_of[id(toks)]
            if row is not None:
                arr = arr[row]
            for r_, rid in mapping:
                self.requests[rid].output.append(int(arr[r_]))
            self.n_tokens_drained += len(mapping)

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One engine iteration. Returns number of completions."""
        now = time.monotonic() if now is None else now
        if self._mega_left == 0 and (self._arrivals or self._pending_injects
                                     or self._pending_aborts):
            # a fused window just drained: apply the aborts it deferred
            # (freed slots/KVC are then visible to the injects/arrivals),
            # then deliver arrivals and peer KV injections
            for rid, t_ab, reason in self._pending_aborts:
                self._apply_abort(rid, t_ab, reason)
            self._pending_aborts.clear()
            for payload, t_in in self._pending_injects:
                self._apply_inject(payload, t_in)
            self._pending_injects.clear()
            for r, t_arr in self._arrivals:
                self.scheduler.on_arrival(r, t_arr)
            self._arrivals.clear()
        if self._mega_left == 0 and self._pending_squeeze:
            kvc = self.scheduler.kvc
            kvc.shrink(int(kvc.capacity_tokens * self._pending_squeeze))
            self._pending_squeeze = 0.0
        if self.guard is not None and self._mega_left == 0:
            self._guard_step(now)
        plan = self.scheduler.form_batch(now)
        if self.scheduler.infeasible_shed:
            # rung 4: the scheduler cancelled requests a squeeze made
            # permanently inadmissible *here* — surface each as a
            # terminal shed, or (fleet hand-back enabled) cancel locally
            # and park the request non-terminal for the fleet's
            # shed-retry tier to re-route to a peer that can still fit it
            shed, self.scheduler.infeasible_shed = \
                self.scheduler.infeasible_shed, []
            for r in shed:
                self.abort(r.rid, now, "kvc-infeasible")
                g = self.requests.get(r.rid)
                if g is not None and g.status == "aborted":
                    if self.fleet_shed_handback:
                        g.status = None
                        g.fail_reason = None
                        self.n_aborted -= 1
                        self.requests.pop(r.rid, None)
                        self.shed_handback.append(g)
                    else:
                        g.status = "shed"
                        self.n_aborted -= 1
                        self.n_shed += 1
        if plan.empty:
            if self._mega_left:
                # every window request completed early (EOS inside the
                # window): the remaining precomputed rows belong to no one
                self._mega_left = 0
                self._mega_toks = self._mega_eos = None
            if self._pending_drain:
                self.sync_counts["flush"] += 1
                self._drain_tokens(force=True)
            if self.metrics is not None:
                self.metrics.on_step(self, now)
            return 0
        # GTs rescheduled after a swap-style preemption or deadlock-relief
        # eviction arrive with their KV "in host memory". With a live
        # host-pool image they are *restored* — pages re-seeded, zero
        # recompute (rung 2); otherwise they are recomputed like an
        # offload-free re-prefill (prompt + generated so far), riding the
        # iteration's prefill wave (rung 3)
        missing = [r for r in plan.decode_reqs if r.rid not in self.slot_of]
        if self._mega_left > 0:
            assert not plan.prompt_items and not missing, \
                "megastep horizon violated: admission inside a fused window"
        if missing and self._pending_drain:     # ctx rebuild reads g.output
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        if missing:
            missing = self._swap_in(missing, now)
        self._run_prefill(plan.prompt_items, now, missing=missing)
        if self._async:
            self._run_decode_async(plan, now)
        else:
            self._run_decode(plan.decode_reqs, now)
        before = len(self.scheduler.completed)
        self.scheduler.finish_iteration(now)
        done = self.scheduler.completed[before:]
        freed = False
        for r in done:
            g = self.requests[r.rid]
            if g.finished:
                # first-writer-wins: another engine (or the fleet's
                # redelivery fast path) already wrote this request's
                # terminal state — suppress the second writer and count
                # it; the invariant audit flags any non-zero count
                self.n_dup_completions += 1
            else:
                g.t_done = r.t_complete
                g.status = "completed"
            slot = self.slot_of.pop(r.rid, None)
            if slot is not None:
                self.free_slots.append(slot)
                freed = True
        # preempted/evicted requests (KVC freed by the scheduler) lose
        # their slot; queued GTs keep theirs — their KV is live. Before a
        # victim's slot is recycled its cache pages are offloaded to the
        # host pool (rung 2), so the next schedule restores instead of
        # recomputing; nothing reuses the slot until next step's prefill,
        # so the post-free capture still reads the victim's pages
        for rid in list(self.slot_of):
            if rid not in self.scheduler.kvc.allocs:
                slot = self.slot_of.pop(rid)
                self.free_slots.append(slot)
                self._chunk_progress.pop(rid, None)
                self._rec_state.pop(rid, None)
                self._swap_out(rid, slot)
                freed = True
        if freed and self._pending_drain:
            # completed outputs must be materialized before t_done is
            # observable, and a preempted request rebuilds its recompute
            # context from g.output at the next prefill
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        if self.metrics is not None:
            self.metrics.on_step(self, now)
        return len(done)

    def flush(self) -> None:
        """Force-drain the token readback ring so every request's
        ``output`` is fully materialized on the host (end of a run, or
        before inspecting outputs mid-stream)."""
        if self._pending_drain:
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)

    # ------------------------------------------------------------------ #
    # liveness / diagnostics (serve_stream watchdog, invariant checker)
    # ------------------------------------------------------------------ #
    def progress_state(self) -> tuple:
        """Monotone fingerprint of forward progress: any iteration that
        decodes, prefills, completes, aborts, or accepts work changes it.
        ``serve_stream`` raises ``FleetStalled`` when it freezes while
        ``has_work()`` holds (e.g. a scheduler wedged on an unplaceable
        request)."""
        return (self.decode_iters, self.n_prefill_waves,
                self.n_prefill_chunks, len(self.scheduler.completed),
                self.n_aborted, self.n_kv_injects, self._rid)

    def publish_metrics(self, registry, instance: str = "0") -> None:
        """Publish every engine/scheduler/KVC counter and gauge into a
        ``repro.obs`` registry (the typed publication API — one code path
        for live sampling, stall diagnostics and exit dumps)."""
        from repro.obs import publish_engine
        publish_engine(self, registry, instance)

    def debug_state(self) -> Dict[str, object]:
        """Queue/KVC snapshot for stall diagnostics — derived from a
        registry snapshot (the same publication path live metrics use),
        not a hand-assembled dict, so the two can never disagree."""
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        self.publish_metrics(reg)
        return reg.snapshot().flat()

    def run(self, gen_requests: Sequence[GenRequest],
            arrivals: Optional[Sequence[float]] = None,
            max_steps: int = 100_000, stall_limit: int = 2_000
            ) -> List[GenRequest]:
        """Serve a batch to completion — or, with ``arrivals``, an online
        stream: each request is submitted at its arrival time on the
        engine's iteration clock (the same contract as
        ``EngineFleet.run``)."""
        return serve_stream(self, gen_requests, arrivals, max_steps,
                            stall_limit)


def serve_stream(server, gen_requests: Sequence[GenRequest],
                 arrivals: Optional[Sequence[float]] = None,
                 max_steps: int = 100_000,
                 stall_limit: int = 2_000) -> List[GenRequest]:
    """Drive any submit/step/has_work/flush server (a ``ServingEngine``
    or a ``repro.cluster.EngineFleet``) over an online request stream on
    its iteration clock: submit each request at its arrival time, step
    while there is work, jump the clock across idle gaps, flush the
    readback ring at the end. The single definition keeps both backends'
    ``run(reqs, arrivals)`` semantics from drifting.

    Two robustness contracts live here:

      * a typed ``RequestShed`` from ``submit`` (fleet admission control)
        is caught and the stream continues — the server already recorded
        the terminal ``shed`` state;
      * a no-progress watchdog: ``stall_limit`` consecutive steps whose
        ``progress_state()`` fingerprint never moves (while ``has_work()``
        holds) raise ``FleetStalled`` with per-instance queue/KVC state,
        instead of the pre-fault-tolerance behavior of spinning on
        ``has_work()`` forever. The limit must exceed any legitimate
        quiet period (fault-injected freezes, recovery backoff waits).
    """
    if arrivals is None:
        arrivals = [0.0] * len(gen_requests)
    stream = sorted(zip(gen_requests, arrivals), key=lambda p: p[1])
    fingerprint = getattr(server, "progress_state", None)
    t, i, steps, stalled, last_fp = 0.0, 0, 0, 0, None
    while steps < max_steps:
        submitted = False
        while i < len(stream) and stream[i][1] <= t:
            try:
                server.submit(stream[i][0], float(stream[i][1]))
            except RequestShed:
                pass              # typed fast-fail; terminal state recorded
            i += 1
            submitted = True
        if not server.has_work():
            if i >= len(stream):
                break
            t = max(t, float(stream[i][1]))
            continue
        t += 1.0
        server.step(t)
        steps += 1
        if fingerprint is not None:
            fp = fingerprint()
            if fp == last_fp and not submitted:
                stalled += 1
                if stalled >= stall_limit:
                    dbg = getattr(server, "debug_state", dict)()
                    raise FleetStalled(
                        f"no progress for {stall_limit} consecutive steps "
                        f"with work outstanding (t={t}); per-instance "
                        f"state: {dbg}", debug=dbg)
            else:
                stalled = 0
            last_fp = fp
    server.flush()
    return list(gen_requests)
