"""Continuous-batching serving engine: real JAX model execution driven by
any `repro.core` scheduler (EconoServe by default).

The scheduler owns KVC block accounting, batching policy, SLO ordering,
and KVC pipelining; the engine owns slots, caches, jitted prefill/decode
steps and sampling. Completion is EOS- or max-tokens-driven; when EOS
fires early the request's `true_rl` is clamped so the scheduler sees the
real completion (the RL predictor only ever saw the prompt).

Hot-path layout (why the shapes look the way they do):

  * Prefill is *bucketed and batched*: all PT items of an iteration run as
    one padded (max_batch, pow2-bucketed-seq) call, so XLA compiles at
    most one program per sequence bucket (<= ceil(log2(max_prompt))
    programs per engine lifetime) instead of retracing per unique prompt
    length. Right-padding is exact for causal attention stacks; models
    with recurrent blocks (SSM/xLSTM) fall back to exact-shape prefill,
    where padding would corrupt the recurrent state.
  * Cache seeding is one jitted, buffer-donated scatter over the whole
    item batch — not a per-layer host-side pytree rebuild.
  * Sampling is vectorized with per-slot temperature / top-k vectors (one
    fused kernel, no per-request collapse to a single scalar).

Scope note: the engine runs whole prompts as single PT items (it sizes TFS
to the longest prompt) — chunked-prefill policy is exercised by the
discrete-event simulator, not the CPU engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel, ModelProfile
from repro.core.predictor import NoisyPredictor, apply_padding
from repro.core.request import Request
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.models import model
from repro.models.config import ATTN, ModelConfig

from .sampling import SamplingParams, sample_per_request

MIN_SEQ_BUCKET = 16


def seq_bucket(n: int) -> int:
    """Power-of-two padded length (floor MIN_SEQ_BUCKET)."""
    b = MIN_SEQ_BUCKET
    while b < n:
        b <<= 1
    return b


@dataclass
class GenRequest:
    prompt: List[int]
    params: SamplingParams = field(default_factory=SamplingParams)
    rid: int = -1
    output: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[dict] = None, *,
                 max_batch: int = 8, capacity: int = 512,
                 scheduler_cfg: Optional[SchedulerConfig] = None,
                 variant: str = "full", impl: str = "xla",
                 rl_accuracy: float = 0.8, seed: int = 0):
        self.cfg = cfg
        self.impl = impl
        self.max_batch = max_batch
        self.capacity = capacity
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else model.init(cfg, key)
        self.key = jax.random.PRNGKey(seed + 1)

        scfg = scheduler_cfg or SchedulerConfig(
            kvc_tokens=max_batch * capacity, block_size=32,
            tfs=capacity, max_model_len=capacity,
            max_batch_reqs=max_batch)
        cost = CostModel(model=ModelProfile.from_config(cfg))
        self.scheduler = make_econoserve(scfg, cost, variant)
        self.predictor = NoisyPredictor(accuracy=rl_accuracy, seed=seed,
                                        bucket=scfg.bucket)

        # slot-based caches
        self.caches = model.init_cache(cfg, max_batch, capacity)
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_batch))
        self.pos = np.zeros(max_batch, np.int64)      # next absolute position
        self.last_tok = np.zeros(max_batch, np.int64)
        self.temps = np.zeros(max_batch, np.float32)  # per-slot sampling
        self.top_ks = np.zeros(max_batch, np.int32)
        self.requests: Dict[int, GenRequest] = {}
        self._rid = 0

        # right-padded prefill is exact only for pure-attention stacks
        # (causal masking ignores pad positions); recurrent blocks would
        # fold pad tokens into their state, so they get exact shapes
        self._pad_prefill = set(cfg.pattern()) <= {ATTN}
        self._prefill_shapes: Set[Tuple[int, int]] = set()

        def _decode_fn(p, tok, pos, caches, active):
            """Decode step with inactive slots masked out of the cache
            update. Attention writes to idle slots were merely wasteful
            (idempotent); recurrent states (SSM/xLSTM) would be silently
            corrupted by spurious h <- f(h, x) advances."""
            logits, new_caches = model.decode_step(cfg, p, tok, pos, caches,
                                                   impl=impl)

            def sel(old, new):
                m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)

            return logits, jax.tree.map(sel, caches, new_caches)

        self._decode = jax.jit(_decode_fn)

        def _prefill_fn(p, toks, lens):
            logits, caches = model.prefill(cfg, p, toks, impl=impl)
            last = logits[jnp.arange(toks.shape[0]), lens - 1]
            return last, caches

        self._prefill = jax.jit(_prefill_fn)
        self._seed = jax.jit(self._seed_fn, donate_argnums=(0,))

    @property
    def n_prefill_compiles(self) -> int:
        """Distinct (batch, seq) prefill shapes traced so far."""
        return len(self._prefill_shapes)

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float) -> int:
        req.rid = self._rid
        self._rid += 1
        req.t_submit = now
        r = Request(rid=req.rid, prompt_len=len(req.prompt),
                    true_rl=req.params.max_new_tokens, arrival=now)
        r.predicted_rl = self.predictor.predict(r)
        r.padded_rl = apply_padding(r.predicted_rl,
                                    self.scheduler.cfg.pad_ratio,
                                    self.scheduler.cfg.bucket)
        self.requests[req.rid] = req
        self.scheduler.on_arrival(r, now)
        return req.rid

    # ------------------------------------------------------------------ #
    def _seed_fn(self, caches, pf_caches, slots, lens):
        """Scatter a whole prefill batch into the decode caches at once.

        slots (Bb,) int32 destination rows; pad rows carry ``max_batch``
        (past-the-end, dropped via mode="drop"); lens (Bb,) true context
        lengths (pad positions beyond them carry junk that decode masking
        never reads).
        """
        def seq_scatter(dst, src, ring):
            # dst (L, B, C, K, hd); src (L, Bb, S, K, hd)
            C, S = dst.shape[2], src.shape[2]
            s_idx = jnp.arange(C)[None, :]                      # (1, C)
            plen = lens[:, None]                                # (Bb, 1)
            if ring and S > C:
                # sliding window: token p of the real tail lands at ring
                # slot p % C; rows with plen <= C keep identity placement
                j = jnp.where(plen > C,
                              (plen - C) + jnp.mod(s_idx - plen, C),
                              jnp.minimum(s_idx, S - 1))
            else:
                # identity placement; slots beyond S (or beyond plen, for
                # padded prefill) hold junk that decode masking never reads
                j = jnp.broadcast_to(jnp.minimum(s_idx, S - 1),
                                     (src.shape[1], C))
            rows = jnp.take_along_axis(
                src, j[None, :, :, None, None], axis=2)
            return dst.at[:, slots].set(rows.astype(dst.dtype), mode="drop")

        def plain_scatter(dst, src):
            return dst.at[:, slots].set(src.astype(dst.dtype), mode="drop")

        win = self.cfg.sliding_window
        out = {}
        for kind, sub in caches.items():
            if kind in (ATTN, "shared"):
                ring = (kind == ATTN and win is not None
                        and sub["k"].shape[2] == win)
                out[kind] = {n: seq_scatter(sub[n], pf_caches[kind][n], ring)
                             for n in ("k", "v")}
            else:
                out[kind] = jax.tree.map(plain_scatter, sub, pf_caches[kind])
        return out

    def _run_prefill(self, items, now: float) -> None:
        """Execute PT items (whole prompts) and seed their cache slots.

        All items run as one padded (max_batch, seq_bucket) call when the
        model tolerates padding; otherwise one exact-shape call per item.
        """
        if not items:
            return
        groups = [list(items)] if self._pad_prefill \
            else [[it] for it in items]
        for group in groups:
            self._prefill_group(group, now)

    def _prefill_group(self, group, now: float) -> None:
        ctxs, slots = [], []
        for r, chunk in group:
            assert chunk == r.prompt_len, \
                "engine runs whole prompts; size TFS >= max prompt length"
            g = self.requests[r.rid]
            # after an offload-free preemption the context to recompute is
            # prompt + everything generated so far
            ctxs.append(list(g.prompt) + g.output[:r.generated])
            slot = self.free_slots.pop()
            self.slot_of[r.rid] = slot
            self.temps[slot] = g.params.temperature
            self.top_ks[slot] = g.params.top_k
            slots.append(slot)
        n = len(group)
        maxlen = max(len(c) for c in ctxs)
        if self._pad_prefill:
            Bb = self.max_batch
            # pow2 bucket, clamped to capacity (a single extra bucket shape)
            # so the padded shape never exceeds the cache it seeds
            Sb = seq_bucket(maxlen)
            if Sb > self.capacity:
                Sb = max(maxlen, self.capacity)
        else:
            Bb, Sb = n, maxlen
        toks = np.zeros((Bb, Sb), np.int32)
        lens = np.ones(Bb, np.int32)        # pad rows: len 1 (safe gather)
        # pad rows scatter to row `max_batch` — out of bounds, mode="drop"
        slot_arr = np.full(Bb, self.max_batch, np.int32)
        for i, ctx in enumerate(ctxs):
            toks[i, :len(ctx)] = ctx
            lens[i] = len(ctx)
            slot_arr[i] = slots[i]
        self._prefill_shapes.add((Bb, Sb))
        last_logits, pf_caches = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        self.caches = self._seed(self.caches, pf_caches,
                                 jnp.asarray(slot_arr), jnp.asarray(lens))
        self.key, sk = jax.random.split(self.key)
        temps = np.zeros(Bb, np.float32)
        top_ks = np.zeros(Bb, np.int32)
        for i, (r, _) in enumerate(group):
            g = self.requests[r.rid]
            temps[i] = g.params.temperature
            top_ks[i] = g.params.top_k
        first = np.asarray(sample_per_request(
            last_logits, sk, jnp.asarray(temps), jnp.asarray(top_ks)))
        for i, (r, _) in enumerate(group):
            g = self.requests[r.rid]
            slot = slots[i]
            self.pos[slot] = lens[i]
            if r.generated == 0:
                # the PT iteration produces the first response token (§1)
                tok = int(first[i])
                g.output.append(tok)
                self.last_tok[slot] = tok
            else:
                self.last_tok[slot] = g.output[r.generated - 1]

    # ------------------------------------------------------------------ #
    def _run_decode(self, reqs: Sequence[Request], now: float) -> None:
        if not reqs:
            return
        active = np.zeros(self.max_batch, bool)
        for r in reqs:
            active[self.slot_of[r.rid]] = True
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches, jnp.asarray(active))
        self.key, sk = jax.random.split(self.key)
        # inactive slots are likewise masked to greedy (temp 0) sampling
        # and their tokens never read back
        temps = np.where(active, self.temps, 0.0).astype(np.float32)
        top_ks = np.where(active, self.top_ks, 0).astype(np.int32)
        new_toks = np.asarray(sample_per_request(
            logits, sk, jnp.asarray(temps), jnp.asarray(top_ks)))
        for r in reqs:
            slot = self.slot_of[r.rid]
            g = self.requests[r.rid]
            tok = int(new_toks[slot])
            g.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if g.params.eos_token is not None and tok == g.params.eos_token:
                r.true_rl = r.generated + 1     # EOS: clamp for the scheduler

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One engine iteration. Returns number of completions."""
        now = time.monotonic() if now is None else now
        plan = self.scheduler.form_batch(now)
        if plan.empty:
            return 0
        self._run_prefill(plan.prompt_items, now)
        self._run_decode(plan.decode_reqs, now)
        before = len(self.scheduler.completed)
        self.scheduler.finish_iteration(now)
        done = self.scheduler.completed[before:]
        for r in done:
            g = self.requests[r.rid]
            g.t_done = r.t_complete
            slot = self.slot_of.pop(r.rid, None)
            if slot is not None:
                self.free_slots.append(slot)
        # preempted/evicted requests (KVC freed by the scheduler) lose
        # their slot; queued GTs keep theirs — their KV is live
        for rid in list(self.slot_of):
            if rid not in self.scheduler.kvc.allocs:
                self.free_slots.append(self.slot_of.pop(rid))
        return len(done)

    def run(self, gen_requests: Sequence[GenRequest],
            max_steps: int = 100_000) -> List[GenRequest]:
        t = 0.0
        for g in gen_requests:
            self.submit(g, t)
        steps = 0
        while (self.scheduler.has_work() and steps < max_steps):
            t += 1.0
            self.step(t)
            steps += 1
        return list(gen_requests)
