"""Continuous-batching serving engine: real JAX model execution driven by
any `repro.core` scheduler (EconoServe by default).

The scheduler owns KVC block accounting, batching policy, SLO ordering,
and KVC pipelining; the engine owns slots, caches, jitted prefill/decode
steps and sampling. Completion is EOS- or max-tokens-driven; when EOS
fires early the request's `true_rl` is clamped so the scheduler sees the
real completion (the RL predictor only ever saw the prompt).

Hot-path layout (why the shapes look the way they do):

  * Decode is *fully asynchronous and device-resident* (default,
    ``EngineConfig.async_decode``): per-slot ``last_tok`` / ``pos`` /
    sampling params live as device arrays carried across iterations, and
    decode -> sample -> EOS-check -> pos-update run as ONE jitted,
    buffer-donated step (XLA reuses the cache buffers in place). Sampled
    tokens are drained to the host with a lag of
    ``EngineConfig.readback_lag`` iterations — the host appends tokens for
    iteration t-k while iteration t runs on device, so the steady-state
    loop issues zero blocking host syncs (``sync_counts`` /
    ``n_blocking_syncs`` instrument this). Only when an *active* request
    carries an ``eos_token`` does the engine read back a (B,) flag vector
    per iteration, because the scheduler's completion accounting needs EOS
    at the iteration it fires to stay bitwise-equal to the sync path.
  * Prefill is *token-packed* (default, ``EngineConfig.packed_prefill``):
    all PT items of an iteration are concatenated into one flattened token
    axis with per-segment positions and a block-diagonal segment mask —
    no batch-dim padding and no per-row length padding; the only padding
    left is rounding the total token count up to a pow2 bucket, so XLA
    compiles <= ceil(log2(max_total_tokens)) programs per engine lifetime.
    Models with recurrent blocks (SSM/xLSTM) fall back to exact-shape
    prefill, where foreign segments would corrupt the recurrent state; the
    legacy (max_batch, pow2-seq) padded-batch path is kept behind
    ``packed_prefill=False`` for the equivalence tests.
  * Cache seeding is one jitted, buffer-donated scatter over the whole
    item batch (a per-segment gather for the packed path) — not a
    per-layer host-side pytree rebuild.
  * Sampling is vectorized with per-slot temperature / top-k vectors and,
    on the async path, runs inside the decode program itself (no separate
    dispatch, no host round-trip).

Scope note: the engine runs whole prompts as single PT items (it sizes TFS
to the longest prompt) — chunked-prefill policy is exercised by the
discrete-event simulator, not the CPU engine.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel, ModelProfile
from repro.core.predictor import NoisyPredictor, apply_padding
from repro.core.request import Request
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.models import model
from repro.models.config import ATTN, ModelConfig

from .sampling import SamplingParams, sample_in_graph, sample_per_request

MIN_SEQ_BUCKET = 16


def seq_bucket(n: int) -> int:
    """Power-of-two padded length (floor MIN_SEQ_BUCKET)."""
    b = MIN_SEQ_BUCKET
    while b < n:
        b <<= 1
    return b


@dataclass
class EngineConfig:
    """Engine hot-path toggles, mirroring the PR 1
    ``SchedulerConfig.incremental_queues`` convention: the fast paths are
    the default and ``False`` keeps the reference implementation for
    equivalence tests and benchmarks.

    ``readback_lag`` is how many decode iterations sampled tokens may trail
    on device before the host materializes them; ``max_pending`` is the
    hard cap on undrained iterations (beyond it the host accepts one
    blocking sync rather than queueing unboundedly).
    """
    async_decode: bool = True
    packed_prefill: bool = True
    readback_lag: int = 2
    max_pending: int = 8


@dataclass
class GenRequest:
    prompt: List[int]
    params: SamplingParams = field(default_factory=SamplingParams)
    rid: int = -1
    output: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[dict] = None, *,
                 max_batch: int = 8, capacity: int = 512,
                 scheduler_cfg: Optional[SchedulerConfig] = None,
                 variant: str = "full", impl: str = "xla",
                 rl_accuracy: float = 0.8, seed: int = 0,
                 engine_cfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.impl = impl
        self.max_batch = max_batch
        self.capacity = capacity
        self.ecfg = engine_cfg or EngineConfig()
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else model.init(cfg, key)
        self.key = jax.random.PRNGKey(seed + 1)

        scfg = scheduler_cfg or SchedulerConfig(
            kvc_tokens=max_batch * capacity, block_size=32,
            tfs=capacity, max_model_len=capacity,
            max_batch_reqs=max_batch)
        cost = CostModel(model=ModelProfile.from_config(cfg))
        self.scheduler = make_econoserve(scfg, cost, variant)
        self.predictor = NoisyPredictor(accuracy=rl_accuracy, seed=seed,
                                        bucket=scfg.bucket)

        # slot-based caches
        self.caches = model.init_cache(cfg, max_batch, capacity)
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_batch))
        # host mirrors of per-slot state. On the legacy sync path they are
        # authoritative; on the async path last_tok/pos are device-resident
        # (carried through the fused step) and the mirrors only hold
        # prefill-time values (temps/top_ks/eos drive the static sampling
        # flags without any device readback).
        self.pos = np.zeros(max_batch, np.int64)      # next absolute position
        self.last_tok = np.zeros(max_batch, np.int64)
        self.temps = np.zeros(max_batch, np.float32)  # per-slot sampling
        self.top_ks = np.zeros(max_batch, np.int32)
        self.requests: Dict[int, GenRequest] = {}
        self._rid = 0

        # right-padded / token-packed prefill is exact only for
        # pure-attention stacks (masking ignores pad positions and foreign
        # segments); recurrent blocks would fold them into their state, so
        # they get exact shapes
        self._pad_prefill = set(cfg.pattern()) <= {ATTN}
        self._async = self.ecfg.async_decode
        self._packed = self.ecfg.packed_prefill and self._pad_prefill
        self._prefill_shapes: Set[Tuple[int, int]] = set()

        # async bookkeeping: device slot state carried across the fused
        # steps, plus the lag-N readback ring of (tokens, [(row, rid)]).
        # The PRNG key rides along so the steady-state loop does not even
        # dispatch a host-side split — the fused step splits in-graph,
        # consuming the exact same key stream as the sync path (prefill
        # swaps the carried leaf without materializing it).
        self._dev = {
            "last_tok": jnp.zeros(max_batch, jnp.int32),
            "pos": jnp.zeros(max_batch, jnp.int32),
            "temps": jnp.zeros(max_batch, jnp.float32),
            "top_ks": jnp.zeros(max_batch, jnp.int32),
            "eos": jnp.full(max_batch, -1, jnp.int32),
            "key": self.key,
        }
        self._active_bytes: Optional[bytes] = None
        self._active_dev: Optional[jax.Array] = None
        self._pending_drain: Deque[Tuple[jax.Array,
                                         List[Tuple[int, int]]]] = deque()
        # host-sync instrumentation (what the hot-path microbench reports):
        # eos_flags      — per-iteration (B,) EOS-flag readbacks (only when
        #                  an active request has an eos_token)
        # drain_blocking — token drains that had to wait on the device
        # drain_ready    — token drains that were already materialized
        # flush          — forced full drains (completion/preemption/idle)
        self.sync_counts = {"eos_flags": 0, "drain_blocking": 0,
                            "drain_ready": 0, "flush": 0}
        self.decode_iters = 0

        def _decode_fn(p, tok, pos, caches, active):
            """Legacy sync decode step with inactive slots masked out of the
            cache update. Attention writes to idle slots were merely
            wasteful (idempotent); recurrent states (SSM/xLSTM) would be
            silently corrupted by spurious h <- f(h, x) advances."""
            logits, new_caches = model.decode_step(cfg, p, tok, pos, caches,
                                                   impl=impl)

            def sel(old, new):
                m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)

            return logits, jax.tree.map(sel, caches, new_caches)

        self._decode = jax.jit(_decode_fn)

        def _fused_fn(p, caches, st, active, need_sample, need_topk):
            """Fused async decode: forward pass, masked cache update,
            in-graph RNG split + sampling, EOS check and pos advance in one
            program. ``caches`` and ``st`` are donated so XLA updates the
            KV buffers and carried slot state in place."""
            toks = st["last_tok"][:, None]
            logits, new_caches = model.decode_step(cfg, p, toks, st["pos"],
                                                   caches, impl=impl)

            def sel(old, new):
                m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)

            new_caches = jax.tree.map(sel, caches, new_caches)
            temps = jnp.where(active, st["temps"], 0.0)
            top_ks = jnp.where(active, st["top_ks"], 0)
            key, sk = jax.random.split(st["key"])
            new = sample_in_graph(logits, sk, temps, top_ks,
                                  need_sample, need_topk)
            eos_hit = active & (st["eos"] >= 0) & (new == st["eos"])
            st = dict(st,
                      last_tok=jnp.where(active, new, st["last_tok"]),
                      pos=st["pos"] + active.astype(st["pos"].dtype),
                      key=key)
            return new_caches, st, new, eos_hit

        self._fused = jax.jit(_fused_fn, static_argnums=(4, 5),
                              donate_argnums=(1, 2))

        def _seed_slots_fn(st, slots, first, fallback, use_first, poss,
                           temps, top_ks, eos):
            """Scatter prefill results into the carried device slot state
            (async path) — the first sampled token stays on device; rows
            re-prefilled after a preemption restore their last generated
            token from the host-known ``fallback``."""
            last = jnp.where(use_first, first, fallback)
            return dict(
                st,
                last_tok=st["last_tok"].at[slots].set(last, mode="drop"),
                pos=st["pos"].at[slots].set(poss, mode="drop"),
                temps=st["temps"].at[slots].set(temps, mode="drop"),
                top_ks=st["top_ks"].at[slots].set(top_ks, mode="drop"),
                eos=st["eos"].at[slots].set(eos, mode="drop"))

        self._seed_slots = jax.jit(_seed_slots_fn, donate_argnums=(0,))

        def _prefill_fn(p, toks, lens):
            logits, caches = model.prefill(cfg, p, toks, impl=impl)
            last = logits[jnp.arange(toks.shape[0]), lens - 1]
            return last, caches

        self._prefill = jax.jit(_prefill_fn)

        def _prefill_packed_fn(p, toks, pos, seg, last_idx):
            """Token-packed prefill: toks/pos/seg (1, T) with per-segment
            positions and segment ids; last_idx (Bb,) flat indices of each
            prompt's final token (pad rows point at 0 and are dropped by
            the caller's slot scatter)."""
            logits, caches = model.prefill(cfg, p, toks, impl=impl,
                                           positions=pos, segment_ids=seg)
            return logits[0, last_idx], caches

        self._prefill_packed = jax.jit(_prefill_packed_fn)
        self._seed = jax.jit(self._seed_fn, donate_argnums=(0,))
        self._seed_packed = jax.jit(self._seed_packed_fn,
                                    donate_argnums=(0,))

    @property
    def n_prefill_compiles(self) -> int:
        """Distinct (batch, seq) prefill shapes traced so far."""
        return len(self._prefill_shapes)

    @property
    def n_blocking_syncs(self) -> int:
        """Host syncs that could block on in-flight device work (EOS-flag
        readbacks + non-ready token drains). Zero across a steady-state
        async decode window with no EOS-capable requests."""
        return (self.sync_counts["eos_flags"]
                + self.sync_counts["drain_blocking"])

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float) -> int:
        req.rid = self._rid
        self._rid += 1
        req.t_submit = now
        r = Request(rid=req.rid, prompt_len=len(req.prompt),
                    true_rl=req.params.max_new_tokens, arrival=now)
        r.predicted_rl = self.predictor.predict(r)
        r.padded_rl = apply_padding(r.predicted_rl,
                                    self.scheduler.cfg.pad_ratio,
                                    self.scheduler.cfg.bucket)
        self.requests[req.rid] = req
        self.scheduler.on_arrival(r, now)
        return req.rid

    # ------------------------------------------------------------------ #
    def _is_ring(self, kind: str, sub) -> bool:
        """A cache row is a sliding-window ring buffer when its capacity
        equals the window (shared-attention caches are always full-size)."""
        win = self.cfg.sliding_window
        return kind == ATTN and win is not None and sub["k"].shape[2] == win

    @staticmethod
    def _ring_index(plen, s_idx, C):
        """Within-sequence source index for seeding a C-slot ring buffer
        from a plen-token prefill: token p of the real tail lands at ring
        slot p % C; rows with plen <= C keep identity placement."""
        return jnp.where(plen > C,
                         (plen - C) + jnp.mod(s_idx - plen, C),
                         jnp.minimum(s_idx, jnp.maximum(plen - 1, 0)))

    def _seed_fn(self, caches, pf_caches, slots, lens):
        """Scatter a whole prefill batch into the decode caches at once.

        slots (Bb,) int32 destination rows; pad rows carry ``max_batch``
        (past-the-end, dropped via mode="drop"); lens (Bb,) true context
        lengths (pad positions beyond them carry junk that decode masking
        never reads).
        """
        def seq_scatter(dst, src, ring):
            # dst (L, B, C, K, hd); src (L, Bb, S, K, hd)
            C, S = dst.shape[2], src.shape[2]
            s_idx = jnp.arange(C)[None, :]                      # (1, C)
            plen = lens[:, None]                                # (Bb, 1)
            if ring and S > C:
                j = self._ring_index(plen, s_idx, C)
            else:
                # identity placement; slots beyond S (or beyond plen, for
                # padded prefill) hold junk that decode masking never reads
                j = jnp.broadcast_to(jnp.minimum(s_idx, S - 1),
                                     (src.shape[1], C))
            rows = jnp.take_along_axis(
                src, j[None, :, :, None, None], axis=2)
            return dst.at[:, slots].set(rows.astype(dst.dtype), mode="drop")

        def plain_scatter(dst, src):
            return dst.at[:, slots].set(src.astype(dst.dtype), mode="drop")

        out = {}
        for kind, sub in caches.items():
            if kind in (ATTN, "shared"):
                ring = self._is_ring(kind, sub)
                out[kind] = {n: seq_scatter(sub[n], pf_caches[kind][n], ring)
                             for n in ("k", "v")}
            else:
                out[kind] = jax.tree.map(plain_scatter, sub, pf_caches[kind])
        return out

    def _seed_packed_fn(self, caches, pf_caches, slots, starts, lens):
        """Seed decode caches from a token-packed prefill: per-item spans
        of the flattened token axis are gathered and scattered into their
        slots. starts/lens (Bb,) flat span starts and true lengths; pad
        rows scatter to row ``max_batch`` (dropped)."""
        def span_scatter(dst, src, ring):
            # dst (L, B, C, K, hd); src (L, 1, T, K, hd)
            C, T = dst.shape[2], src.shape[2]
            s_idx = jnp.arange(C)[None, :]                      # (1, C)
            plen = lens[:, None]                                # (Bb, 1)
            if ring:
                within = self._ring_index(plen, s_idx, C)
            else:
                # identity placement within the span; cache slots beyond
                # plen repeat the last real token — junk the decode
                # masking never reads
                within = jnp.minimum(s_idx, jnp.maximum(plen - 1, 0))
            j = jnp.clip(starts[:, None] + within, 0, T - 1)    # (Bb, C)
            rows = jnp.take(src[:, 0], j, axis=1)   # (L, Bb, C, K, hd)
            return dst.at[:, slots].set(rows.astype(dst.dtype), mode="drop")

        out = {}
        for kind, sub in caches.items():
            assert kind in (ATTN, "shared"), \
                "packed prefill is gated to attention-only stacks"
            out[kind] = {n: span_scatter(sub[n], pf_caches[kind][n],
                                         self._is_ring(kind, sub))
                         for n in ("k", "v")}
        return out

    # ------------------------------------------------------------------ #
    def _run_prefill(self, items, now: float) -> None:
        """Execute PT items (whole prompts) and seed their cache slots.

        All items of an iteration run as ONE call: token-packed (flattened
        with a block-diagonal segment mask — no batch or length padding)
        when enabled, else padded (max_batch, seq_bucket) when the model
        tolerates padding; otherwise one exact-shape call per item.
        """
        if not items:
            return
        groups = [list(items)] if self._pad_prefill \
            else [[it] for it in items]
        for group in groups:
            self._prefill_group(group, now)

    def _prefill_group(self, group, now: float) -> None:
        ctxs, slots = [], []
        for r, chunk in group:
            assert chunk == r.prompt_len, \
                "engine runs whole prompts; size TFS >= max prompt length"
            g = self.requests[r.rid]
            # after an offload-free preemption the context to recompute is
            # prompt + everything generated so far
            ctxs.append(list(g.prompt) + g.output[:r.generated])
            slot = self.free_slots.pop()
            self.slot_of[r.rid] = slot
            self.temps[slot] = g.params.temperature
            self.top_ks[slot] = g.params.top_k
            slots.append(slot)
        n = len(group)
        lens_true = [len(c) for c in ctxs]
        maxlen = max(lens_true)
        if self._pad_prefill:
            Bb = self.max_batch
        else:
            Bb = n
        # pad rows: len 1 (safe gather), scatter to row `max_batch` —
        # out of bounds, dropped via mode="drop"
        lens = np.ones(Bb, np.int32)
        slot_arr = np.full(Bb, self.max_batch, np.int32)
        for i in range(n):
            lens[i] = lens_true[i]
            slot_arr[i] = slots[i]
        if self._packed:
            starts_np = np.zeros(Bb, np.int32)
            last_idx = np.zeros(Bb, np.int32)
            off = 0
            for i in range(n):
                starts_np[i] = off
                off += lens_true[i]
                last_idx[i] = off - 1
            Tb = seq_bucket(off)
            toks = np.zeros((1, Tb), np.int32)
            pos = np.zeros((1, Tb), np.int32)
            seg = np.full((1, Tb), -1, np.int32)
            for i, ctx in enumerate(ctxs):
                s, L = starts_np[i], lens_true[i]
                toks[0, s:s + L] = ctx
                pos[0, s:s + L] = np.arange(L)
                seg[0, s:s + L] = i
            self._prefill_shapes.add((1, Tb))
            last_logits, pf_caches = self._prefill_packed(
                self.params, jnp.asarray(toks), jnp.asarray(pos),
                jnp.asarray(seg), jnp.asarray(last_idx))
            self.caches = self._seed_packed(
                self.caches, pf_caches, jnp.asarray(slot_arr),
                jnp.asarray(starts_np), jnp.asarray(lens))
        else:
            if self._pad_prefill:
                # pow2 bucket, clamped to capacity (a single extra bucket
                # shape) so the padded shape never exceeds the cache it seeds
                Sb = seq_bucket(maxlen)
                if Sb > self.capacity:
                    Sb = max(maxlen, self.capacity)
            else:
                Sb = maxlen
            toks = np.zeros((Bb, Sb), np.int32)
            for i, ctx in enumerate(ctxs):
                toks[i, :len(ctx)] = ctx
            self._prefill_shapes.add((Bb, Sb))
            last_logits, pf_caches = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens))
            self.caches = self._seed(self.caches, pf_caches,
                                     jnp.asarray(slot_arr),
                                     jnp.asarray(lens))
        if self._async:
            # consume the carried device key — same stream as the sync
            # path's self.key, no host materialization
            key, sk = jax.random.split(self._dev["key"])
            self._dev = dict(self._dev, key=key)
        else:
            self.key, sk = jax.random.split(self.key)
        temps = np.zeros(Bb, np.float32)
        top_ks = np.zeros(Bb, np.int32)
        eos = np.full(Bb, -1, np.int32)
        for i, (r, _) in enumerate(group):
            g = self.requests[r.rid]
            temps[i] = g.params.temperature
            top_ks[i] = g.params.top_k
            eos[i] = -1 if g.params.eos_token is None else g.params.eos_token
        first = sample_per_request(last_logits, sk, temps, top_ks)
        if self._async:
            # device path: the first token never touches the host here —
            # it is scattered into the carried slot state and drained with
            # the regular lag-N ring
            fallback = np.zeros(Bb, np.int32)
            use_first = np.zeros(Bb, bool)
            mapping: List[Tuple[int, int]] = []
            for i, (r, _) in enumerate(group):
                g = self.requests[r.rid]
                self.pos[slots[i]] = lens[i]
                if r.generated == 0:
                    # the PT iteration produces the first response token (§1)
                    use_first[i] = True
                    mapping.append((i, r.rid))
                else:
                    fallback[i] = g.output[r.generated - 1]
            self._dev = self._seed_slots(
                self._dev, jnp.asarray(slot_arr), first,
                jnp.asarray(fallback), jnp.asarray(use_first),
                jnp.asarray(lens), jnp.asarray(temps),
                jnp.asarray(top_ks), jnp.asarray(eos))
            if mapping:
                self._pending_drain.append((first, mapping))
        else:
            first_np = np.asarray(first)
            for i, (r, _) in enumerate(group):
                g = self.requests[r.rid]
                slot = slots[i]
                self.pos[slot] = lens[i]
                if r.generated == 0:
                    # the PT iteration produces the first response token (§1)
                    tok = int(first_np[i])
                    g.output.append(tok)
                    self.last_tok[slot] = tok
                else:
                    self.last_tok[slot] = g.output[r.generated - 1]

    # ------------------------------------------------------------------ #
    def _run_decode(self, reqs: Sequence[Request], now: float) -> None:
        """Legacy sync decode: one host sync per iteration for the sampled
        batch, then per-request host reads. Kept as the reference the
        async path is equivalence-tested against."""
        if not reqs:
            return
        active = np.zeros(self.max_batch, bool)
        for r in reqs:
            active[self.slot_of[r.rid]] = True
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches, jnp.asarray(active))
        self.key, sk = jax.random.split(self.key)
        # inactive slots are likewise masked to greedy (temp 0) sampling
        # and their tokens never read back
        temps = np.where(active, self.temps, 0.0).astype(np.float32)
        top_ks = np.where(active, self.top_ks, 0).astype(np.int32)
        # this materialization waits on the iteration that was just
        # dispatched — the per-iteration blocking sync the async path removes
        self.sync_counts["drain_blocking"] += 1
        new_toks = np.asarray(sample_per_request(
            logits, sk, jnp.asarray(temps), jnp.asarray(top_ks)))
        self.decode_iters += 1
        for r in reqs:
            slot = self.slot_of[r.rid]
            g = self.requests[r.rid]
            tok = int(new_toks[slot])
            g.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if g.params.eos_token is not None and tok == g.params.eos_token:
                self.scheduler.notify_eos(r, r.generated + 1)

    def _run_decode_async(self, reqs: Sequence[Request], now: float) -> None:
        """Fused device-resident decode. The host builds the (B,) active
        mask, splits the RNG key (an async device op, identical key stream
        to the sync path) and dispatches the donated fused step; sampled
        tokens land in the lag-N drain ring. EOS flags are only read back
        when an active request actually has an ``eos_token`` — the clamp
        must reach the scheduler at the iteration EOS fires to keep its
        decisions bitwise-equal to the sync path."""
        if not reqs:
            return
        # drain first: entries had a whole scheduler cycle to finish on
        # device, so lag-expired drains are copies, not waits
        self._drain_tokens()
        active = np.zeros(self.max_batch, bool)
        eos_possible = False
        for r in reqs:
            active[self.slot_of[r.rid]] = True
            if self.requests[r.rid].params.eos_token is not None:
                eos_possible = True
        temps_m = np.where(active, self.temps, 0.0)
        need_sample = bool(np.any(temps_m > 0.0))
        need_topk = need_sample and bool(
            np.any(np.where(active, self.top_ks, 0) > 0))
        # the active mask only changes on admission/completion/preemption;
        # steady state reuses the cached device copy (no transfer dispatch)
        ab = active.tobytes()
        if ab != self._active_bytes:
            self._active_bytes = ab
            self._active_dev = jnp.asarray(active)
        self.caches, self._dev, toks, eos_hit = self._fused(
            self.params, self.caches, self._dev, self._active_dev,
            need_sample, need_topk)
        self.decode_iters += 1
        self._pending_drain.append(
            (toks, [(self.slot_of[r.rid], r.rid) for r in reqs]))
        if eos_possible:
            self.sync_counts["eos_flags"] += 1
            flags = np.asarray(eos_hit)
            for r in reqs:
                if flags[self.slot_of[r.rid]]:
                    self.scheduler.notify_eos(r, r.generated + 1)

    def _drain_tokens(self, force: bool = False) -> None:
        """Materialize pending sampled-token batches older than the lag.

        Steady state: an entry ``readback_lag`` iterations old has long
        finished on device, so the ``np.asarray`` is a copy, not a wait —
        the engine only accepts a potentially-blocking drain when the ring
        exceeds ``max_pending`` or a flush is forced (completion,
        preemption, idle, end of run)."""
        dq = self._pending_drain
        lag = 0 if force else self.ecfg.readback_lag
        while len(dq) > lag:
            toks, mapping = dq[0]
            ready = toks.is_ready()
            if not ready and not force and len(dq) <= self.ecfg.max_pending:
                break
            dq.popleft()
            key = "drain_ready" if ready else "drain_blocking"
            self.sync_counts[key] += 1
            arr = np.asarray(toks)
            for row, rid in mapping:
                self.requests[rid].output.append(int(arr[row]))

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One engine iteration. Returns number of completions."""
        now = time.monotonic() if now is None else now
        plan = self.scheduler.form_batch(now)
        if plan.empty:
            if self._pending_drain:
                self.sync_counts["flush"] += 1
                self._drain_tokens(force=True)
            return 0
        # GTs rescheduled after a swap-style preemption or deadlock-relief
        # eviction arrive with their KV "in host memory" — this engine has
        # no host KV store, so they are recomputed like an offload-free
        # re-prefill (prompt + generated so far), riding the iteration's
        # prefill wave so the rare preemption path costs no extra dispatch
        missing = [r for r in plan.decode_reqs if r.rid not in self.slot_of]
        if missing and self._pending_drain:     # ctx rebuild reads g.output
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        self._run_prefill([(r, r.prompt_len) for r in missing]
                          + list(plan.prompt_items), now)
        if self._async:
            self._run_decode_async(plan.decode_reqs, now)
        else:
            self._run_decode(plan.decode_reqs, now)
        before = len(self.scheduler.completed)
        self.scheduler.finish_iteration(now)
        done = self.scheduler.completed[before:]
        freed = False
        for r in done:
            g = self.requests[r.rid]
            g.t_done = r.t_complete
            slot = self.slot_of.pop(r.rid, None)
            if slot is not None:
                self.free_slots.append(slot)
                freed = True
        # preempted/evicted requests (KVC freed by the scheduler) lose
        # their slot; queued GTs keep theirs — their KV is live
        for rid in list(self.slot_of):
            if rid not in self.scheduler.kvc.allocs:
                self.free_slots.append(self.slot_of.pop(rid))
                freed = True
        if freed and self._pending_drain:
            # completed outputs must be materialized before t_done is
            # observable, and a preempted request rebuilds its recompute
            # context from g.output at the next prefill
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        return len(done)

    def run(self, gen_requests: Sequence[GenRequest],
            max_steps: int = 100_000) -> List[GenRequest]:
        t = 0.0
        for g in gen_requests:
            self.submit(g, t)
        steps = 0
        while (self.scheduler.has_work() and steps < max_steps):
            t += 1.0
            self.step(t)
            steps += 1
        if self._pending_drain:
            self.sync_counts["flush"] += 1
            self._drain_tokens(force=True)
        return list(gen_requests)
