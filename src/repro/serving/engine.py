"""Continuous-batching serving engine: real JAX model execution driven by
any `repro.core` scheduler (EconoServe by default).

The scheduler owns KVC block accounting, batching policy, SLO ordering,
and KVC pipelining; the engine owns slots, caches, jitted prefill/decode
steps and sampling. Completion is EOS- or max-tokens-driven; when EOS
fires early the request's `true_rl` is clamped so the scheduler sees the
real completion (the RL predictor only ever saw the prompt).

Scope note: the engine runs whole prompts as single PT items (it sizes TFS
to the longest prompt) — chunked-prefill policy is exercised by the
discrete-event simulator, not the CPU engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel, ModelProfile
from repro.core.predictor import NoisyPredictor, apply_padding
from repro.core.request import Request, State
from repro.core.scheduler import SchedulerConfig, make_econoserve
from repro.models import model
from repro.models.config import ModelConfig

from .sampling import SamplingParams, sample


@dataclass
class GenRequest:
    prompt: List[int]
    params: SamplingParams = field(default_factory=SamplingParams)
    rid: int = -1
    output: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[dict] = None, *,
                 max_batch: int = 8, capacity: int = 512,
                 scheduler_cfg: Optional[SchedulerConfig] = None,
                 variant: str = "full", impl: str = "xla",
                 rl_accuracy: float = 0.8, seed: int = 0):
        self.cfg = cfg
        self.impl = impl
        self.max_batch = max_batch
        self.capacity = capacity
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else model.init(cfg, key)
        self.key = jax.random.PRNGKey(seed + 1)

        scfg = scheduler_cfg or SchedulerConfig(
            kvc_tokens=max_batch * capacity, block_size=32,
            tfs=capacity, max_model_len=capacity,
            max_batch_reqs=max_batch)
        cost = CostModel(model=ModelProfile.from_config(cfg))
        self.scheduler = make_econoserve(scfg, cost, variant)
        self.predictor = NoisyPredictor(accuracy=rl_accuracy, seed=seed,
                                        bucket=scfg.bucket)

        # slot-based caches
        self.caches = model.init_cache(cfg, max_batch, capacity)
        self.slot_of: Dict[int, int] = {}
        self.free_slots = list(range(max_batch))
        self.pos = np.zeros(max_batch, np.int64)      # next absolute position
        self.last_tok = np.zeros(max_batch, np.int64)
        self.requests: Dict[int, GenRequest] = {}
        self._rid = 0

        self._decode = jax.jit(
            lambda p, tok, pos, caches: model.decode_step(
                cfg, p, tok, pos, caches, impl=impl))
        self._prefill = jax.jit(
            lambda p, tok: model.prefill(cfg, p, tok, impl=impl))

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float) -> int:
        req.rid = self._rid
        self._rid += 1
        req.t_submit = now
        r = Request(rid=req.rid, prompt_len=len(req.prompt),
                    true_rl=req.params.max_new_tokens, arrival=now)
        r.predicted_rl = self.predictor.predict(r)
        r.padded_rl = apply_padding(r.predicted_rl,
                                    self.scheduler.cfg.pad_ratio,
                                    self.scheduler.cfg.bucket)
        self.requests[req.rid] = req
        self.scheduler.on_arrival(r, now)
        return req.rid

    # ------------------------------------------------------------------ #
    def _run_prefill(self, items, now: float) -> None:
        """Execute PT items (whole prompts) and seed their cache slots."""
        for r, chunk in items:
            assert chunk == r.prompt_len, \
                "engine runs whole prompts; size TFS >= max prompt length"
            g = self.requests[r.rid]
            slot = self.free_slots.pop()
            self.slot_of[r.rid] = slot
            # after an offload-free preemption the context to recompute is
            # prompt + everything generated so far
            ctx = list(g.prompt) + g.output[:r.generated]
            toks = jnp.asarray(ctx, jnp.int32)[None, :]
            logits, pf_caches = self._prefill(self.params, toks)
            self._seed_slot(slot, pf_caches, len(ctx))
            self.pos[slot] = len(ctx)
            if r.generated == 0:
                # the PT iteration produces the first response token (§1)
                self.key, sk = jax.random.split(self.key)
                tok = int(sample(logits[:, -1], sk, g.params.temperature,
                                 g.params.top_k)[0])
                g.output.append(tok)
                self.last_tok[slot] = tok
            else:
                self.last_tok[slot] = g.output[r.generated - 1]

    def _seed_slot(self, slot: int, pf_caches, plen: int) -> None:
        def put(dst, src, seq_axis: Optional[int]):
            # dst (L, B, ...); src (L, 1, ...) or (L,1,S,...)
            idx = [slice(None)] * dst.ndim
            idx[1] = slice(slot, slot + 1)
            if seq_axis is not None:
                C = dst.shape[seq_axis]
                if src.shape[seq_axis] > C:     # sliding window: keep tail
                    src = jax.lax.slice_in_dim(
                        src, src.shape[seq_axis] - C, src.shape[seq_axis],
                        axis=seq_axis)
                    start = (plen - C) % C
                    src = jnp.roll(src, start, axis=seq_axis)
                idx[seq_axis] = slice(0, src.shape[seq_axis])
            dst = dst.at[tuple(idx)].set(src.astype(dst.dtype))
            return dst

        new = {}
        for kind, sub in self.caches.items():
            if kind in ("A", "shared"):
                new[kind] = {
                    "k": put(sub["k"], pf_caches[kind]["k"], 2),
                    "v": put(sub["v"], pf_caches[kind]["v"], 2),
                }
            else:
                new[kind] = jax.tree.map(
                    lambda d, s: put(d, s, None), sub, pf_caches[kind])
        self.caches = new

    # ------------------------------------------------------------------ #
    def _run_decode(self, reqs: Sequence[Request], now: float) -> None:
        if not reqs:
            return
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(self.params, toks, pos,
                                           self.caches)
        self.key, sk = jax.random.split(self.key)
        temps = max((self.requests[r.rid].params.temperature for r in reqs),
                    default=0.0)
        new_toks = np.asarray(sample(logits, sk, temps))
        for r in reqs:
            slot = self.slot_of[r.rid]
            g = self.requests[r.rid]
            tok = int(new_toks[slot])
            g.output.append(tok)
            self.pos[slot] += 1
            self.last_tok[slot] = tok
            if g.params.eos_token is not None and tok == g.params.eos_token:
                r.true_rl = r.generated + 1     # EOS: clamp for the scheduler

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One engine iteration. Returns number of completions."""
        now = time.monotonic() if now is None else now
        plan = self.scheduler.form_batch(now)
        if plan.empty:
            return 0
        self._run_prefill(plan.prompt_items, now)
        self._run_decode(plan.decode_reqs, now)
        before = len(self.scheduler.completed)
        self.scheduler.finish_iteration(time.monotonic()
                                        if now is None else now)
        done = self.scheduler.completed[before:]
        for r in done:
            g = self.requests[r.rid]
            g.t_done = r.t_complete
            slot = self.slot_of.pop(r.rid, None)
            if slot is not None:
                self.free_slots.append(slot)
        # preempted/evicted requests (KVC freed by the scheduler) lose
        # their slot; queued GTs keep theirs — their KV is live
        for rid in list(self.slot_of):
            if rid not in self.scheduler.kvc.allocs:
                self.free_slots.append(self.slot_of.pop(rid))
        return len(done)

    def run(self, gen_requests: Sequence[GenRequest],
            max_steps: int = 100_000) -> List[GenRequest]:
        t = 0.0
        for g in gen_requests:
            self.submit(g, t)
        steps = 0
        while (self.scheduler.has_work() and steps < max_steps):
            t += 1.0
            self.step(t)
            steps += 1
        return list(gen_requests)
