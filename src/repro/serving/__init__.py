"""Serving engine: continuous batching of real JAX models under the
EconoServe scheduler."""
from .engine import EngineConfig, GenRequest, ServingEngine
from .sampling import SamplingParams
