"""Serving engine: continuous batching of real JAX models under the
EconoServe scheduler."""
from .engine import (EngineConfig, FleetStalled, GenRequest,
                     InvalidRequestError, RequestShed, ServingEngine)
from .sampling import SamplingParams
