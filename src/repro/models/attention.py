"""GQA attention with RoPE, optional qk-norm and sliding window.

Two entry points:
  * ``attn_prefill`` — full-sequence causal attention, returns the layer
    output plus the K/V tensors to seed a cache.
  * ``attn_decode``  — one new token against a (possibly ring-buffer) cache.

The default math path is pure jnp (the oracle the Pallas kernels are tested
against); ``impl='pallas'`` routes the core attention through
``repro.kernels.ops`` on CPU via interpret mode.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import (HEADS, KV, EMBED, NUL, ParamMeta, ParamTree, apply_rope,
                     rms_norm, softcap)
from .config import ModelConfig

NEG_INF = -1e30
# sequences longer than this use the streaming jnp flash path in the XLA
# implementation (the dense S^2 path is kept for short-seq tests/decode)
FLASH_THRESHOLD = 2048


def attn_params(cfg: ModelConfig, *, kv_heads: Optional[int] = None) -> ParamTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh = cfg.num_heads
    nkv = kv_heads or cfg.num_kv_heads
    t: ParamTree = {
        "wq": ParamMeta((d, nh * hd), (EMBED, HEADS)),
        "wk": ParamMeta((d, nkv * hd), (EMBED, KV)),
        "wv": ParamMeta((d, nkv * hd), (EMBED, KV)),
        "wo": ParamMeta((nh * hd, d), (HEADS, EMBED)),
    }
    if cfg.use_qk_norm:
        t["q_norm"] = ParamMeta((hd,), (NUL,), init="ones")
        t["k_norm"] = ParamMeta((hd,), (NUL,), init="ones")
    return t


def _project_qkv(p: Dict[str, jax.Array], cfg: ModelConfig, x: jax.Array,
                 positions: jax.Array, nkv: int):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, nkv, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, nkv, hd)
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _flash_jnp(q: jax.Array, k: jax.Array, v: jax.Array,
               pos_q: jax.Array, pos_k: jax.Array, cfg: ModelConfig,
               block_q: int = 512, block_k: int = 1024,
               segment_ids: Optional[jax.Array] = None,
               kv_segment_ids: Optional[jax.Array] = None) -> jax.Array:
    """Streaming (flash-style) attention in pure jnp: double lax.scan with
    online softmax — O(S) memory instead of the S^2 logits tensor, and the
    q-block body is rematerialized in the backward pass. This is the XLA
    fallback for long sequences; the Pallas kernel is the TPU fast path.

    ``segment_ids`` (B, S) restricts attention to equal segments (token-
    packed prefill: a block-diagonal mask over concatenated prompts).
    ``kv_segment_ids`` (B, Sk) gives the key axis its own segment array
    (packed multi-request chunked prefill, where the key axis carries
    several requests' prefix views plus their chunks).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    G = H // K
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    seg_q = segment_ids
    seg_k = kv_segment_ids if kv_segment_ids is not None else segment_ids
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        pos_q = jnp.pad(pos_q, ((0, 0), (0, pq)), constant_values=-1)
        if seg_q is not None:       # -1/-2: pad q never matches any pad k
            seg_q = jnp.pad(seg_q, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pk)), constant_values=2**30)
        if seg_k is not None:
            seg_k = jnp.pad(seg_k, ((0, 0), (0, pk)), constant_values=-2)
    nq, nk = q.shape[1] // bq, k.shape[1] // bk
    packed = seg_q is not None
    qs = jnp.moveaxis(q.reshape(B, nq, bq, K, G, hd), 1, 0)
    pqs = jnp.moveaxis(pos_q.reshape(B, nq, bq), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, bk, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, bk, K, hd), 1, 0)
    pks = jnp.moveaxis(pos_k.reshape(B, nk, bk), 1, 0)
    if packed:
        sqs = jnp.moveaxis(seg_q.reshape(B, nq, bq), 1, 0)
        sks = jnp.moveaxis(seg_k.reshape(B, nk, bk), 1, 0)
    else:       # the scan operand structure must be static either way
        sqs = jnp.zeros((nq, B, 0), jnp.int32)
        sks = jnp.zeros((nk, B, 0), jnp.int32)
    scale = 1.0 / (hd ** 0.5)

    def q_step(_, inp):
        qi, pqi, sqi = inp                          # (B,bq,K,G,hd), (B,bq)

        def k_step(carry, inp2):
            m, l, acc = carry
            kj, vj, pkj, skj = inp2
            s = jnp.einsum("bskgh,btkh->bkgst", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            s = softcap(s, cfg.attn_logit_softcap)
            ii = pqi[:, None, None, :, None]
            jj = pkj[:, None, None, None, :]
            mask = jj <= ii
            if cfg.sliding_window is not None:
                mask &= jj > ii - cfg.sliding_window
            if packed:      # block-diagonal (token-packed) masking only
                mask &= (sqi[:, None, None, :, None]
                         == skj[:, None, None, None, :])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = alpha * l + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p, vj.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, K, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        a0 = jnp.zeros((B, K, G, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (ks, vs, pks, sks))
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,bq,hd)
        return None, jnp.moveaxis(o, 3, 1)          # (B,bq,K,G,hd)

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (qs, pqs, sqs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, q.shape[1], H, hd)
    return out[:, :Sq].astype(q.dtype)


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
          cfg: ModelConfig) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Sk,K,hd), mask (B,Sq,Sk) or (1,Sq,Sk) bool."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    # keep K/V in their storage dtype: the MXU multiplies bf16 natively with
    # fp32 accumulation — upcasting the whole cache would double its HBM
    # traffic (decode roofline iteration 1, EXPERIMENTS.md §Perf)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) \
        / jnp.sqrt(hd).astype(jnp.float32)
    logits = softcap(logits, cfg.attn_logit_softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


POS_INVALID = 2 ** 30           # mirrors kernels.flash_prefill.POS_INVALID


def attn_prefill(p, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                 *, segment_ids: Optional[jax.Array] = None,
                 kv_heads: Optional[int] = None, impl: str = "xla",
                 prefix_k: Optional[jax.Array] = None,
                 prefix_v: Optional[jax.Array] = None,
                 prefix_len: Optional[jax.Array] = None,
                 prefix_positions: Optional[jax.Array] = None,
                 prefix_segment_ids: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """``segment_ids`` (B, S) enables token-packed prefill: several prompts
    concatenated along the sequence axis attend block-diagonally (equal
    segment only), with ``positions`` restarting per segment.

    ``prefix_k``/``prefix_v`` (B, C, K, hd) + ``prefix_len`` (scalar)
    enable chunked prefill: the chunk queries attend over the first
    ``prefix_len`` slots of an already-seeded cache row (identity
    placement — token p at slot p, already RoPE'd) and then causally over
    the chunk itself, whose ``positions`` are absolute (offset by the
    prefix). Returns only the *chunk's* K/V for seeding.

    Packed multi-request chunked prefill combines both: ``segment_ids``
    marks each chunk's tokens, ``prefix_k``/``prefix_v`` concatenate the
    requests' cache-prefix views along the key axis, and
    ``prefix_positions``/``prefix_segment_ids`` (B, C) replace the
    scalar ``prefix_len`` — per-prefix-slot positions (``POS_INVALID``
    beyond each request's seeded prefix) and owning segment ids. Every
    chunk then attends over its own prefix view plus itself, block-
    diagonally, in ONE rectangular call.
    """
    B, S, _ = x.shape
    nkv = kv_heads or cfg.num_kv_heads
    q, k, v = _project_qkv(p, cfg, x, positions, nkv)
    if prefix_k is not None:
        # chunk continuation: key axis = seeded cache-prefix view (slots
        # [0, prefix_len) hold already-RoPE'd K at identity positions)
        # concatenated with the chunk; invalid prefix slots carry the
        # POS_INVALID sentinel, which causality masks
        C = prefix_k.shape[1]
        if prefix_positions is not None:
            kpos_prefix = jnp.broadcast_to(prefix_positions, (B, C))
        else:
            slot = jnp.arange(C)
            kpos_prefix = jnp.broadcast_to(
                jnp.where(slot < prefix_len, slot, POS_INVALID)[None],
                (B, C))
        kpos = jnp.concatenate([kpos_prefix, positions], axis=1)
        kseg = None
        if segment_ids is not None:
            kseg = jnp.concatenate(
                [jnp.broadcast_to(prefix_segment_ids, (B, C)), segment_ids],
                axis=1)
        k_all = jnp.concatenate([prefix_k.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([prefix_v.astype(v.dtype), v], axis=1)
        if impl == "pallas":
            from repro.kernels import ops
            out = ops.flash_attention(q, k_all, v_all, segment_ids,
                                      positions, kpos, kseg, causal=True,
                                      window=cfg.sliding_window,
                                      softcap=cfg.attn_logit_softcap)
        elif C + S > FLASH_THRESHOLD:
            out = _flash_jnp(q, k_all, v_all, positions, kpos, cfg,
                             segment_ids=segment_ids, kv_segment_ids=kseg)
        else:
            ii = positions[:, :, None]  # query positions (B,S,1)
            jj = kpos[:, None, :]       # key positions (B,1,C+S)
            mask = jj <= ii
            if cfg.sliding_window is not None:
                mask &= jj > ii - cfg.sliding_window
            if kseg is not None:
                mask &= segment_ids[:, :, None] == kseg[:, None, :]
            out = _sdpa(q, k_all, v_all, mask, cfg)
    elif impl == "pallas":
        from repro.kernels import ops
        out = ops.flash_attention(q, k, v, causal=True,
                                  window=cfg.sliding_window,
                                  softcap=cfg.attn_logit_softcap,
                                  segment_ids=segment_ids)
    elif S > FLASH_THRESHOLD:
        out = _flash_jnp(q, k, v, positions, positions, cfg,
                         segment_ids=segment_ids)
    else:
        ii = positions[:, :, None]  # query positions (B,S,1)
        jj = positions[:, None, :]  # key positions (B,1,S)
        mask = jj <= ii
        if cfg.sliding_window is not None:
            mask &= jj > ii - cfg.sliding_window
        if segment_ids is not None:
            mask &= segment_ids[:, :, None] == segment_ids[:, None, :]
        out = _sdpa(q, k, v, mask, cfg)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), p["wo"])
    return y, (k, v)


def attn_decode(p, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                cache_k: jax.Array, cache_v: jax.Array,
                *, kv_heads: Optional[int] = None, impl: str = "xla"
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode.

    x (B,1,d); pos (B,) absolute position of the new token;
    cache_k/v (B, C, K, hd) where C = full context or sliding window size.
    Returns (y (B,1,d), updated cache).
    """
    B = x.shape[0]
    C = cache_k.shape[1]
    nkv = kv_heads or cfg.num_kv_heads
    q, k, v = _project_qkv(p, cfg, x, pos[:, None], nkv)

    windowed = cfg.sliding_window is not None and C == cfg.sliding_window
    slot = jnp.where(windowed, pos % C, jnp.minimum(pos, C - 1))
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])

    idx = jnp.arange(C)[None, :]                     # (1,C) slot index
    if windowed:
        # ring buffer: slot i holds the token `age = (slot - i) mod C` steps
        # back; valid iff that token has been written (age <= pos).
        age = jnp.mod(slot[:, None] - idx, C)
        mask = age <= pos[:, None]
    else:
        mask = idx <= slot[:, None]
    if impl == "pallas":
        from repro.kernels import ops
        # every written slot is valid; softmax is permutation-invariant, so
        # ring-buffer slot order does not matter — a count suffices
        n_valid = jnp.minimum(pos + 1, C) if windowed else pos + 1
        out = ops.decode_attention(q[:, 0], cache_k, cache_v, n_valid,
                                   softcap=cfg.attn_logit_softcap)[:, None]
    else:
        out = _sdpa(q, cache_k, cache_v, mask[:, None, :], cfg)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, -1), p["wo"])
    return y, (cache_k, cache_v)
