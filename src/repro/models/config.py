"""Model configuration for every architecture family the framework supports.

A single dataclass covers dense GQA transformers, MoE, Mamba2/SSM, xLSTM,
hybrid (Zamba2-style shared attention), and VLM/audio backbones whose
modality frontends are stubs (precomputed embeddings).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Layer kind codes used in ``layer_pattern``:
#   'A' full attention block (attn + mlp)
#   'M' Mamba2 block
#   'S' sLSTM block
#   'X' mLSTM block
ATTN, MAMBA, SLSTM, MLSTM = "A", "M", "S", "X"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # ---- attention details -------------------------------------------------
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    attn_logit_softcap: Optional[float] = None

    # ---- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 2
    moe_d_ff: int = 0            # per-expert hidden size (0 -> d_ff)
    moe_dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01

    # ---- SSM (Mamba2) ------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256         # chunked SSD scan length

    # ---- xLSTM -------------------------------------------------------------
    xlstm_proj_factor: float = 2.0

    # ---- layer layout ------------------------------------------------------
    # If None: homogeneous stack of the arch_type's default block.
    # Otherwise a string over {A,M,S,X} of length num_layers.
    layer_pattern: Optional[str] = None
    # Zamba2-style: a single shared attention block applied every k-th layer
    # (weights shared across invocations). When set, layer_pattern covers the
    # non-shared layers only.
    shared_attention_every: int = 0
    shared_attn_kv_heads: int = 0  # kv heads for the shared block (0 -> num_kv_heads)

    # ---- modality frontend (stub) -------------------------------------------
    # 'vision' | 'audio' -> prefill accepts precomputed embeddings that are
    # prepended to the token embeddings.
    frontend: Optional[str] = None
    frontend_tokens: int = 0     # patches / audio-cond frames at prefill

    # ---- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    rms_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    # citation / provenance for the assigned-architecture pool
    source: str = ""

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def pattern(self) -> str:
        """Resolved per-layer kind string (excluding shared attention)."""
        if self.layer_pattern is not None:
            assert len(self.layer_pattern) == self.num_layers, (
                f"{self.name}: layer_pattern length {len(self.layer_pattern)} "
                f"!= num_layers {self.num_layers}")
            return self.layer_pattern
        if self.arch_type == "ssm":
            return MAMBA * self.num_layers
        return ATTN * self.num_layers

    def block_kinds(self) -> Tuple[str, ...]:
        """Unique layer kinds present, in first-appearance order."""
        seen = []
        for c in self.pattern():
            if c not in seen:
                seen.append(c)
        return tuple(seen)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return ATTN in self.pattern() or self.shared_attention_every > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic per-token decode state: SSM/hybrid or windowed attn."""
        if not self.has_attention:
            return True
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self, *, layers: int = 2, d_model: int = 256,
                experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        heads = max(2, min(4, self.num_heads))
        kv = heads if self.num_kv_heads >= self.num_heads else max(1, heads // 2)
        d_model = max(d_model, heads * 32)
        pat = None
        if self.layer_pattern is not None:
            # keep the kind mix: take a slice that contains every kind
            kinds = self.block_kinds()
            pat = ("".join(kinds) * layers)[: layers]
            layers = len(pat)
        kw = dict(
            num_layers=layers, d_model=d_model, num_heads=heads,
            num_kv_heads=kv, head_dim=d_model // heads,
            d_ff=0 if self.d_ff == 0 else 4 * d_model,
            vocab_size=min(self.vocab_size, vocab),
            layer_pattern=pat,
            ssm_head_dim=32, ssm_state=min(self.ssm_state, 16) or 0,
            ssm_chunk=32,
            frontend_tokens=8 if self.frontend else 0,
            remat=False,
        )
        if self.is_moe:
            kw.update(num_experts=min(self.num_experts, experts),
                      moe_d_ff=2 * d_model)
        if self.shared_attention_every:
            kw.update(shared_attention_every=min(self.shared_attention_every, 2))
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.with_(**kw)
