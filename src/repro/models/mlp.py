"""Dense SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import EMBED, MLP, ParamMeta, ParamTree, swiglu
from .config import ModelConfig


def mlp_params(cfg: ModelConfig, d_ff: int = 0) -> ParamTree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": ParamMeta((d, f), (EMBED, MLP)),
        "w_up": ParamMeta((d, f), (EMBED, MLP)),
        "w_down": ParamMeta((f, d), (MLP, EMBED)),
    }


def mlp_apply(p, x: jax.Array) -> jax.Array:
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])
