"""Shared building blocks: parameter metadata, norms, rotary embeddings.

The framework is pure JAX (no flax). Every module contributes parameter
*metadata* — (shape, logical axes, init scale) — into a flat dict keyed by
path. From that single source we derive:
  * materialized params            (init_params)
  * abstract ShapeDtypeStructs     (abstract_params, for the dry-run)
  * PartitionSpecs                 (via distributed.sharding rules)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis names. distributed/sharding.py maps these to mesh axes.
VOCAB = "vocab"
EMBED = "embed"        # d_model
HEADS = "heads"        # fused q heads * head_dim
KV = "kv"              # fused kv heads * head_dim
MLP = "mlp"            # ffn hidden
EXPERT = "expert"
INNER = "inner"        # ssm/xlstm inner width
STATE = "state"        # ssm state dim
LAYER = "layer"        # stacked-layer leading dim
NUL = None


@dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Dict[str, ParamMeta]


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    # last dim is fan-out by convention; everything before contracts
    return int(np.prod(shape[:-1]))


def materialize(meta: ParamMeta, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    if meta.init == "zeros":
        return jnp.zeros(meta.shape, dtype)
    if meta.init == "ones":
        return jnp.ones(meta.shape, dtype)
    std = meta.scale / math.sqrt(max(1, _fan_in(meta.shape)))
    if meta.init == "small":
        std *= 0.1
    return (std * jax.random.normal(key, meta.shape, jnp.float32)).astype(dtype)


def init_params(tree: ParamTree, key: jax.Array, dtype) -> Dict[str, jax.Array]:
    names = sorted(tree)
    keys = jax.random.split(key, len(names))
    return {n: materialize(tree[n], k, dtype) for n, k in zip(names, keys)}


def abstract_params(tree: ParamTree, dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    return {n: jax.ShapeDtypeStruct(m.shape, dtype) for n, m in tree.items()}


def param_axes(tree: ParamTree) -> Dict[str, Tuple[Optional[str], ...]]:
    return {n: m.axes for n, m in tree.items()}


# --------------------------------------------------------------------------- #
# numerics
# --------------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., :, None, :]                      # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (..., V) float; labels (...) int.

    The label term uses a one-hot contraction instead of take_along_axis —
    a gather across a vocab-sharded logits tensor would force GSPMD to
    replicate it; the einsum keeps the sharding.
    """
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("...v,...v->...", logits, onehot).astype(jnp.float32)
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


_ACTIVE_MESH_AXES: tuple = ()
_ACTIVE_MESH_SIZES: dict = {}
_ACTIVE_MESH = None


def set_mesh_axes(axes, sizes: dict | None = None, mesh=None) -> None:
    """Declare the mesh axis names (and sizes) activation constraints may
    reference. Called by the launchers (build_step / train) — empty in CPU
    tests, in which case maybe_constrain is a no-op."""
    global _ACTIVE_MESH_AXES, _ACTIVE_MESH_SIZES, _ACTIVE_MESH
    _ACTIVE_MESH_AXES = tuple(axes)
    _ACTIVE_MESH_SIZES = dict(sizes or {})
    _ACTIVE_MESH = mesh


def active_mesh():
    return _ACTIVE_MESH


def data_shards() -> int:
    """Product of the batch-axis sizes of the active mesh (1 in tests)."""
    n = 1
    for a in BATCH_AXES:
        n *= _ACTIVE_MESH_SIZES.get(a, 1)
    return n


def maybe_constrain(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the declared mesh axes; no-op when
    none are declared. axes entries may be None / str / tuple."""
    names = set(_ACTIVE_MESH_AXES)
    if not names:
        return x

    def ok(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            picked = tuple(x_ for x_ in a if x_ in names)
            return picked or None
        return a if a in names else None

    spec = jax.sharding.PartitionSpec(*[ok(a) for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


BATCH_AXES = ("pod", "data")
