"""Mamba2 (SSD) block — chunked matmul form for training/prefill (TPU-native:
the recurrence becomes MXU matmuls over chunk-local decay matrices plus a
short inter-chunk scan), single-step recurrent form for decode.

Layout follows the Mamba2 paper with n_groups = 1:
  in_proj -> [z (di), x (di), B (n), C (n), dt (nh)]
  causal conv1d over [x, B, C]; SSD; gated RMSNorm; out_proj.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import EMBED, INNER, NUL, STATE, ParamMeta, ParamTree, rms_norm
from .config import ModelConfig


def ssm_dims(cfg: ModelConfig):
    di = cfg.d_inner
    nh = cfg.ssm_heads
    n = cfg.ssm_state
    conv_dim = di + 2 * n
    return di, nh, n, conv_dim


def ssm_params(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    di, nh, n, conv_dim = ssm_dims(cfg)
    w = cfg.ssm_conv_width
    return {
        "in_proj": ParamMeta((d, 2 * di + 2 * n + nh), (EMBED, INNER)),
        "conv_w": ParamMeta((w, conv_dim), (NUL, INNER), init="small"),
        "conv_b": ParamMeta((conv_dim,), (INNER,), init="zeros"),
        "A_log": ParamMeta((nh,), (NUL,), init="ones"),
        "D": ParamMeta((nh,), (NUL,), init="ones"),
        "dt_bias": ParamMeta((nh,), (NUL,), init="zeros"),
        "norm": ParamMeta((di,), (INNER,), init="ones"),
        "out_proj": ParamMeta((di, d), (INNER, EMBED)),
    }


def _split_proj(p, cfg: ModelConfig, u: jax.Array):
    di, nh, n, _ = ssm_dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xs, Bm, Cm, dt


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., l) -> (..., l, l) lower-tri seg[i,j] = sum_{j+1..i} a."""
    cum = jnp.cumsum(a, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    l = a.shape[-1]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssm_prefill(p, cfg: ModelConfig, u: jax.Array, init=None,
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """u (B,S,d) with S a multiple of ssm_chunk (pad upstream).

    Returns (y (B,S,d), cache {h, conv}). ``init`` (a previous call's
    cache, or a decode cache) resumes the recurrence mid-sequence —
    chunked prefill carries the state forward instead of recomputing the
    prefix: the conv history seeds the causal conv window and ``h`` seeds
    the inter-chunk scan. ``init=None`` is bit-identical to the zero
    state.
    """
    B, S0, _ = u.shape
    di, nh, n, conv_dim = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S0)
    # pad the sequence to a chunk multiple; padded steps get dt = 0, which
    # leaves the state untouched (dA = exp(0) = 1, input weight dt = 0)
    S = -(-S0 // Q) * Q
    nc = S // Q

    z, xs, Bm, Cm, dt = _split_proj(p, cfg, u)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)               # (B,S0,conv)
    w = cfg.ssm_conv_width
    history = init["conv"].astype(xbc.dtype) if init is not None \
        else jnp.zeros((B, w - 1, conv_dim), xbc.dtype)
    conv_cache = jnp.concatenate([history, xbc], axis=1)[:, S0:]
    if S != S0:
        z, xs, Bm, Cm, dt, xbc = (
            jnp.pad(t, ((0, 0), (0, S - S0), (0, 0)))
            for t in (z, xs, Bm, Cm, dt, xbc))
    xbc_pad = jnp.concatenate([history, xbc], axis=1)
    conv = sum(xbc_pad[:, i:i + S] * p["conv_w"][w - 1 - i]
               for i in range(w)) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    if S != S0:
        valid = (jnp.arange(S) < S0)[None, :, None]
        dt = dt * valid
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (nh,)
    xh = xs.reshape(B, S, nh, hd).astype(jnp.float32)

    # chunked SSD
    c = lambda t: t.reshape(B, nc, Q, *t.shape[2:])
    dt_c, x_c = c(dt), c(xh)                                   # (B,nc,Q,nh[,hd])
    B_c, C_c = c(Bm.astype(jnp.float32)), c(Cm.astype(jnp.float32))  # (B,nc,Q,n)
    a_c = dt_c * A                                             # (B,nc,Q,nh)
    a_cum = jnp.cumsum(a_c, axis=2)
    L = jnp.exp(_segsum(jnp.moveaxis(a_c, -1, 2)))             # (B,nc,nh,Q,Q)
    xdt = x_c * dt_c[..., None]                                # (B,nc,Q,nh,hd)

    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp",
                        C_c, B_c, L, xdt)
    decay_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)           # (B,nc,Q,nh)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", B_c, decay_end, xdt)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                  # (B,nc,nh)

    def scan_fn(h, inp):
        st, dec = inp
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    h0 = init["h"].astype(jnp.float32) if init is not None \
        else jnp.zeros((B, nh, hd, n), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                      # (B,nc,nh,hd,n)

    in_decay = jnp.exp(a_cum)                                  # (B,nc,Q,nh)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", C_c, h_prevs, in_decay)
    y = (y_diag + y_off).reshape(B, S, nh, hd) \
        + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B, S, di).astype(u.dtype)[:, :S0]

    y = rms_norm(y * jax.nn.silu(z[:, :S0]), p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    cache = {"h": h_last.astype(jnp.float32), "conv": conv_cache}
    return out, cache


def ssm_decode(p, cfg: ModelConfig, u: jax.Array, cache: Dict[str, jax.Array],
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """u (B,1,d); cache {'h': (B,nh,hd,n) fp32, 'conv': (B,w-1,conv_dim)}."""
    B = u.shape[0]
    di, nh, n, conv_dim = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    w = cfg.ssm_conv_width

    z, xs, Bm, Cm, dt = _split_proj(p, cfg, u)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)[:, 0]         # (B,conv)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,w,conv)
    # prefill convention: conv_w[0] weights the newest token — flip history
    conv = jnp.einsum("bwc,wc->bc", jnp.flip(hist, axis=1),
                      p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                       # (B,nh)
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)    # (B,n)

    h = cache["h"] * dA[:, :, None, None] \
        + jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bf)
    y = jnp.einsum("bhpn,bn->bhp", h, Cf) \
        + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.rms_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h, "conv": hist[:, 1:]}


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, nh, n, conv_dim = ssm_dims(cfg)
    return {"h": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype)}
