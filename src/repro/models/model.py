"""Model assembly: pattern-driven block stacks with scanned homogeneous
segments, shared-attention (Zamba2-style) support, prefill/decode/train paths.

Params live in a flat dict ``{path: array}``. Layers of the same kind are
stacked along a leading LAYER axis and executed with ``lax.scan`` over
contiguous segments of the layer pattern — this keeps compile time sane for
80-layer models while supporting interleaved patterns.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention, mlp, moe, ssm, xlstm
from .common import (BATCH_AXES, EMBED, LAYER, NUL, VOCAB, ParamMeta,
                     ParamTree, abstract_params, init_params,
                     maybe_constrain, rms_norm)
from .config import ATTN, MAMBA, MLSTM, SLSTM, ModelConfig

Params = Dict[str, jax.Array]
Cache = Dict[str, Any]


# --------------------------------------------------------------------------- #
# parameter tree
# --------------------------------------------------------------------------- #
def _block_tree(cfg: ModelConfig, kind: str) -> ParamTree:
    """Per-layer (unstacked) parameter tree for one block kind."""
    d = cfg.d_model
    t: ParamTree = {}
    if kind == ATTN:
        t["norm1"] = ParamMeta((d,), (EMBED,), init="ones")
        for k, m in attention.attn_params(cfg).items():
            t[f"attn/{k}"] = m
        t["norm2"] = ParamMeta((d,), (EMBED,), init="ones")
        if cfg.is_moe:
            for k, m in moe.moe_params(cfg).items():
                t[f"moe/{k}"] = m
            if cfg.moe_dense_residual:
                for k, m in mlp.mlp_params(cfg).items():
                    t[f"mlp/{k}"] = m
        else:
            for k, m in mlp.mlp_params(cfg).items():
                t[f"mlp/{k}"] = m
    elif kind == MAMBA:
        t["norm"] = ParamMeta((d,), (EMBED,), init="ones")
        for k, m in ssm.ssm_params(cfg).items():
            t[f"ssm/{k}"] = m
    elif kind == SLSTM:
        t["norm"] = ParamMeta((d,), (EMBED,), init="ones")
        for k, m in xlstm.slstm_params(cfg).items():
            t[f"cell/{k}"] = m
    elif kind == MLSTM:
        t["norm"] = ParamMeta((d,), (EMBED,), init="ones")
        for k, m in xlstm.mlstm_params(cfg).items():
            t[f"cell/{k}"] = m
    else:
        raise ValueError(kind)
    return t


def segments(cfg: ModelConfig) -> List[Tuple[str, int, int]]:
    """Contiguous same-kind runs of the pattern: (kind, offset_in_kind, len).

    ``offset_in_kind`` indexes into the stacked params of that kind.
    """
    pat = cfg.pattern()
    segs: List[Tuple[str, int, int]] = []
    counts: Dict[str, int] = {}
    i = 0
    while i < len(pat):
        j = i
        while j < len(pat) and pat[j] == pat[i]:
            j += 1
        k = pat[i]
        segs.append((k, counts.get(k, 0), j - i))
        counts[k] = counts.get(k, 0) + (j - i)
        i = j
    return segs


def kind_counts(cfg: ModelConfig) -> Dict[str, int]:
    c: Dict[str, int] = {}
    for ch in cfg.pattern():
        c[ch] = c.get(ch, 0) + 1
    return c


def num_shared_invocations(cfg: ModelConfig) -> int:
    if not cfg.shared_attention_every:
        return 0
    return cfg.num_layers // cfg.shared_attention_every


def param_tree(cfg: ModelConfig) -> ParamTree:
    d, v = cfg.d_model, cfg.vocab_size
    t: ParamTree = {"embed/tok": ParamMeta((v, d), (VOCAB, EMBED))}
    for kind, n in kind_counts(cfg).items():
        for k, m in _block_tree(cfg, kind).items():
            t[f"{kind}/{k}"] = ParamMeta((n,) + m.shape, (LAYER,) + m.axes,
                                         init=m.init, scale=m.scale)
    if num_shared_invocations(cfg):
        scfg = cfg if not cfg.shared_attn_kv_heads else cfg.with_(
            num_kv_heads=cfg.shared_attn_kv_heads)
        t["shared/norm1"] = ParamMeta((d,), (EMBED,), init="ones")
        for k, m in attention.attn_params(scfg).items():
            t[f"shared/attn/{k}"] = m
        t["shared/norm2"] = ParamMeta((d,), (EMBED,), init="ones")
        for k, m in mlp.mlp_params(cfg).items():
            t[f"shared/mlp/{k}"] = m
    t["final_norm"] = ParamMeta((d,), (EMBED,), init="ones")
    if not cfg.tie_embeddings:
        t["head"] = ParamMeta((d, v), (EMBED, VOCAB))
    return t


def init(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_params(param_tree(cfg), key, jnp.dtype(cfg.param_dtype))


def abstract(cfg: ModelConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    return abstract_params(param_tree(cfg), jnp.dtype(cfg.param_dtype))


def _sub(params: Params, prefix: str) -> Params:
    pl = len(prefix)
    return {k[pl:]: v for k, v in params.items() if k.startswith(prefix)}


def _constrain_acts(x: jax.Array) -> jax.Array:
    """Residual-stream sharding: batch over (pod,data); sequence over
    "model" (Megatron-style sequence parallelism) — without it the remat-
    saved per-layer activations are replicated across the model axis."""
    seq = "model" if x.shape[1] > 1 else None
    return maybe_constrain(x, BATCH_AXES, seq, None)


# --------------------------------------------------------------------------- #
# block application
# --------------------------------------------------------------------------- #
def _apply_block_prefill(cfg: ModelConfig, kind: str, p: Params, x, positions,
                         impl: str, segment_ids=None, prefix=None,
                         prefix_len=None, prefix_positions=None,
                         prefix_segment_ids=None):
    """Returns (x_out, cache_slice, aux). ``prefix`` is this layer's chunk
    resume point: for attention, the seeded cache row {'k','v'} the chunk
    attends over (with ``prefix_len`` or per-slot ``prefix_positions`` /
    ``prefix_segment_ids`` for the packed multi-request form); for
    recurrent kinds, the carried state snapshot the chunk continues
    from."""
    aux = jnp.zeros((), jnp.float32)
    if kind == ATTN:
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        y, (k, v) = attention.attn_prefill(
            _sub(p, "attn/"), cfg, h, positions,
            segment_ids=segment_ids, impl=impl,
            prefix_k=None if prefix is None else prefix["k"],
            prefix_v=None if prefix is None else prefix["v"],
            prefix_len=prefix_len, prefix_positions=prefix_positions,
            prefix_segment_ids=prefix_segment_ids)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.rms_eps)
        if cfg.is_moe:
            y, aux = moe.moe_apply(_sub(p, "moe/"), cfg, h)
            if cfg.moe_dense_residual:
                y = y + mlp.mlp_apply(_sub(p, "mlp/"), h)
        else:
            y = mlp.mlp_apply(_sub(p, "mlp/"), h)
        return x + y, {"k": k, "v": v}, aux
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    if kind == MAMBA:
        y, cache = ssm.ssm_prefill(_sub(p, "ssm/"), cfg, h, init=prefix)
    elif kind == MLSTM:
        y, cache = xlstm.mlstm_prefill(_sub(p, "cell/"), cfg, h, init=prefix)
    elif kind == SLSTM:
        y, cache = xlstm.slstm_prefill(_sub(p, "cell/"), cfg, h, init=prefix)
    else:
        raise ValueError(kind)
    return x + y, cache, aux


def _apply_block_decode(cfg: ModelConfig, kind: str, p: Params, x, pos,
                        cache, impl: str):
    if kind == ATTN:
        h = rms_norm(x, p["norm1"], cfg.rms_eps)
        y, (ck, cv) = attention.attn_decode(_sub(p, "attn/"), cfg, h, pos,
                                            cache["k"], cache["v"], impl=impl)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.rms_eps)
        if cfg.is_moe:
            y, _ = moe.moe_apply(_sub(p, "moe/"), cfg, h)
            if cfg.moe_dense_residual:
                y = y + mlp.mlp_apply(_sub(p, "mlp/"), h)
        else:
            y = mlp.mlp_apply(_sub(p, "mlp/"), h)
        return x + y, {"k": ck, "v": cv}
    h = rms_norm(x, p["norm"], cfg.rms_eps)
    if kind == MAMBA:
        y, cache = ssm.ssm_decode(_sub(p, "ssm/"), cfg, h, cache)
    elif kind == MLSTM:
        y, cache = xlstm.mlstm_decode(_sub(p, "cell/"), cfg, h, cache)
    elif kind == SLSTM:
        y, cache = xlstm.slstm_decode(_sub(p, "cell/"), cfg, h, cache)
    else:
        raise ValueError(kind)
    return x + y, cache


def _shared_attn_prefill(cfg, params, x, positions, impl, segment_ids=None,
                         prefix=None, prefix_len=None, prefix_positions=None,
                         prefix_segment_ids=None):
    scfg = cfg if not cfg.shared_attn_kv_heads else cfg.with_(
        num_kv_heads=cfg.shared_attn_kv_heads)
    p = _sub(params, "shared/")
    h = rms_norm(x, p["norm1"], cfg.rms_eps)
    y, (k, v) = attention.attn_prefill(
        _sub(p, "attn/"), scfg, h, positions,
        segment_ids=segment_ids, kv_heads=scfg.num_kv_heads, impl=impl,
        prefix_k=None if prefix is None else prefix["k"],
        prefix_v=None if prefix is None else prefix["v"],
        prefix_len=prefix_len, prefix_positions=prefix_positions,
        prefix_segment_ids=prefix_segment_ids)
    x = x + y
    h = rms_norm(x, p["norm2"], cfg.rms_eps)
    return x + mlp.mlp_apply(_sub(p, "mlp/"), h), (k, v)


def _shared_attn_decode(cfg, params, x, pos, ck, cv, impl):
    scfg = cfg if not cfg.shared_attn_kv_heads else cfg.with_(
        num_kv_heads=cfg.shared_attn_kv_heads)
    p = _sub(params, "shared/")
    h = rms_norm(x, p["norm1"], cfg.rms_eps)
    y, (ck, cv) = attention.attn_decode(
        _sub(p, "attn/"), scfg, h, pos, ck, cv,
        kv_heads=scfg.num_kv_heads, impl=impl)
    x = x + y
    h = rms_norm(x, p["norm2"], cfg.rms_eps)
    return x + mlp.mlp_apply(_sub(p, "mlp/"), h), (ck, cv)


# --------------------------------------------------------------------------- #
# embeddings & logits
# --------------------------------------------------------------------------- #
def embed_inputs(cfg: ModelConfig, params: Params, tokens: jax.Array,
                 embeds: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["embed/tok"], tokens, axis=0).astype(cfg.dtype)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(cfg.dtype), x], axis=1)
    return x


def logits_fn(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed/tok"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("...d,dv->...v", x, head)
    # batch over (pod, data), vocab over model — keeps CE sharded
    return maybe_constrain(logits, BATCH_AXES,
                           *([None] * (logits.ndim - 2)), "model")


# --------------------------------------------------------------------------- #
# full passes
# --------------------------------------------------------------------------- #
def _run_stack(cfg: ModelConfig, params: Params, x: jax.Array,
               positions: jax.Array, impl: str,
               decode: bool = False, pos=None, caches: Optional[Cache] = None,
               segment_ids: Optional[jax.Array] = None,
               prefix_caches: Optional[Cache] = None, prefix_len=None,
               prefix_positions=None, prefix_segment_ids=None):
    """Shared driver for prefill (decode=False) and decode (decode=True).

    ``prefix_caches``/``prefix_len`` (prefill only): per-layer seeded cache
    rows a chunk's queries attend over (chunked prefill) — threaded through
    the layer scan exactly like decode threads its caches. The packed
    multi-request form replaces the scalar ``prefix_len`` with per-slot
    ``prefix_positions``/``prefix_segment_ids``; recurrent kinds instead
    receive their carried state snapshots through the same pytree.
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, List] = {k: [] for k in cfg.block_kinds()}
    shared_caches: List = []
    every = cfg.shared_attention_every
    n_done = 0          # pattern layers consumed
    shared_i = 0

    for kind, off, length in segments(cfg):
        stacked = _sub(params, f"{kind}/")
        # split the segment at shared-attention insertion points
        sub_start = 0
        while sub_start < length:
            if every:
                upto = (n_done // every + 1) * every - n_done
                run = min(length - sub_start, upto)
            else:
                run = length - sub_start
            seg_params = jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, off + sub_start,
                                               off + sub_start + run, axis=0),
                stacked)
            # --- scan over the run (single-layer runs skip the scan: the
            # XLA while-loop wrapper costs real per-step overhead on the
            # decode hot path, and hybrid patterns produce many length-1
            # segments; the unrolled call is mathematically identical) ---
            x = _constrain_acts(x)
            if decode:
                cache_off = _cache_offset(new_caches[kind])
                seg_cache = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, cache_off,
                                                   cache_off + run, axis=0),
                    caches[kind])

                def body_d(carry, xs):
                    xc = carry
                    lp, lc = xs
                    y, c2 = _apply_block_decode(cfg, kind, lp, xc, pos, lc,
                                                impl)
                    return y, c2

                body = jax.checkpoint(body_d) if cfg.remat else body_d
                if run == 1:
                    x, c1 = body(x, (jax.tree.map(lambda a: a[0], seg_params),
                                     jax.tree.map(lambda a: a[0], seg_cache)))
                    seg_cache_out = jax.tree.map(lambda a: a[None], c1)
                else:
                    x, seg_cache_out = jax.lax.scan(body, x,
                                                    (seg_params, seg_cache))
                new_caches[kind].append(seg_cache_out)
            elif prefix_caches is not None:
                # chunked prefill: thread this segment's seeded cache rows
                # through the scan so each layer attends over its own prefix
                cache_off = _cache_offset(new_caches[kind])
                seg_prefix = jax.tree.map(
                    lambda a: jax.lax.slice_in_dim(a, cache_off,
                                                   cache_off + run, axis=0),
                    prefix_caches[kind])

                def body_pc(carry, xs):
                    xc, aux = carry
                    lp, lc = xs
                    y, c2, a = _apply_block_prefill(
                        cfg, kind, lp, xc, positions, impl,
                        segment_ids, prefix=lc, prefix_len=prefix_len,
                        prefix_positions=prefix_positions,
                        prefix_segment_ids=prefix_segment_ids)
                    return (y, aux + a), c2

                body = jax.checkpoint(body_pc) if cfg.remat else body_pc
                if run == 1:
                    (x, aux_total), c1 = body(
                        (x, aux_total),
                        (jax.tree.map(lambda a: a[0], seg_params),
                         jax.tree.map(lambda a: a[0], seg_prefix)))
                    seg_cache_out = jax.tree.map(lambda a: a[None], c1)
                else:
                    (x, aux_total), seg_cache_out = jax.lax.scan(
                        body, (x, aux_total), (seg_params, seg_prefix))
                new_caches[kind].append(seg_cache_out)
            else:
                def body_p(carry, lp):
                    xc, aux = carry
                    y, c2, a = _apply_block_prefill(cfg, kind, lp, xc,
                                                    positions, impl,
                                                    segment_ids)
                    return (y, aux + a), c2

                body = jax.checkpoint(body_p) if cfg.remat else body_p
                if run == 1:
                    (x, aux_total), c1 = body(
                        (x, aux_total),
                        jax.tree.map(lambda a: a[0], seg_params))
                    seg_cache_out = jax.tree.map(lambda a: a[None], c1)
                else:
                    (x, aux_total), seg_cache_out = jax.lax.scan(
                        body, (x, aux_total), seg_params)
                new_caches[kind].append(seg_cache_out)
            n_done += run
            sub_start += run
            if every and n_done % every == 0 and shared_i < num_shared_invocations(cfg):
                if decode:
                    ck = caches["shared"]["k"][shared_i]
                    cv = caches["shared"]["v"][shared_i]
                    x, (ck, cv) = _shared_attn_decode(cfg, params, x, pos,
                                                      ck, cv, impl)
                    shared_caches.append((ck, cv))
                else:
                    sprefix = None
                    if prefix_caches is not None:
                        sprefix = {
                            "k": prefix_caches["shared"]["k"][shared_i],
                            "v": prefix_caches["shared"]["v"][shared_i]}
                    x, (k, v) = _shared_attn_prefill(
                        cfg, params, x, positions, impl, segment_ids,
                        prefix=sprefix, prefix_len=prefix_len,
                        prefix_positions=prefix_positions,
                        prefix_segment_ids=prefix_segment_ids)
                    shared_caches.append((k, v))
                shared_i += 1

    out_caches: Cache = {}
    for kind, lst in new_caches.items():
        if lst:
            out_caches[kind] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *lst) \
                if len(lst) > 1 else lst[0]
    if shared_caches:
        out_caches["shared"] = {
            "k": jnp.stack([c[0] for c in shared_caches]),
            "v": jnp.stack([c[1] for c in shared_caches]),
        }
    return x, out_caches, aux_total


def _cache_offset(collected: List) -> int:
    off = 0
    for c in collected:
        leaf = jax.tree.leaves(c)[0]
        off += leaf.shape[0]
    return off


def forward_train(cfg: ModelConfig, params: Params, tokens: jax.Array,
                  embeds: Optional[jax.Array] = None, impl: str = "xla"
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S_total, V), moe aux loss)."""
    x = embed_inputs(cfg, params, tokens, embeds)
    x = _constrain_acts(x)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, _, aux = _run_stack(cfg, params, x, positions, impl)
    return logits_fn(cfg, params, x), aux


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array,
            embeds: Optional[jax.Array] = None, impl: str = "xla",
            last_only: bool = False,
            positions: Optional[jax.Array] = None,
            segment_ids: Optional[jax.Array] = None,
            prefix_caches: Optional[Cache] = None,
            prefix_len=None, prefix_positions=None,
            prefix_segment_ids=None) -> Tuple[jax.Array, Cache]:
    """Returns (logits, caches seeded with the prompt). ``last_only``
    projects only the final position — serving prefill never needs the
    (B, S, vocab) tensor.

    Token-packed prefill: pass ``segment_ids`` (B, S) plus ``positions``
    that restart at 0 per segment — several prompts concatenated along the
    sequence axis then attend block-diagonally with no batch padding. Only
    valid for pure-attention stacks (recurrent blocks would fold foreign
    segments into their state).

    Chunked prefill: pass ``prefix_caches`` (the request's seeded decode-
    cache rows, layer-stacked like ``init_cache`` output) plus
    ``prefix_len`` (scalar: valid prefix slots) and absolute ``positions``
    starting at the chunk offset — each attention layer attends over its
    seeded prefix and the chunk itself, and the returned caches hold the
    *chunk's* K/V only.

    Packed multi-request chunked prefill: additionally pass
    ``segment_ids`` (B, T) for the packed chunk wave and, instead of the
    scalar ``prefix_len``, per-prefix-slot ``prefix_positions`` /
    ``prefix_segment_ids`` (B, C) — the prefix axis concatenates every
    request's cache-prefix view; each chunk attends block-diagonally over
    its own view plus itself. Attention-pure stacks only.

    Recurrent chunked prefill (pure SSM/xLSTM stacks): ``prefix_caches``
    carries the per-layer recurrent-state snapshots from the previous
    chunk (the shape the prefill itself returns) — the chunk continues
    the recurrence instead of recomputing its prefix, O(n) total across
    chunks. Returned caches are the updated snapshots.
    """
    kinds = set(cfg.pattern())
    if segment_ids is not None:
        assert kinds <= {ATTN}, \
            "token-packed prefill requires a pure-attention stack"
        assert embeds is None, "packed prefill does not take extra embeds"
    if prefix_caches is not None:
        if kinds <= {ATTN}:
            assert positions is not None
            assert (prefix_len is not None) or (
                prefix_positions is not None
                and prefix_segment_ids is not None)
            assert segment_ids is None or prefix_positions is not None, \
                "a packed chunk wave needs per-slot prefix positions"
        else:
            # recurrent state resume: positions are meaningless to the
            # recurrence and attention layers have no snapshot to resume
            assert not (kinds & {ATTN}) and not num_shared_invocations(cfg), \
                "chunk resume needs a pure-attention (kv prefix) or " \
                "pure-recurrent (state snapshot) stack"
            assert segment_ids is None
    x = embed_inputs(cfg, params, tokens, embeds)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, caches, _ = _run_stack(cfg, params, x, positions, impl,
                              segment_ids=segment_ids,
                              prefix_caches=prefix_caches,
                              prefix_len=prefix_len,
                              prefix_positions=prefix_positions,
                              prefix_segment_ids=prefix_segment_ids)
    if last_only:
        return logits_fn(cfg, params, x[:, -1]), caches
    return logits_fn(cfg, params, x), caches


def decode_step(cfg: ModelConfig, params: Params, tokens: jax.Array,
                pos: jax.Array, caches: Cache, impl: str = "xla"
                ) -> Tuple[jax.Array, Cache]:
    """tokens (B,1); pos (B,) absolute positions. Returns (logits (B,V), caches)."""
    x = jnp.take(params["embed/tok"], tokens, axis=0).astype(cfg.dtype)
    x, new_caches, _ = _run_stack(cfg, params, x, None, impl,
                                  decode=True, pos=pos, caches=caches)
    return logits_fn(cfg, params, x[:, 0]), new_caches


def seed_cache(cfg: ModelConfig, cache: Cache, prefill_caches: Cache,
               prompt_len: int) -> Cache:
    """Copy prefill outputs into a decode cache of larger capacity.

    Attention K/V from the prompt land at their absolute positions (ring-
    buffer slots for windowed attention); recurrent states are taken as-is.
    """
    out = dict(cache)

    def _place_kv(dst, src):
        # dst (L,B,C,K,hd), src (L,B,S,K,hd)
        C = dst.shape[2]
        S = src.shape[2]
        if S <= C:
            return jax.lax.dynamic_update_slice_in_dim(dst, src, 0, axis=2)
        # windowed: last C tokens, rotated so token p sits at slot p % C
        tail = src[:, :, S - C:]
        start = (S - C) % C
        rolled = jnp.roll(tail, shift=start, axis=2)
        return rolled

    for kind in (ATTN, "shared"):
        if kind in cache and kind in prefill_caches:
            out[kind] = {
                "k": _place_kv(cache[kind]["k"], prefill_caches[kind]["k"]),
                "v": _place_kv(cache[kind]["v"], prefill_caches[kind]["v"]),
            }
    for kind in (MAMBA, MLSTM, SLSTM):
        if kind in cache and kind in prefill_caches:
            out[kind] = prefill_caches[kind]
    return out


# --------------------------------------------------------------------------- #
# cache init
# --------------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=None) -> Cache:
    """Decode caches at a given context capacity (window-clamped for attn)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    kc = kind_counts(cfg)
    hd = cfg.resolved_head_dim
    caches: Cache = {}
    if ATTN in kc:
        C = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
        shape = (kc[ATTN], batch, C, cfg.num_kv_heads, hd)
        caches[ATTN] = {"k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype)}
    if MAMBA in kc:
        one = ssm.ssm_init_cache(cfg, batch, dtype)
        caches[MAMBA] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (kc[MAMBA],) + a.shape).copy(), one)
    if MLSTM in kc:
        one = xlstm.mlstm_init_cache(cfg, batch)
        caches[MLSTM] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (kc[MLSTM],) + a.shape).copy(), one)
    if SLSTM in kc:
        one = xlstm.slstm_init_cache(cfg, batch)
        caches[SLSTM] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (kc[SLSTM],) + a.shape).copy(), one)
    n_inv = num_shared_invocations(cfg)
    if n_inv:
        kv = cfg.shared_attn_kv_heads or cfg.num_kv_heads
        shape = (n_inv, batch, capacity, kv, hd)
        caches["shared"] = {"k": jnp.zeros(shape, dtype),
                            "v": jnp.zeros(shape, dtype)}
    return caches
