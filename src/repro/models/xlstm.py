"""xLSTM blocks: mLSTM (matrix memory, parallel training form) and sLSTM
(scalar memory with exponential gating, sequential scan).

Training/prefill uses the stabilized parallel form from the xLSTM paper
(arXiv:2405.04517); decode uses the recurrent update.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import EMBED, INNER, NUL, ParamMeta, ParamTree, rms_norm
from .config import ModelConfig


def _dims(cfg: ModelConfig):
    di = int(cfg.xlstm_proj_factor * cfg.d_model)
    nh = cfg.num_heads
    hd = di // nh
    return di, nh, hd


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #
def mlstm_params(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    di, nh, hd = _dims(cfg)
    return {
        "wq": ParamMeta((d, di), (EMBED, INNER)),
        "wk": ParamMeta((d, di), (EMBED, INNER)),
        "wv": ParamMeta((d, di), (EMBED, INNER)),
        "wi": ParamMeta((d, nh), (EMBED, NUL), init="small"),
        "wf": ParamMeta((d, nh), (EMBED, NUL), init="small"),
        "bf": ParamMeta((nh,), (NUL,), init="ones"),
        "wo": ParamMeta((d, di), (EMBED, INNER), init="small"),
        "norm": ParamMeta((di,), (INNER,), init="ones"),
        "down": ParamMeta((di, d), (INNER, EMBED)),
    }


def _qkvif(p, cfg, x):
    B, S, _ = x.shape
    di, nh, hd = _dims(cfg)
    q = jnp.einsum("bsd,di->bsi", x, p["wq"]).reshape(B, S, nh, hd)
    k = jnp.einsum("bsd,di->bsi", x, p["wk"]).reshape(B, S, nh, hd) / jnp.sqrt(hd)
    v = jnp.einsum("bsd,di->bsi", x, p["wv"]).reshape(B, S, nh, hd)
    i_raw = jnp.einsum("bsd,dh->bsh", x, p["wi"]).astype(jnp.float32)
    f_raw = (jnp.einsum("bsd,dh->bsh", x, p["wf"]) + p["bf"]).astype(jnp.float32)
    return q, k, v, i_raw, f_raw


def mlstm_prefill(p, cfg: ModelConfig, x: jax.Array, init=None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunkwise-parallel stabilized mLSTM (xLSTM paper App. form): a
    lax.scan over chunks carries (C, n, m); within a chunk the quadratic
    decay matrix is only (Q, Q). O(S·Q) memory, not O(S^2); the chunk body
    is rematerialized in the backward pass.

    ``init`` (a previous call's cache) resumes the recurrence mid-sequence
    for chunked prefill; ``None`` is the zero (empty-memory) state."""
    B, S0, _ = x.shape
    di, nh, hd = _dims(cfg)
    q, k, v, i_raw, f_raw = _qkvif(p, cfg, x)
    Q = min(cfg.ssm_chunk, S0)
    S = -(-S0 // Q) * Q
    if S != S0:
        pad = ((0, 0), (0, S - S0), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
        # padded steps: f-gate -> 1 (log_f 0), i-gate -> -inf (no input)
        i_raw = jnp.pad(i_raw, ((0, 0), (0, S - S0), (0, 0)),
                        constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, S - S0), (0, 0)),
                        constant_values=40.0)
    nc = S // Q
    cs = lambda t: jnp.moveaxis(t.reshape(B, nc, Q, *t.shape[2:]), 1, 0)
    qs, ks, vs = cs(q.astype(jnp.float32)), cs(k.astype(jnp.float32)), \
        cs(v.astype(jnp.float32))
    is_, fs = cs(i_raw), cs(jax.nn.log_sigmoid(f_raw))

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk(carry, inp):
        C_prev, n_prev, m_prev = carry                # (B,nh,hd,hd) ...
        qc, kc, vc, ic, fc = inp                      # (B,Q,nh,hd) / (B,Q,nh)
        bcum = jnp.cumsum(fc, axis=1)                 # (B,Q,nh)
        total = bcum[:, -1]                           # (B,nh)
        # intra-chunk decay matrix  logD[i,j] = bcum_i - bcum_j + i_j
        seg = bcum[:, :, None, :] - bcum[:, None, :, :] + ic[:, None, :, :]
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        m_intra = jnp.maximum(jnp.max(seg, axis=2), -1e30)    # (B,Q,nh)
        m_inter = bcum + m_prev[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)                   # (B,Q,nh)
        D = jnp.exp(seg - m_t[:, :, None, :])                 # (B,Q,Q,nh)
        qk = jnp.einsum("bshd,bthd->bsth", qc, kc)            # (B,Q,Q,nh)
        w = qk * D
        h_intra = jnp.einsum("bsth,bthd->bshd", w, vc)
        scale_in = jnp.exp(m_inter - m_t)                     # (B,Q,nh)
        h_inter = jnp.einsum("bshd,bhed->bshe", qc, C_prev) \
            * scale_in[..., None]
        num = h_intra + h_inter                               # (B,Q,nh,hd)
        # denominator n_t·q_t: intra = sum_j w[s,j]; inter = (q·n_prev)·decay
        dq = w.sum(axis=2) \
            + jnp.einsum("bshd,bhd->bsh", qc, n_prev) * scale_in
        denom = jnp.maximum(jnp.abs(dq), jnp.exp(-m_t))
        y = num / jnp.maximum(denom, 1e-6)[..., None]
        # ---- state update to chunk end -----------------------------------
        wk = jnp.exp(total[:, None, :] - bcum + ic)           # unstabilized
        m_candidates = total[:, None, :] - bcum + ic          # (B,Q,nh)
        m_next = jnp.maximum(total + m_prev,
                             jnp.max(m_candidates, axis=1))   # (B,nh)
        wk = jnp.exp(m_candidates - m_next[:, None, :])
        C_new = jnp.exp(total + m_prev - m_next)[:, :, None, None] * C_prev \
            + jnp.einsum("bth,bthd,bthe->bhde", wk, vc, kc)
        n_new = jnp.exp(total + m_prev - m_next)[:, :, None] * n_prev \
            + jnp.einsum("bth,bthd->bhd", wk, kc)
        return (C_new, n_new, m_next), y

    if init is not None:
        C0, n0, m0 = init["C"], init["n"], init["m"]
    else:
        C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
    (C, nvec, m_end), ys = jax.lax.scan(jax.checkpoint(chunk), (C0, n0, m0),
                                        (qs, ks, vs, is_, fs))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)[:, :S0].astype(x.dtype)
    cache = {"C": C, "n": nvec, "m": m_end}
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    y = y * jax.nn.sigmoid(jnp.einsum("bsd,di->bsi", x, p["wo"]))
    return jnp.einsum("bsi,id->bsd", y, p["down"]), cache


def mlstm_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    di, nh, hd = _dims(cfg)
    q, k, v, i_raw, f_raw = _qkvif(p, cfg, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    i_raw, log_f = i_raw[:, 0], jax.nn.log_sigmoid(f_raw[:, 0])  # (B,nh)
    m_old, C_old, n_old = cache["m"], cache["C"], cache["n"]
    m_new = jnp.maximum(log_f + m_old, i_raw)
    a = jnp.exp(log_f + m_old - m_new)                        # (B,nh)
    b = jnp.exp(i_raw - m_new)
    C = a[:, :, None, None] * C_old \
        + b[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", v.astype(jnp.float32),
                                           k.astype(jnp.float32))
    n = a[:, :, None] * n_old + b[:, :, None] * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C, q.astype(jnp.float32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.astype(jnp.float32))),
                      jnp.exp(-m_new))[..., None]
    y = (num / jnp.maximum(den, 1e-6)).reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    y = y * jax.nn.sigmoid(jnp.einsum("bsd,di->bsi", x, p["wo"]))
    return jnp.einsum("bsi,id->bsd", y, p["down"]), {"C": C, "n": n, "m": m_new}


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, nh, hd = _dims(cfg)
    return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #
def slstm_params(cfg: ModelConfig) -> ParamTree:
    d = cfg.d_model
    di, nh, hd = _dims(cfg)
    return {
        "w_in": ParamMeta((d, 4 * di), (EMBED, INNER)),
        "r": ParamMeta((nh, hd, 4 * hd), (NUL, NUL, INNER), init="small"),
        "b": ParamMeta((4 * di,), (INNER,), init="zeros"),
        "norm": ParamMeta((di,), (INNER,), init="ones"),
        "down": ParamMeta((di, d), (INNER, EMBED)),
    }


def _slstm_step(p, cfg, xt, state):
    """xt (B, 4*di) pre-projected input; state dict of (B, di) fp32."""
    di, nh, hd = _dims(cfg)
    B = xt.shape[0]
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhd,hdk->bhk", h.reshape(B, nh, hd).astype(xt.dtype),
                     p["r"]).reshape(B, 4 * di)
    zifo = (xt + rec).astype(jnp.float32) + p["b"].astype(jnp.float32)
    z, i_raw, f_raw, o = jnp.split(zifo, 4, axis=-1)
    z = jnp.tanh(z)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m, i_raw)
    a = jnp.exp(log_f + m - m_new)
    b = jnp.exp(i_raw - m_new)
    c_new = a * c + b * z
    n_new = a * n + b
    h_new = jnp.tanh(c_new / jnp.maximum(n_new, 1e-6)) * jax.nn.sigmoid(o)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_init_cache(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, _, _ = _dims(cfg)
    z = jnp.zeros((batch, di), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, di), -1e30, jnp.float32)}


def slstm_prefill(p, cfg: ModelConfig, x: jax.Array, init=None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """``init`` (a previous call's cache) resumes the recurrence mid-
    sequence for chunked prefill; ``None`` is the zero state."""
    B, S, _ = x.shape
    di, nh, hd = _dims(cfg)
    xproj = jnp.einsum("bsd,dk->bsk", x, p["w_in"])            # (B,S,4di)

    def step(state, xt):
        new = _slstm_step(p, cfg, xt, state)
        return new, new["h"]

    state, hs = jax.lax.scan(step,
                             init if init is not None
                             else slstm_init_cache(cfg, B),
                             jnp.moveaxis(xproj, 0, 1))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                 # (B,S,di)
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    return jnp.einsum("bsi,id->bsd", y, p["down"]), state


def slstm_decode(p, cfg: ModelConfig, x: jax.Array, cache
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    xproj = jnp.einsum("bsd,dk->bsk", x, p["w_in"])[:, 0]
    state = _slstm_step(p, cfg, xproj, cache)
    y = state["h"][:, None].astype(x.dtype)
    y = rms_norm(y, p["norm"], cfg.rms_eps)
    return jnp.einsum("bsi,id->bsd", y, p["down"]), state
