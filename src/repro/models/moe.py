"""Top-k MoE with capacity-based scatter dispatch (expert-parallel friendly).

Dispatch is sort-free: position-in-expert comes from a one-hot cumsum and
tokens are scattered into an (E, C, d) buffer ("drop" semantics beyond
capacity). Under GSPMD the buffer is sharded E->model / C->data, so the
scatter/gather lower to all-to-all style collectives on TPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import (BATCH_AXES, EMBED, EXPERT, MLP, NUL, ParamMeta,
                     ParamTree, maybe_constrain)
from .config import ModelConfig


def moe_params(cfg: ModelConfig) -> ParamTree:
    d, f, e = cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.num_experts
    return {
        "router": ParamMeta((d, e), (EMBED, NUL), init="small"),
        "w_gate": ParamMeta((e, d, f), (EXPERT, EMBED, MLP)),
        "w_up": ParamMeta((e, d, f), (EXPERT, EMBED, MLP)),
        "w_down": ParamMeta((e, f, d), (EXPERT, MLP, EMBED)),
    }


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * num_tokens
            / max(1, cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch(cfg: ModelConfig, xv, e_flat, pos_s, E: int, Cl: int, dtype):
    """Scatter (D,Tl*k,d) token copies into the (D,E,Cl,d) expert buffer.

    Under an active mesh this runs inside shard_map so the scatter is a
    plain *local* scatter per device — GSPMD cannot partition a global
    scatter with computed indices and replicates (T·k, d) per device
    otherwise (§Perf iteration 3). Each model column holds E/model_n
    experts; tokens routed to other columns drop locally and the expert
    buffer emerges sharded (data, model) with zero collective traffic.
    """
    from .common import BATCH_AXES as BA, _ACTIVE_MESH_SIZES, active_mesh
    mesh = active_mesh()
    D = xv.shape[0]
    model_n = _ACTIVE_MESH_SIZES.get("model", 1)
    if mesh is None or model_n <= 1 or E % model_n or D == 1:
        rix = jnp.broadcast_to(jnp.arange(D)[:, None], e_flat.shape)
        buf = jnp.zeros((D, E, Cl, xv.shape[-1]), dtype)
        return buf.at[rix, e_flat, pos_s].set(xv, mode="drop")

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    ba = tuple(a for a in BA if a in mesh.axis_names)
    E_loc = E // model_n

    def local(xv_l, e_l, pos_l):
        j = jax.lax.axis_index("model")
        e_local = e_l[0] - j * E_loc          # OOB -> dropped by scatter
        buf_l = jnp.zeros((E_loc, Cl, xv_l.shape[-1]), dtype)
        buf_l = buf_l.at[e_local, pos_l[0]].set(xv_l[0], mode="drop")
        return buf_l[None]

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None), P(ba, None)),
        out_specs=P(ba, "model", None, None))(xv, e_flat, pos_s)


def _combine(cfg: ModelConfig, out_buf, e_flat, pos_s):
    """Gather each token's expert output back: inverse of _dispatch."""
    from .common import BATCH_AXES as BA, _ACTIVE_MESH_SIZES, active_mesh
    mesh = active_mesh()
    D, E, Cl, d = out_buf.shape
    model_n = _ACTIVE_MESH_SIZES.get("model", 1)
    if mesh is None or model_n <= 1 or E % model_n or D == 1:
        rix = jnp.broadcast_to(jnp.arange(D)[:, None], e_flat.shape)
        return out_buf.at[rix, e_flat, pos_s].get(mode="fill",
                                                  fill_value=0)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    ba = tuple(a for a in BA if a in mesh.axis_names)
    E_loc = E // model_n

    def local(buf_l, e_l, pos_l):
        j = jax.lax.axis_index("model")
        e_local = e_l[0] - j * E_loc
        yv_l = buf_l[0].at[e_local, pos_l[0]].get(mode="fill",
                                                  fill_value=0)
        # other columns contribute their experts' tokens
        return jax.lax.psum(yv_l, "model")[None]

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(ba, "model", None, None), P(ba, None), P(ba, None)),
        out_specs=P(ba, None, None))(out_buf, e_flat, pos_s)


def _expert_ffn(cfg: ModelConfig, p, buf, D: int):
    """SwiGLU over the expert buffer (D,E,Cl,d) -> (D,E,Cl,d).

    Decode-sized buffers (D == 1, tokens replicated over data) go through
    an explicit shard_map schedule: partial contraction over the d-sharded
    expert weights + MB-sized psums — GSPMD's default here is to all-gather
    the weights (GBs per layer for the 480B MoE, §Perf iteration 5)."""
    from .common import _ACTIVE_MESH_SIZES, active_mesh
    mesh = active_mesh()
    E = buf.shape[1]
    model_n = _ACTIVE_MESH_SIZES.get("model", 1)
    data_n = _ACTIVE_MESH_SIZES.get("data", 1)
    d = buf.shape[-1]
    f = p["w_gate"].shape[-1]
    small = D == 1 and mesh is not None and model_n > 1 and data_n > 1 \
        and E % model_n == 0 and d % data_n == 0 and f % data_n == 0 \
        and "pod" not in mesh.axis_names
    if not small:
        g = jnp.einsum("recd,edf->recf", buf, p["w_gate"])
        u = jnp.einsum("recd,edf->recf", buf, p["w_up"])
        return jnp.einsum("recf,efd->recd", jax.nn.silu(g) * u,
                          p["w_down"])

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def local(buf_l, wg_l, wu_l, wd_l):
        i = jax.lax.axis_index("data")
        dl = wg_l.shape[1]
        bslice = jax.lax.dynamic_slice_in_dim(buf_l[0], i * dl, dl, axis=2)
        g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", bslice, wg_l), "data")
        u = jax.lax.psum(jnp.einsum("ecd,edf->ecf", bslice, wu_l), "data")
        a = jax.nn.silu(g) * u                        # (E_loc, Cl, f) full f
        # w_down is (E, f, d) with d sharded over "data" -> local d slice
        y_l = jnp.einsum("ecf,efd->ecd", a, wd_l)     # (E_loc, Cl, d/data)
        y = jax.lax.all_gather(y_l, "data", axis=2, tiled=True)
        return y[None]

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model", None, None), P("model", "data", None),
                  P("model", "data", None), P("model", None, "data")),
        out_specs=P(None, "model", None, None), check_rep=False)(
            buf, p["w_gate"], p["w_up"], p["w_down"])


def moe_apply(p, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux load-balance loss scalar).

    Dispatch is *row-blocked*: tokens are reshaped to (D, T/D) where D is
    the data-shard count, and every expert's capacity is pre-partitioned
    per source row (GShard-style per-shard capacity). Positions then come
    from a within-row cumsum and the scatter/gather carry an explicit
    leading batch dim that matches the "data" sharding — no token ever
    crosses a data shard, so GSPMD never replicates the dispatch tensors
    (the naive global scatter replicated (T·k, d) per device — §Perf
    iteration 3). D = 1 on a single host, which reproduces the classic
    global-capacity dispatch exactly.
    """
    B, S, d = x.shape
    T = B * S
    k = cfg.experts_per_token
    E = cfg.num_experts
    from .common import data_shards
    D = data_shards()
    # Decode-sized batches (few tokens): keep tokens replicated across the
    # data axis so the expert contraction psums MB-sized partials instead
    # of all-gathering the d-sharded expert weights (GBs per layer for the
    # 480B MoE — §Perf iteration 5).
    if T % D != 0 or T < 16 * D:
        D = 1
    Tl = T // D
    Cl = capacity(cfg, Tl)
    xf = x.reshape(D, Tl, d)
    xf = maybe_constrain(xf, BATCH_AXES, None, None)

    logits = jnp.einsum("rtd,de->rte", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (D,Tl,E)
    gate, idx = jax.lax.top_k(probs, k)                        # (D,Tl,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) assignment within its (row, expert)
    e_flat = idx.reshape(D, Tl * k)                            # (D,Tl*k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)        # (D,Tl*k,E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos, e_flat[..., None], axis=2)[..., 0]
    keep = pos < Cl
    pos_s = jnp.where(keep, pos, Cl)                           # OOB -> drop

    t_flat = jnp.arange(Tl * k) // k
    xv = jnp.take(xf, t_flat, axis=1)                          # (D,Tl*k,d)
    buf = _dispatch(cfg, xv, e_flat, pos_s, E, Cl, x.dtype)    # (D,E,Cl,d)
    buf = maybe_constrain(buf, BATCH_AXES, "model", None, None)

    out_buf = _expert_ffn(cfg, p, buf, D)
    out_buf = maybe_constrain(out_buf, BATCH_AXES, "model", None, None)

    yv = _combine(cfg, out_buf, e_flat, pos_s)                 # (D,Tl*k,d)
    w = (gate.reshape(D, Tl * k) * keep).astype(x.dtype)
    y = (yv * w[..., None]).reshape(D, Tl, k, d).sum(axis=2)
    y = y.reshape(B, S, d)

    # Switch-style load-balance aux loss
    frac_tokens = jnp.mean(onehot.astype(jnp.float32), axis=(0, 1)) * k
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
