"""Exporters for registry snapshots: Prometheus text format, JSON
time-series, and Chrome ``trace_event`` request-lifecycle spans.

All exporters consume the immutable :class:`~repro.obs.registry.Snapshot`
(or the :class:`TimeSeriesLog` accumulated from snapshots) — nothing here
reads live subsystem state, so an export can never disagree with the
diagnostics built from the same snapshot.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from .registry import HistogramValue, Snapshot, _render_labels

__all__ = ["to_prometheus_text", "parse_prometheus_text",
           "write_prometheus", "TimeSeriesLog", "write_json_snapshot",
           "request_trace_events", "write_chrome_trace"]


# --------------------------------------------------------------------- #
# Prometheus text exposition format
# --------------------------------------------------------------------- #
def to_prometheus_text(snap: Snapshot) -> str:
    """Render a snapshot in the Prometheus text exposition format
    (``# HELP`` / ``# TYPE`` headers, histogram ``_bucket``/``_sum``/
    ``_count`` expansion, cumulative ``le`` buckets ending at +Inf)."""
    lines: List[str] = []
    for fam in snap.families:
        if not fam.samples:
            continue
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for lbls, value in fam.samples:
            base = _render_labels(lbls)
            if isinstance(value, HistogramValue):
                for le, c in value.buckets:
                    lines.append(
                        f"{fam.name}_bucket{_render_labels(lbls, le=le)}"
                        f" {c}")
                lines.append(f"{fam.name}_sum{base} {_fmt(value.sum)}")
                lines.append(f"{fam.name}_count{base} {value.count}")
            else:
                lines.append(f"{fam.name}{base} {_fmt(value)}")
    return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Minimal exposition-format parser (sample name+labels -> value).
    Used by CI smokes to assert an export round-trips; raises ValueError
    on any malformed sample line."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # name{labels} value  |  name value
        head, _, tail = line.rpartition(" ")
        if not head:
            raise ValueError(f"malformed sample line: {line!r}")
        try:
            out[head] = float(tail)
        except ValueError:
            raise ValueError(f"malformed sample value: {line!r}")
        name = head.split("{", 1)[0]
        if not (name and name[0].isalpha() and all(
                c.isalnum() or c == "_" for c in name)):
            raise ValueError(f"malformed sample name: {line!r}")
    if not out:
        raise ValueError("no samples in exposition text")
    return out


def write_prometheus(snap: Snapshot, path: str) -> None:
    with open(path, "w") as f:
        f.write(to_prometheus_text(snap))


# --------------------------------------------------------------------- #
# JSON time series
# --------------------------------------------------------------------- #
class TimeSeriesLog:
    """Append-only (t, value) series keyed by flat sample name.

    ``record`` takes explicit name->value pairs (the replayer's derived
    rates); ``record_snapshot`` pulls every scalar sample out of a
    registry snapshot. Export is one JSON document:
    ``{"series": {name: {"t": [...], "v": [...]}}}``.
    """

    def __init__(self):
        self.series: Dict[str, Tuple[List[float], List[float]]] = {}

    def _append(self, name: str, t: float, v: float) -> None:
        ts, vs = self.series.setdefault(name, ([], []))
        ts.append(float(t))
        vs.append(float(v))

    def record(self, t: float, values: Dict[str, float]) -> None:
        for name, v in values.items():
            self._append(name, t, v)

    def record_snapshot(self, t: float, snap: Snapshot,
                        names: Optional[Iterable[str]] = None) -> None:
        want = None if names is None else set(names)
        for name, v in snap.flat().items():
            base = name.split("{", 1)[0]
            if want is not None and base not in want:
                continue
            self._append(name, t, v)

    def to_json(self) -> dict:
        return {"series": {name: {"t": ts, "v": vs}
                           for name, (ts, vs) in self.series.items()}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


def write_json_snapshot(snap: Snapshot, path: str,
                        extra: Optional[dict] = None) -> None:
    """One flat ``{sample-name: value}`` JSON snapshot (plus optional
    run-level metadata under ``"meta"``)."""
    doc = {"metrics": snap.flat()}
    if extra:
        doc["meta"] = extra
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)


# --------------------------------------------------------------------- #
# Chrome trace_event request-lifecycle spans
# --------------------------------------------------------------------- #
# phase spans are reconstructed from the Request JCT decomposition the
# scheduler already maintains (§2.2 timestamps), so the trace agrees with
# the metrics by construction: queued (arrival -> first execution),
# prefill (first execution -> first token), decode (first token ->
# terminal), with swap/migrate time and preemptions attached as args.
def request_trace_events(requests: Sequence, pid: int = 0,
                         clock_us: float = 1e6) -> List[dict]:
    """Chrome ``trace_event`` list for a set of ``repro.core.request``
    Requests. ``clock_us`` converts iteration-clock units to trace
    microseconds. One trace row (tid) per request."""
    events: List[dict] = []

    def span(name: str, rid: int, t0: float, t1: float, **args) -> None:
        if t1 < t0:
            return
        events.append({"name": name, "cat": "request", "ph": "X",
                       "pid": pid, "tid": rid,
                       "ts": t0 * clock_us,
                       "dur": max(0.0, (t1 - t0)) * clock_us,
                       "args": args})

    for r in requests:
        t_exec = r.t_start_exec
        t_first = r.t_first_token
        t_end = r.t_complete
        terminal = "completed" if t_end is not None else r.state.value
        if t_end is None:
            # aborted/shed: close open spans at the last charged event
            t_end = r._last_event_t
        span("queued", r.rid, r.arrival,
             t_exec if t_exec is not None else t_end,
             prompt_len=r.prompt_len)
        if t_exec is not None:
            span("prefill", r.rid, t_exec,
                 t_first if t_first is not None else t_end,
                 prompt_len=r.prompt_len)
        if t_first is not None:
            span("decode", r.rid, t_first, t_end,
                 generated=r.generated, terminal=terminal)
        if r.swap_time > 0 or r.n_preemptions > 0:
            # swap/migrate holds have no absolute timestamps in the JCT
            # decomposition — attach the totals as an instant marker
            events.append({"name": "swap_migrate", "cat": "request",
                           "ph": "i", "s": "t", "pid": pid, "tid": r.rid,
                           "ts": t_end * clock_us,
                           "args": {"swap_time": r.swap_time,
                                    "preempt_time": r.preempt_time,
                                    "n_preemptions": r.n_preemptions}})
        if terminal != "completed":
            events.append({"name": terminal, "cat": "request", "ph": "i",
                           "s": "t", "pid": pid, "tid": r.rid,
                           "ts": t_end * clock_us, "args": {}})
    return events


def write_chrome_trace(events: List[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f)
