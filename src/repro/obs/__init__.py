"""Production metrics plane: registry, zero-sync sampler, exporters.

    from repro.obs import MetricsRegistry, MetricsSampler
    reg = MetricsRegistry()
    MetricsSampler(reg, instance="0").attach(engine)
    ...
    text = to_prometheus_text(reg.snapshot())

See ``ROADMAP.md`` (observability section) for the metric-naming
convention and the zero-overhead contract the ``hotpath_micro --check``
``bench_metrics`` gate enforces.
"""
from .exporters import (TimeSeriesLog, parse_prometheus_text,
                        request_trace_events, to_prometheus_text,
                        write_chrome_trace, write_json_snapshot,
                        write_prometheus)
from .registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                       HistogramValue, MetricsRegistry, Snapshot)
from .sampler import SYNC_KINDS, MetricsSampler, publish_engine

__all__ = [
    "MetricsRegistry", "MetricsSampler", "Snapshot", "Counter", "Gauge",
    "Histogram", "HistogramValue", "DEFAULT_BUCKETS", "SYNC_KINDS",
    "publish_engine", "to_prometheus_text", "parse_prometheus_text",
    "write_prometheus", "write_json_snapshot", "TimeSeriesLog",
    "request_trace_events", "write_chrome_trace",
]
