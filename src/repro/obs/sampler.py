"""Per-iteration metrics sampling with provably bounded overhead.

``MetricsSampler`` binds one registry to one ``ServingEngine`` and is
invoked by the engine at the end of every ``step`` — the *existing* step
boundary, never a new one. Two rules keep the hot path intact:

  * **host-side values only.** Everything sampled is a Python int/float
    the engine already maintains (queue lengths, KVC block accounting,
    the ``n_*`` running counters). Device-resident values reach the
    registry exclusively through the lag-N readback ring the engine
    already drains — the ``engine_tokens_drained_total`` counter advances
    when the ring materializes tokens, never via a fresh ``device_get``;
  * **no control-flow influence.** The sampler reads, never writes,
    engine state, draws no RNG and dispatches nothing — so a metrics-on
    run is bitwise-identical to metrics-off with zero added blocking
    syncs (``hotpath_micro --check``'s ``bench_metrics`` gate).

Child handles are resolved once at ``attach`` and published by attribute
thereafter; ``sample_time`` accumulates the sampler's own wall-clock so
the overhead bound (< 5% of the decode loop) is measured, not assumed.
"""
from __future__ import annotations

import time
from typing import Optional

from .registry import MetricsRegistry

__all__ = ["MetricsSampler", "publish_engine", "SYNC_KINDS"]

SYNC_KINDS = ("eos_flags", "drain_blocking", "drain_backpressure",
              "drain_ready", "flush")


class MetricsSampler:
    """Zero-sync per-iteration sampler for one engine."""

    def __init__(self, registry: MetricsRegistry, instance: str = "0"):
        self.registry = registry
        self.instance = str(instance)
        self.sample_time = 0.0        # cumulative seconds spent sampling
        self.n_samples = 0
        ln = ("instance",)
        lv = {"instance": self.instance}
        r = registry
        # cached children: one dict lookup per family at attach, zero at
        # sample time
        self._g_pt = r.gauge(
            "scheduler_queue_depth", "requests waiting per queue",
            ("instance", "queue")).labels(queue="pt", **lv)
        self._g_gt = r.gauge(
            "scheduler_queue_depth", "requests waiting per queue",
            ("instance", "queue")).labels(queue="gt", **lv)
        self._g_running = r.gauge(
            "scheduler_running_requests", "decode-phase requests in the "
            "current groups", ln).labels(**lv)
        self._g_occ = r.gauge(
            "engine_kvc_occupied_blocks", "KVC blocks held by live "
            "allocations", ln).labels(**lv)
        self._g_free = r.gauge(
            "engine_kvc_free_blocks", "KVC blocks free", ln).labels(**lv)
        self._g_frac = r.gauge(
            "engine_kvc_allocated_frac", "allocated / total blocks",
            ln).labels(**lv)
        self._g_used = r.gauge(
            "engine_kvc_used_tokens", "tokens actually written into the "
            "cache", ln).labels(**lv)
        self._g_slots = r.gauge(
            "engine_free_slots", "free batch slots", ln).labels(**lv)
        self._g_ring = r.gauge(
            "engine_drain_ring_depth", "undrained readback-ring entries",
            ln).labels(**lv)
        self._g_mega = r.gauge(
            "engine_megastep_rows_left", "precomputed megastep rows not "
            "yet replayed", ln).labels(**lv)
        self._g_amort = r.gauge(
            "megastep_dispatch_amortization", "decode iterations per "
            "device dispatch", ln).labels(**lv)
        self._c_iters = r.counter(
            "engine_decode_iters_total", "decode iterations",
            ln).labels(**lv)
        self._c_disp = r.counter(
            "engine_decode_dispatches_total", "device decode dispatches",
            ln).labels(**lv)
        self._c_drained = r.counter(
            "engine_tokens_drained_total", "sampled tokens materialized "
            "through the readback ring", ln).labels(**lv)
        self._c_sync = {k: r.counter(
            "engine_host_syncs_total", "host sync events by kind",
            ("instance", "kind")).labels(kind=k, **lv)
            for k in SYNC_KINDS}
        self._c_blocking = r.counter(
            "engine_blocking_syncs_total", "pipeline-serializing host "
            "syncs (eos_flags + drain_blocking)", ln).labels(**lv)
        self._c_samples = r.counter(
            "sampler_samples_total", "sampler invocations",
            ln).labels(**lv)

    # ------------------------------------------------------------------ #
    def attach(self, engine) -> "MetricsSampler":
        """Register with the engine; ``engine.step`` calls ``on_step``
        from then on."""
        engine.metrics = self
        self.on_step(engine, 0.0)
        return self

    def on_step(self, engine, now: float) -> None:
        t0 = time.perf_counter()
        sched = engine.scheduler
        kvc = sched.kvc
        self._g_pt.set(len(sched.pt_queue))
        self._g_gt.set(len(sched.gt_queue))
        self._g_running.set(sum(len(g.members)
                                for g in sched.running_groups))
        self._g_occ.set(kvc.allocated_blocks)
        self._g_free.set(kvc.free_blocks)
        self._g_frac.set(kvc.allocated_frac)
        self._g_used.set(kvc.used_tokens)
        self._g_slots.set(len(engine.free_slots))
        self._g_ring.set(len(engine._pending_drain))
        self._g_mega.set(engine._mega_left)
        self._c_iters.inc_to(engine.decode_iters)
        self._c_disp.inc_to(engine.n_decode_dispatches)
        self._g_amort.set(engine.decode_iters
                          / max(1, engine.n_decode_dispatches))
        self._c_drained.inc_to(engine.n_tokens_drained)
        sc = engine.sync_counts
        for k, child in self._c_sync.items():
            child.inc_to(sc[k])
        self._c_blocking.inc_to(engine.n_blocking_syncs)
        self._c_samples.inc(1)
        self.n_samples += 1
        self.sample_time += time.perf_counter() - t0


def publish_engine(engine, reg: MetricsRegistry,
                   instance: str = "0") -> None:
    """Full one-shot publication of an engine's counters and gauges —
    the per-iteration sample plus every slow-moving counter. This is the
    single code path behind ``ServingEngine.debug_state`` and the
    ``--metrics`` exit dumps, so stall diagnostics and live metrics can
    never disagree."""
    MetricsSampler(reg, instance).on_step(engine, 0.0)
    lv = {"instance": str(instance)}
    ln = ("instance",)

    def c(name, help, value, **extra):
        fam = reg.counter(name, help, ln + tuple(sorted(extra)))
        fam.labels(**lv, **extra).inc_to(value)

    def g(name, help, value):
        reg.gauge(name, help, ln).labels(**lv).set(value)

    c("engine_prefill_waves_total", "whole-prompt prefill dispatch waves",
      engine.n_prefill_waves)
    c("engine_prefill_chunks_total", "chunked-prefill chunks executed",
      engine.n_prefill_chunks)
    c("engine_prefill_chunk_calls_total", "chunk-prefill dispatches",
      engine.n_chunk_calls)
    c("engine_prefill_compiles_total", "distinct prefill trace shapes",
      engine.n_prefill_compiles)
    c("engine_kv_migrations_total", "KV page images by direction",
      engine.n_kv_exports, direction="export")
    c("engine_kv_migrations_total", "KV page images by direction",
      engine.n_kv_injects, direction="inject")
    c("engine_kv_rejects_total", "corrupt KV images refused at inject",
      engine.n_kv_rejects)
    c("engine_aborted_total", "requests terminally aborted",
      engine.n_aborted)
    c("engine_shed_total", "rung-4 terminal sheds", engine.n_shed)
    c("engine_dup_deliveries_total", "duplicate deliveries suppressed",
      engine.n_dup_deliveries)
    c("engine_dup_completions_total", "duplicate terminal writes "
      "suppressed", engine.n_dup_completions)
    c("engine_swap_events_total", "host-swap ledger events",
      engine.n_swap_captures, kind="capture")
    c("engine_swap_events_total", "host-swap ledger events",
      engine.n_swap_restores, kind="restore")
    c("engine_swap_events_total", "host-swap ledger events",
      engine.n_swap_rejects, kind="reject")
    c("engine_swap_events_total", "host-swap ledger events",
      engine.n_swap_drops, kind="drop")
    g("engine_host_swap_images", "KV images parked in the host-swap "
      "ledger", len(engine._host_swap))
    g("engine_buffered_arrivals", "requests submitted but not yet due",
      len(engine._arrivals))
    g("engine_pending_injects", "KV injects awaiting a window boundary",
      len(engine._pending_injects))
    g("engine_pending_aborts", "aborts awaiting a window boundary",
      len(engine._pending_aborts))
    engine.scheduler.publish_metrics(reg, **lv)
