"""Typed metrics registry: Counter / Gauge / Histogram families with
Prometheus-style names and label sets.

One registry is the single publication surface every subsystem writes
into (``ServingEngine``, the EconoServe scheduler, ``BlockKVC``, routers,
``FailureDetector``, ``GoodputAutoscaler``) — replacing the ad-hoc dict
scraping each benchmark used to hand-roll. Naming follows
``<subsystem>_<noun>_<unit>`` (see ROADMAP.md appendix); counters end in
``_total``.

Design constraints (all hot-path callers are engine iteration loops):

  * pure host-side Python — publishing never touches a device value, so
    a metrics-on run is bitwise-identical to metrics-off with zero added
    blocking syncs (hard-gated by ``hotpath_micro --check``);
  * label-set identity — ``family.labels(a="1", b="2")`` returns the
    *same* child object for the same label values regardless of keyword
    order, so publishers can cache children and publish by attribute;
  * counters are monotone — ``inc`` rejects negative amounts and
    ``inc_to`` rejects regressions, so concurrent publishers can only
    ever move a counter forward;
  * snapshots are immutable — ``registry.snapshot()`` deep-freezes every
    family into tuples/mapping-proxies, so a stall post-mortem captured
    at raise time cannot be mutated by later iterations.

Histogram bucket semantics match Prometheus ``le`` (less-or-equal):
a value exactly on a bucket edge lands in that (low-side) bucket, and
the implicit ``+Inf`` bucket conserves the total observation count.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from types import MappingProxyType
from typing import Dict, Iterable, Mapping, Optional, Tuple, Union

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Histogram",
           "Snapshot", "FamilySnapshot", "HistogramValue",
           "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0)


def _validate_name(name: str) -> str:
    assert name and name[0].isalpha() and all(
        c.isalnum() or c == "_" for c in name), \
        f"metric name {name!r} must match [a-zA-Z][a-zA-Z0-9_]*"
    return name


class Counter:
    """Monotonically non-decreasing value. ``inc`` takes a per-counter
    lock: a bare ``+=`` is a read-modify-write, and a lost update under
    concurrent publishers can store a *smaller* value than a reader
    already saw — breaking monotonicity, the one property counters
    promise. The lock is uncontended on the single-threaded engine hot
    path, so it costs one atomic acquire."""
    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: Union[int, float] = 1) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0: {amount}")
        with self._lock:
            self.value += amount

    def inc_to(self, total: Union[int, float]) -> None:
        """Advance to an externally-maintained running total (the engine's
        own ``n_*`` ints). A regression means two publishers disagree —
        refuse it rather than silently un-counting."""
        with self._lock:
            if total < self.value:
                raise ValueError(
                    f"counter cannot regress: {self.value} -> {total}")
            self.value = float(total)


class Gauge:
    """Point-in-time value; free to move both ways."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, value: Union[int, float]) -> None:
        self.value = float(value)

    def inc(self, amount: Union[int, float] = 1) -> None:
        self.value += amount

    def dec(self, amount: Union[int, float] = 1) -> None:
        self.value -= amount


class Histogram:
    """Cumulative-bucket histogram (Prometheus ``le`` semantics)."""
    __slots__ = ("edges", "counts", "sum", "count")

    def __init__(self, edges: Tuple[float, ...]):
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)   # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: Union[int, float]) -> None:
        v = float(value)
        lo, hi = 0, len(self.edges)            # first edge with v <= edge:
        while lo < hi:                         # boundary values land in the
            mid = (lo + hi) // 2               # low-side bucket
            if v <= self.edges[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        self.sum += v
        self.count += 1


@dataclass(frozen=True)
class HistogramValue:
    """Frozen histogram sample: cumulative (le, count) pairs ending at
    +Inf; the +Inf cumulative count always equals ``count``."""
    buckets: Tuple[Tuple[float, int], ...]
    sum: float
    count: int


class _Family:
    """One named metric + its per-label-set children."""

    def __init__(self, name: str, kind: str, help: str,
                 labelnames: Tuple[str, ...],
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = _validate_name(name)
        self.kind = kind
        self.help = help
        self.labelnames = labelnames
        self.buckets = buckets
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make_child(self):
        if self.kind == "counter":
            return Counter()
        if self.kind == "gauge":
            return Gauge()
        return Histogram(self.buckets)

    def labels(self, **labelvalues):
        """Child for one label-value set. Identity is guaranteed: the
        same values (any keyword order) return the same object."""
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(labelvalues)} != declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(labelvalues[k]) for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            # setdefault is atomic in CPython: when two publishers race
            # to create the same child, both get the one that won
            child = self._children.setdefault(key, self._make_child())
        return child

    @property
    def unlabeled(self):
        """The single child of a label-less family."""
        assert not self.labelnames, \
            f"{self.name} declares labels {self.labelnames}"
        return self.labels()


@dataclass(frozen=True)
class FamilySnapshot:
    name: str
    kind: str
    help: str
    labelnames: Tuple[str, ...]
    # ((labels mapping, value-or-HistogramValue), ...)
    samples: Tuple[Tuple[Mapping[str, str],
                         Union[float, HistogramValue]], ...]


@dataclass(frozen=True)
class Snapshot:
    """Immutable point-in-time copy of a whole registry."""
    families: Tuple[FamilySnapshot, ...]

    def get(self, name: str, **labels):
        """Value of one sample (float, or HistogramValue)."""
        for fam in self.families:
            if fam.name != name:
                continue
            want = {k: str(v) for k, v in labels.items()}
            for lbls, value in fam.samples:
                if dict(lbls) == want:
                    return value
            raise KeyError(f"{name}: no sample with labels {want}")
        raise KeyError(name)

    def flat(self) -> Dict[str, Union[float, int]]:
        """``name{k="v",...}`` -> scalar, histograms expanded into
        ``_bucket{le=...}`` / ``_sum`` / ``_count`` series — the exact
        sample set the Prometheus text exporter renders."""
        out: Dict[str, Union[float, int]] = {}
        for fam in self.families:
            for lbls, value in fam.samples:
                base = _render_labels(lbls)
                if isinstance(value, HistogramValue):
                    for le, c in value.buckets:
                        out[_suffixed(fam.name + "_bucket", lbls,
                                      le=le)] = c
                    out[fam.name + "_sum" + base] = value.sum
                    out[fam.name + "_count" + base] = value.count
                else:
                    out[fam.name + base] = value
        return out


def _render_labels(lbls: Mapping[str, str], **extra) -> str:
    items = list(lbls.items()) + [
        (k, "+Inf" if v == float("inf") else _fmt_num(v))
        for k, v in extra.items()]
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


def _suffixed(name: str, lbls: Mapping[str, str], **extra) -> str:
    return name + _render_labels(lbls, **extra)


def _fmt_num(v) -> str:
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class MetricsRegistry:
    """Factory + namespace for metric families.

    Re-declaring an existing name returns the existing family when the
    (kind, labelnames, buckets) signature matches and raises otherwise —
    two subsystems can share a family but never silently retype one.
    """

    def __init__(self):
        self._families: Dict[str, _Family] = {}

    # ------------------------------------------------------------------ #
    def _declare(self, name: str, kind: str, help: str,
                 labelnames: Iterable[str],
                 buckets: Optional[Tuple[float, ...]] = None) -> _Family:
        labelnames = tuple(labelnames)
        fam = self._families.get(name)
        if fam is not None:
            if (fam.kind, fam.labelnames, fam.buckets) != \
                    (kind, labelnames, buckets):
                raise ValueError(
                    f"metric {name!r} re-declared as {kind}"
                    f"{labelnames} (was {fam.kind}{fam.labelnames})")
            return fam
        fam = _Family(name, kind, help, labelnames, buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> _Family:
        return self._declare(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> _Family:
        return self._declare(name, "gauge", help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> _Family:
        edges = tuple(sorted(float(b) for b in buckets))
        assert edges and all(e == e for e in edges) \
            and edges[-1] != float("inf"), \
            "buckets must be finite (+Inf is implicit)"
        return self._declare(name, "histogram", help, (), edges) \
            if not labelnames else \
            self._declare(name, "histogram", help, labelnames, edges)

    # ------------------------------------------------------------------ #
    def snapshot(self) -> Snapshot:
        fams = []
        for fam in self._families.values():
            samples = []
            for key, child in fam._children.items():
                lbls = MappingProxyType(dict(zip(fam.labelnames, key)))
                if isinstance(child, Histogram):
                    cum, pairs = 0, []
                    for edge, c in zip(child.edges, child.counts):
                        cum += c
                        pairs.append((edge, cum))
                    pairs.append((float("inf"), child.count))
                    value: Union[float, HistogramValue] = HistogramValue(
                        buckets=tuple(pairs), sum=child.sum,
                        count=child.count)
                else:
                    value = child.value
                samples.append((lbls, value))
            fams.append(FamilySnapshot(
                name=fam.name, kind=fam.kind, help=fam.help,
                labelnames=fam.labelnames, samples=tuple(samples)))
        return Snapshot(families=tuple(fams))
