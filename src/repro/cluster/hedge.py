"""Straggler-aware hedged execution: config + lifecycle ledger.

A *hedge* races a stalled (or suspect-hosted) request on a second
instance: the primary keeps running, a clone starts on the best live
peer under a fresh delivery epoch, and the first terminal transition
wins. The loser is cancelled through the megastep-safe abort path and
its host is *fenced* for that request — any completion it produces
afterwards (a partitioned zombie finishing into the void) is counted,
never delivered.

``HedgeCoordinator`` is the backend-agnostic half: it owns the
per-request progress watchdog (:class:`~repro.core.pressure.
StragglerWatchdog`) and the lifecycle ledger, and it *enforces* the
hedging invariants at transition time rather than trusting the backends
to get them right:

  * at most one winner per request, ever;
  * no hedge launched for a terminal (or already-hedged-out) request;
  * delivery epochs strictly increase per request — an epoch is never
    reused, so a stale clone's messages can always be fenced by key;
  * a fenced loser never delivers downstream — ``deliverable`` answers
    the receiving side's "may this host still write this request?".

Both cluster backends (``EngineFleet`` real engines, ``ClusterSim``
discrete-event) drive the same coordinator, so one chaos schedule
produces the same hedge decisions on either. With ``enabled=False`` the
coordinator never issues a verdict and the backends take their legacy
paths untouched — hedging off is bitwise-unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Set, Tuple

from ..core.pressure import StragglerWatchdog


class HedgeViolation(AssertionError):
    """A hedging lifecycle invariant was broken (double winner, reused
    epoch, hedge on a terminal request, delivery past a fence)."""


@dataclass
class HedgeConfig:
    """Knobs for the hedged-execution tier.

    Stall thresholds are ``*_factor`` multiples of a rolling
    EWMA-smoothed ``quantile`` of observed TTFT / inter-token gaps
    (see :class:`~repro.core.pressure.StragglerWatchdog`), floored by
    ``floor`` so a cold estimator never hair-triggers. ``on_suspect``
    additionally hedges any tracked request whose host the failure
    detector marks SUSPECT — the partition case, where the zombie keeps
    *appearing* to make progress locally while the client sees nothing.
    ``max_hedges`` bounds clones per request (one is the classic
    tail-latency hedge; more buys nothing under greedy decoding)."""
    enabled: bool = True
    ttft_factor: float = 3.0
    rate_factor: float = 3.0
    quantile: float = 0.9
    window: int = 64
    min_samples: int = 4
    floor: float = 4.0
    alpha: float = 0.5
    on_suspect: bool = True
    max_hedges: int = 1

    def make_watchdog(self) -> StragglerWatchdog:
        return StragglerWatchdog(
            ttft_factor=self.ttft_factor, rate_factor=self.rate_factor,
            quantile=self.quantile, window=self.window,
            min_samples=self.min_samples, floor=self.floor,
            alpha=self.alpha)


@dataclass
class _HedgeState:
    """One in-flight hedge: the clone's host + delivery epoch."""
    clone_host: int
    epoch: tuple
    reason: str


class HedgeCoordinator:
    """Lifecycle ledger for hedged requests (see module docstring).

    ``key`` identifies one logical request on the backend's terms
    (``id(GenRequest)`` for the fleet, ``rid`` for the sim); ``host`` is
    an instance id. The coordinator never touches engines or transports
    — backends ask it *whether* to hedge (``want_hedge``), tell it what
    happened (``launch`` / ``resolve`` / ``mark_terminal``), and consult
    it at the delivery boundary (``deliverable`` / ``record_fenced``).
    """

    def __init__(self, cfg: Optional[HedgeConfig] = None):
        self.cfg = cfg or HedgeConfig()
        self.watchdog = self.cfg.make_watchdog()
        self._active: Dict[object, _HedgeState] = {}
        self._terminal: Set[object] = set()
        self._winner: Dict[object, str] = {}      # key -> primary|clone
        self._n_hedges: Dict[object, int] = {}    # clones launched so far
        self._last_epoch: Dict[object, tuple] = {}
        self._fenced: Set[Tuple[object, int]] = set()   # (key, host)
        self.n_fired = 0
        self.n_won = 0           # clone beat the primary
        self.n_cancelled = 0     # loser cancelled (either side)
        self.n_fenced = 0        # post-fence completions counted, dropped

    # -- watchdog feed -------------------------------------------------- #
    def track(self, key, now: float) -> None:
        if key not in self._terminal:
            self.watchdog.track(key, now)

    def observe_progress(self, key, tokens: int, now: float) -> None:
        self.watchdog.observe_progress(key, tokens, now)

    def reset_progress(self, key, tokens: int, now: float) -> None:
        if self.watchdog.tracked(key):
            self.watchdog.reset(key, tokens, now)

    # -- decisions ------------------------------------------------------ #
    def want_hedge(self, key, now: float,
                   host_suspect: bool = False) -> Optional[str]:
        """Reason to hedge ``key`` now (``"ttft-stall"`` /
        ``"rate-stall"`` / ``"suspect"``), or None. Never fires when
        disabled, for a terminal request, for one already racing a
        clone, or past the per-request hedge budget."""
        if not self.cfg.enabled or key in self._terminal \
                or key in self._active \
                or self._n_hedges.get(key, 0) >= self.cfg.max_hedges:
            return None
        stall = self.watchdog.stalled(key, now)
        if stall is not None:
            return stall
        if host_suspect and self.cfg.on_suspect \
                and self.watchdog.tracked(key):
            return "suspect"
        return None

    # -- lifecycle transitions (invariant-enforcing) -------------------- #
    def launch(self, key, epoch: tuple, clone_host: int,
               reason: str) -> None:
        """Record a clone launched for ``key`` on ``clone_host`` under
        delivery ``epoch``. Raises :class:`HedgeViolation` on a hedge
        for a terminal/resolved request, a concurrent second clone, or
        a non-increasing epoch."""
        if key in self._terminal or key in self._winner:
            raise HedgeViolation(f"hedge launched for terminal request "
                                 f"{key!r}")
        if key in self._active:
            raise HedgeViolation(f"second concurrent clone for {key!r}")
        if self._n_hedges.get(key, 0) >= self.cfg.max_hedges:
            raise HedgeViolation(f"hedge budget exhausted for {key!r}")
        last = self._last_epoch.get(key)
        if last is not None and epoch <= last:
            raise HedgeViolation(f"delivery epoch reused for {key!r}: "
                                 f"{epoch!r} after {last!r}")
        self._last_epoch[key] = epoch
        self._active[key] = _HedgeState(clone_host=clone_host,
                                        epoch=epoch, reason=reason)
        self._n_hedges[key] = self._n_hedges.get(key, 0) + 1
        self.n_fired += 1

    def resolve(self, key, winner: str, primary_host: int) -> None:
        """First terminal transition for a hedged request: ``winner`` is
        ``"primary"`` or ``"clone"``. The loser's host is fenced for
        this request. A second resolution raises — at most one winner,
        ever."""
        assert winner in ("primary", "clone"), winner
        st = self._active.pop(key, None)
        if key in self._winner:
            raise HedgeViolation(f"second winner for {key!r}: "
                                 f"{winner} after {self._winner[key]}")
        if st is None:
            raise HedgeViolation(f"resolve for {key!r} with no clone in "
                                 f"flight")
        self._winner[key] = winner
        self._terminal.add(key)
        self.watchdog.forget(key)
        loser = st.clone_host if winner == "primary" else primary_host
        self._fenced.add((key, loser))
        self.n_cancelled += 1
        if winner == "clone":
            self.n_won += 1

    def abandon(self, key) -> None:
        """The clone died without completing (its host crashed, or a
        deadline abort got it first): the race dissolves with no winner.
        The clone's host is fenced for this request; the primary keeps
        running, and the request may hedge again while budget remains."""
        st = self._active.pop(key, None)
        if st is None:
            raise HedgeViolation(f"abandon for {key!r} with no clone in "
                                 f"flight")
        self._fenced.add((key, st.clone_host))
        self.n_cancelled += 1

    def mark_terminal(self, key) -> None:
        """The request reached a terminal state with no clone in flight
        (the common, unhedged path). Idempotent; after this no hedge can
        launch for ``key``."""
        self._terminal.add(key)
        self.watchdog.forget(key)

    # -- delivery-boundary fencing -------------------------------------- #
    def deliverable(self, key, host: int) -> bool:
        """May ``host`` still deliver output for ``key``? False once the
        host lost the race — its late completions are fenced."""
        return (key, host) not in self._fenced

    def record_fenced(self, key, host: int) -> None:
        """Count one completion/emission arriving past the fence. The
        caller must drop it (counted, never delivered); delivering it
        anyway is the double-delivery bug this tier exists to prevent."""
        if self.deliverable(key, host):
            raise HedgeViolation(f"fenced completion recorded for "
                                 f"un-fenced ({key!r}, host {host})")
        self.n_fenced += 1

    # -- introspection -------------------------------------------------- #
    def active(self, key) -> bool:
        return key in self._active

    def clone_host(self, key) -> Optional[int]:
        st = self._active.get(key)
        return None if st is None else st.clone_host

    def clone_epoch(self, key) -> Optional[tuple]:
        st = self._active.get(key)
        return None if st is None else st.epoch

    def winner(self, key) -> Optional[str]:
        return self._winner.get(key)

    def counters(self) -> Dict[str, int]:
        return {
            "hedges_fired": self.n_fired,
            "hedges_won": self.n_won,
            "hedges_cancelled": self.n_cancelled,
            "fenced_completions": self.n_fenced,
            "stall_verdicts": self.watchdog.n_stall_verdicts,
        }

    def publish_metrics(self, registry) -> None:
        """Publish the ``hedge_*`` metric family into a ``repro.obs``
        registry (both backends call this from their metrics hooks)."""
        registry.counter("hedge_fired_total",
                         "hedge clones launched") \
            .unlabeled.inc_to(self.n_fired)
        registry.counter("hedge_won_total",
                         "hedge clones that beat their primary") \
            .unlabeled.inc_to(self.n_won)
        registry.counter("hedge_cancelled_total",
                         "hedge losers cancelled (either side)") \
            .unlabeled.inc_to(self.n_cancelled)
        registry.counter("hedge_fenced_completions_total",
                         "completions arriving past a fence: counted, "
                         "never delivered") \
            .unlabeled.inc_to(self.n_fenced)
        registry.counter("hedge_stall_verdicts_total",
                         "watchdog stall verdicts (TTFT + token-rate)") \
            .unlabeled.inc_to(self.watchdog.n_stall_verdicts)
