"""Cluster serving layer: one request stream across N engine instances.

Two interchangeable backends share routers, roles and the autoscaler:

  * ``ClusterSim``   — discrete-event simulation (N ``SimInstance``s under
    a shared event clock) for large-scale experiments (fig 12);
  * ``EngineFleet``  — N real in-process ``ServingEngine``s (shared model
    parameters, per-engine caches/schedulers) driven by one event loop,
    with live KV migration between disaggregated prefill/decode roles.
"""
from .autoscale import AutoscaleConfig, GoodputAutoscaler
from .fleet import EngineFleet, FleetInstance
from .router import (LeastKVCRouter, LeastOutstandingTokensRouter, ROUTERS,
                     Router, RoundRobinRouter, make_router)
from .sim import ClusterInstance, ClusterResult, ClusterSim, ROLES
