"""Cluster serving layer: one request stream across N engine instances.

Two interchangeable backends share routers, roles and the autoscaler:

  * ``ClusterSim``   — discrete-event simulation (N ``SimInstance``s under
    a shared event clock) for large-scale experiments (fig 12);
  * ``EngineFleet``  — N real in-process ``ServingEngine``s (shared model
    parameters, per-engine caches/schedulers) driven by one event loop,
    with live KV migration between disaggregated prefill/decode roles.

``repro.cluster.faults`` adds the chaos layer both backends share:
scripted/probabilistic fault injection (kill / freeze / slow /
corrupt-KV / KVC squeeze / drop / dup / delay), bounded-retry crash
recovery with seeded backoff jitter, and the post-run conservation
audit (``check_fleet_invariants``). ``repro.cluster.transport`` is the
lossy message layer those drop/dup/delay windows act on, and
``repro.cluster.base`` hosts the heartbeat/lease ``FailureDetector``
that turns declared failure into *detected* failure on both backends.
``repro.cluster.hedge`` adds straggler-aware hedged execution on top:
a progress watchdog races stalled (or suspect-hosted) requests on a
second instance under first-winner fencing — including across the
asymmetric network partitions (``part@t:a|b/dur``) the transport can
inject, where a partitioned instance keeps running as a zombie and its
late completions are counted, never double-delivered.
"""
from .autoscale import AutoscaleConfig, GoodputAutoscaler
from .base import (DEAD, DetectorConfig, FailureDetector, HEALTH_STATES,
                   HEALTHY, SUSPECT)
from .faults import (ChaosSpecError, FAULT_KINDS, FaultEvent, FaultInjector,
                     InvariantViolation, RecoveryConfig, backoff_delay,
                     check_fleet_invariants, parse_chaos_spec)
from .fleet import EngineFleet, FleetInstance
from .hedge import (HedgeConfig, HedgeCoordinator, HedgeViolation)
from .router import (LeastKVCRouter, LeastOutstandingTokensRouter, ROUTERS,
                     Router, RoundRobinRouter, make_router)
from .sim import ClusterInstance, ClusterResult, ClusterSim, ROLES
from .transport import DETECTOR, Message, Transport, Verdict
