"""Cluster discrete-event simulator: one request stream over N instances.

Each instance is a ``repro.core.simulator.SimInstance`` (the same stepping
primitive the single-engine ``simulate`` uses) with its own scheduler and
KVC; ``ClusterSim`` interleaves them under a shared event clock:

  * the next event is the earliest of (next unrouted arrival, next ready
    KV migration, earliest instance able to step); arrivals/migrations are
    routed exactly when they become the earliest event, so every routing
    decision observes instance state as of that moment;
  * a routed request is *delivered* to its instance only once the instance
    clock reaches it (an instance mid-iteration cannot see a request that
    arrives inside the iteration — same semantics as the single-engine
    loop);
  * instance **roles** model disaggregated serving à la DistServe: a
    ``prefill`` instance's finished prompts are pulled out of its GT queue
    and migrated — KV freed at the source, a ``kv_transfer_time`` delay,
    then queued-GT delivery at a ``decode`` instance chosen by the decode
    router. ``unified`` instances (the default) serve both phases;
  * an optional ``GoodputAutoscaler`` is evaluated at every arrival: +1
    adds a fresh unified instance at the current time, -1 marks the
    least-loaded unified instance *draining* (no new routes; in-flight
    work finishes; the instance retires when empty).

Conservation is tracked structurally: every submitted rid is routed at
most once (``double_routes`` counts violations) and must complete on
exactly one instance (``ClusterResult.conservation``) — the gate
``benchmarks/hotpath_micro.py --check`` enforces in CI.

Scheduler contract: role-based migration moves requests through
``scheduler.gt_queue``, which the EconoServe/MultiRes family consumes
(vLLM/ORCA-style baselines keep private running lists and only support
``unified`` roles).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import CostModel
from repro.core.metrics import SimResult
from repro.core.request import Request, State
from repro.core.scheduler import BaseScheduler
from repro.core.simulator import SimInstance

from .autoscale import GoodputAutoscaler
from .base import (DEAD, DetectorConfig, FailureDetector, HEALTHY,
                   InstanceBase, ROLES, execute_autoscale, validate_roles)
from .faults import FaultInjector, RecoveryConfig, backoff_delay
from .hedge import HedgeConfig, HedgeCoordinator
from .router import Router, make_router
from .transport import Transport

_INF = float("inf")
_EPS = 1e-12

__all__ = ["ClusterInstance", "ClusterResult", "ClusterSim", "ROLES"]


class ClusterInstance(InstanceBase):
    """One simulated instance plus its routing-visible stats."""

    def __init__(self, iid: int, sim: SimInstance, role: str = "unified"):
        super().__init__(iid, role)
        self.sim = sim
        self.stalled = False          # has work the scheduler cannot place
        # routed-but-undelivered requests: (deliver_t, req, as_gt, dkey),
        # kept time-sorted — routing happens in global event-time order
        # and a transport delay re-sorts on insert
        self.pending: List[Tuple[float, Request, bool, Optional[tuple]]] = []
        self._seen: set = set()       # delivery keys applied (idempotency)
        self.n_dup_deliveries = 0     # duplicates suppressed at this rank

    @property
    def scheduler(self):
        return self.sim.scheduler

    def outstanding_tokens(self) -> int:
        tot = super().outstanding_tokens()
        for _, r, _, _ in self.pending:
            tot += (r.prompt_len - r.prompt_done) + r.remaining_predicted
        return tot

    # -- event-loop interface ------------------------------------------ #
    def next_time(self) -> float:
        if self.crashed or (self.health == DEAD and not self.detected):
            return _INF               # silent carcass: only the detector
            # (or a declared kill) frees its work. A *detected* DEAD
            # instance that never crashed is a zombie (partitioned away
            # from the control plane): it keeps stepping — its output is
            # fenced at the delivery boundary, not by freezing it
        t = _INF
        if self.sim.has_work() and not self.stalled:
            t = self.sim.t
        elif self.pending:
            t = max(self.sim.t, self.pending[0][0])
        if t != _INF and self.frozen_until > t:
            t = max(t, self.frozen_until)    # frozen: wakes at the thaw
        return t

    def deliver_due(self) -> None:
        if not self.pending:
            return
        if not (self.sim.has_work() and not self.stalled):
            self.sim.advance_to(self.pending[0][0])
        while self.pending and self.pending[0][0] <= self.sim.t + _EPS:
            _, req, as_gt, dkey = self.pending.pop(0)
            if dkey is not None:
                if dkey in self._seen:
                    self.n_dup_deliveries += 1   # at-least-once duplicate
                    continue                     # suppressed: exactly-once
                self._seen.add(dkey)             # effect on the instance
            if as_gt:
                self.sim.scheduler.enqueue_gt(req)
            else:
                self.sim.deliver(req, self.sim.t)
            self.stalled = False

    def idle(self) -> bool:
        return not self.sim.has_work() and not self.pending


@dataclass
class ClusterResult:
    """Fleet-level aggregate + per-instance SimResults."""
    name: str
    requests: List[Request]
    per_instance: List[SimResult]
    wall_time: float
    n_routed: int = 0
    n_migrations: int = 0
    double_routes: int = 0
    route_of: Dict[int, int] = field(default_factory=dict)
    completed_by: Dict[int, List[int]] = field(default_factory=dict)
    scale_events: List[Tuple[float, int]] = field(default_factory=list)
    aborted: List[int] = field(default_factory=list)   # terminal, not done
    n_recovered: int = 0
    fault_log: List[Tuple[float, str, int]] = field(default_factory=list)
    # detected-failure / shed-retry accounting (zero in declared mode)
    n_shed_reroutes: int = 0     # rung-4 sheds handed to the retry tier
    n_shed_rescued: int = 0      # of those, delivered to a feasible peer
    n_shed_terminal: int = 0     # of those, shed for good (no peer fits)
    n_dup_deliveries: int = 0    # duplicates suppressed by idempotency
    n_false_suspects: int = 0    # suspects reinstated by a fresh beat
    # hedged execution / partition fencing (zero with hedging off and no
    # partition faults)
    n_fenced_completions: int = 0   # zombie completions counted, dropped
    n_hedges_fired: int = 0
    n_hedges_won: int = 0           # clone beat its primary
    n_hedges_cancelled: int = 0     # losers cancelled (either side)
    detector_transitions: List[Tuple[float, int, str, str]] = \
        field(default_factory=list)
    transport_stats: Dict[str, int] = field(default_factory=dict)

    @property
    def n_instances(self) -> int:
        return len(self.per_instance)

    @property
    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.t_complete is not None]

    @property
    def goodput(self) -> float:
        """SLO-met completions per second across the fleet (fig 12)."""
        return sum(r.met_slo for r in self.completed) \
            / max(1e-9, self.wall_time)

    @property
    def ssr(self) -> float:
        c = self.completed
        return sum(r.met_slo for r in c) / max(1, len(c))

    @property
    def throughput_reqs(self) -> float:
        return len(self.completed) / max(1e-9, self.wall_time)

    def conservation(self) -> Dict[str, int]:
        """Structural invariant: every routed rid reaches exactly one
        terminal state — completed on exactly one instance, or aborted
        (retry budget / deadline / no-live-instance) — with zero
        double-routes."""
        counts: Dict[int, int] = {}
        for rids in self.completed_by.values():
            for rid in rids:
                counts[rid] = counts.get(rid, 0) + 1
        aborted = set(self.aborted)
        dups = sum(1 for c in counts.values() if c > 1)
        both = sum(1 for rid in aborted if counts.get(rid, 0) > 0)
        missing = sum(1 for rid in self.route_of
                      if counts.get(rid, 0) == 0 and rid not in aborted)
        return {"submitted": len(self.requests),
                "routed": self.n_routed,
                "completed": len(counts),
                "aborted": len(aborted),
                "duplicate_completions": dups,
                "uncompleted_routed": missing,
                "double_routes": self.double_routes,
                "fenced_completions": self.n_fenced_completions,
                "hedges_fired": self.n_hedges_fired,
                "hedges_won": self.n_hedges_won,
                "hedges_cancelled": self.n_hedges_cancelled,
                "ok": int(dups == 0 and both == 0
                          and self.double_routes == 0
                          and missing == 0
                          and len(counts) + len(aborted)
                          == len(self.requests))}


class ClusterSim:
    def __init__(self, scheduler_factory: Callable[[int], BaseScheduler],
                 cost: CostModel, n_instances: int = 2,
                 router: str = "least-kvc",
                 roles: Optional[Sequence[str]] = None,
                 seed: int = 0,
                 autoscaler: Optional[GoodputAutoscaler] = None,
                 faults: Optional[FaultInjector] = None,
                 recovery: Optional[RecoveryConfig] = None,
                 detector: Optional[DetectorConfig] = None,
                 hedge: Optional[HedgeConfig] = None,
                 collect_samples: bool = False,
                 name: Optional[str] = None):
        self.factory = scheduler_factory
        self.cost = cost
        self.collect_samples = collect_samples
        self.faults = faults
        self.recovery = recovery or RecoveryConfig()
        roles = validate_roles(roles, n_instances)
        self.instances: List[ClusterInstance] = [
            ClusterInstance(i, SimInstance(scheduler_factory(i), cost,
                                           collect_samples), roles[i])
            for i in range(n_instances)]
        # detected failure: the sim keeps its own delivery structures (the
        # pending lists + migration heap) and asks the transport only to
        # *judge* each send, so one chaos schedule reproduces on either
        # backend; heartbeats/leases drive observed health exactly as on
        # the real-engine fleet
        self.detector_cfg = detector
        self.transport = Transport(seed=seed + 7) \
            if detector is not None else None
        self.detector = FailureDetector(detector, self.transport) \
            if detector is not None else None
        if self.detector is not None:
            for inst in self.instances:
                inst.detected = True
            if self.faults is not None:
                self.faults.detected = True
                self.faults.transport = self.transport
        self.router: Router = make_router(router, seed) \
            if isinstance(router, str) else router
        # migrations get their own router instance (same policy) so the
        # decode-side cycle/tie stream is independent of the arrival side
        rname = self.router.name if not isinstance(router, str) else router
        self.decode_router: Router = make_router(rname, seed + 1)
        self.autoscaler = autoscaler
        self.name = name or f"cluster-{rname}-x{n_instances}"
        # conservation / accounting
        self.route_of: Dict[int, int] = {}
        self.double_routes = 0
        self.n_migrations = 0
        self.scale_events: List[Tuple[float, int]] = []
        self._next_id = n_instances
        self._mig_seq = 0
        # fault-tolerance accounting
        self._retries: Dict[int, int] = {}       # rid -> recovery attempts
        self._dead_handled: set = set()
        self.aborted_rids: List[int] = []
        self.n_recovered = 0
        # at-least-once delivery epochs (rid -> epoch) + shed-retry tier
        self._epoch: Dict[int, int] = {}
        self._migrations: List = []              # bound to run()'s heap
        self._shed_rids: set = set()             # rids in the retry tier
        self.n_shed_reroutes = 0
        self.n_shed_rescued = 0
        self.n_shed_terminal = 0
        # hedged execution + partition fencing. Fencing (zombies, clone
        # recovery, winner snapshots) is independent of hedging: partition
        # chaos with hedging off still needs it for conservation
        self.hedge = HedgeCoordinator(hedge) if hedge is not None else None
        if self.hedge is not None:
            assert detector is not None, \
                "hedging requires the failure detector (suspect signal)"
        self._hedge_seq = 0                      # global epoch stream
        self._hedge_live: Dict[int, Request] = {}    # watched originals
        # rid -> {orig, clone, piid, ciid?, p_gone?}: one in-flight race
        self._races: Dict[int, dict] = {}
        self._host_of: Dict[int, int] = {}       # rid -> last chosen host
        self._fenced: set = set()                # (iid, rid): zombie side
        self._dead_objs: set = set()             # id(Request): race losers
        # rid -> (orig, winner-src): terminal fields re-applied at run()
        # end — a fenced zombie may stomp the client record mid-run
        self._swap_result: Dict[int, Tuple[Request, Request]] = {}
        self._cancel_at: List = []               # (heal_t, seq, iid, rid)
        self.n_fenced_completions = 0

    def _dkey(self, rid: int) -> tuple:
        """Fresh delivery key for one intentional (re)delivery of rid."""
        ep = self._epoch.get(rid, 0) + 1
        self._epoch[rid] = ep
        return (rid, ep)

    # ------------------------------------------------------------------ #
    def _route(self, req: Request, t: float, as_gt: bool,
               rerouted: bool = False) -> None:
        if id(req) in self._dead_objs:
            return          # fenced race loser: never resurrected
        cands = [i for i in self.instances
                 if (i.accepts_decodes() if as_gt else i.accepts_prompts())]
        if not cands:
            # every eligible instance is draining or degraded: fall back
            # to any live instance of the right role (a route beats
            # dropping the request), then to any live instance at all
            want = ("unified", "decode") if as_gt else ("unified", "prefill")
            cands = [i for i in self.instances
                     if i.alive and i.role in want] \
                or [i for i in self.instances if i.alive]
        race = self._races.get(req.rid)
        if race is not None and race["clone"] is req:
            # a hedge clone must never land on its own primary (same-rid
            # collision inside one scheduler); with no other peer left
            # the race dissolves and the primary runs alone
            cands = [i for i in cands if i.id != race["piid"]]
            if not cands:
                self._abandon_race(req.rid, t)
                return
        if not cands:
            # whole fleet is dead: the request cannot be served, ever —
            # record a terminal abort instead of losing it silently
            req.set_state(State.ABORTED, t)
            self.aborted_rids.append(req.rid)
            return
        demand = req.prompt_len + max(req.padded_rl, req.predicted_rl, 1)
        if rerouted and req.rid in self._shed_rids:
            # shed-retry tier: only a peer whose *total* KVC can ever fund
            # the frozen exact-alloc demand may receive a rung-4 shed
            fits = [i for i in cands if i.scheduler.fits_ever(demand)]
            if not fits:
                if any(i.alive and i.scheduler.fits_ever(demand)
                       for i in self.instances):
                    # a feasible peer exists but is not routable right
                    # now (draining/degraded): burn a retry and wait
                    self._recover(req, t, self._migrations)
                else:
                    self.n_shed_terminal += 1
                    req.set_state(State.ABORTED, t)
                    self.aborted_rids.append(req.rid)
                return
            cands = fits
            self.n_shed_rescued += 1
        router = self.decode_router if as_gt else self.router
        inst = router.choose(cands, demand)
        if not as_gt:
            if req.rid in self.route_of and not rerouted:
                self.double_routes += 1
            self.route_of[req.rid] = inst.id
            if not rerouted and self.hedge is not None \
                    and self.hedge.cfg.enabled:
                self.hedge.track(req.rid, t)
                self._hedge_live[req.rid] = req
        if rerouted and self.hedge is not None and self.hedge.cfg.enabled:
            # re-delivery re-arms the stall clocks: the new host deserves
            # a full threshold window before being called a straggler
            self.hedge.reset_progress(req.rid, req.generated, t)
        self._deliver(inst, req, t, as_gt)

    def _deliver(self, inst: ClusterInstance, req: Request, t: float,
                 as_gt: bool) -> None:
        """Hand one routed request to its instance — through the lossy
        transport's verdict when detection is on (drop => retransmit via
        the shared event heap, dup => two pending copies sharing one
        delivery key, delay => deferred and possibly overtaken)."""
        race = self._races.get(req.rid)
        if race is not None and race["clone"] is req:
            race["ciid"] = inst.id       # clone-side fence key tracks
        else:                            # the host actually delivered to
            self._host_of[req.rid] = inst.id
        if self.transport is None:
            inst.pending.append((t, req, as_gt, None))
            inst.stalled = False
            return
        dkey = self._dkey(req.rid)
        v = self.transport.judge(inst.id, t)
        if v.heal > 0.0:
            # partitioned link: the sender's retry timer holds the send
            # and re-routes once the partition heals (fresh decision,
            # fresh epoch) — data is never silently lost to a partition
            self._mig_seq += 1
            heapq.heappush(self._migrations,
                           (max(t + self.transport.retransmit_after,
                                v.heal),
                            self._mig_seq, req, as_gt))
            return
        deliver_t = t + v.delay
        if v.drop:
            # at-least-once: the sender's retry timer re-sends (a fresh
            # routing decision and a fresh epoch — the original is gone)
            self.transport.n_retransmits += 1
            self._mig_seq += 1
            heapq.heappush(self._migrations,
                           (deliver_t + self.transport.retransmit_after,
                            self._mig_seq, req, as_gt))
            return
        self._push_pending(inst, deliver_t, req, as_gt, dkey)
        if v.dup:
            self._push_pending(inst, deliver_t, req, as_gt, dkey)

    @staticmethod
    def _push_pending(inst: ClusterInstance, deliver_t: float,
                      req: Request, as_gt: bool, dkey) -> None:
        inst.pending.append((deliver_t, req, as_gt, dkey))
        if len(inst.pending) > 1 and inst.pending[-2][0] > deliver_t:
            # a delayed message was overtaken: restore delivery order
            # (stable sort keeps FIFO among equal times)
            inst.pending.sort(key=lambda p: p[0])
        inst.stalled = False

    def _collect_migrations(self, inst: ClusterInstance,
                            heap: List) -> None:
        """Pull finished prompts off a prefill instance: free their KVC,
        pay the KV transfer, and schedule queued-GT delivery at a decode
        instance (chosen when the transfer lands)."""
        sched = inst.sim.scheduler
        for r in list(sched.gt_queue):
            sched.gt_queue.remove(r)
            sched.kvc.free(r.rid)
            tokens = r.prompt_len + r.generated
            r.occupied_kvc = tokens          # held in transfer/host memory
            xfer = self.cost.kv_transfer_time(tokens)
            r.swap_time += xfer
            self._mig_seq += 1
            heapq.heappush(heap, (inst.sim.t + xfer, self._mig_seq, r, True))
            self.n_migrations += 1

    # -- fault handling / crash recovery -------------------------------- #
    def _reclaim_dead(self, t: float, heap: List) -> None:
        """Sweep newly-dead instances: pull every non-terminal request off
        the carcass (undelivered pendings, queues, running groups — the
        scheduler's ``cancel`` releases KVC and cascades pipelined
        orphans) and queue each for recovery elsewhere."""
        for inst in self.instances:
            if inst.alive or inst.id in self._dead_handled:
                continue
            self._dead_handled.add(inst.id)
            if inst.detected and not inst.crashed:
                # declared dead but still running: a partitioned zombie —
                # fence it instead of cancelling through the partition
                self._reclaim_zombie(inst, t, heap)
                continue
            victims, vseen = [], set()
            for _, r, _, _ in inst.pending:
                if r.rid not in vseen:      # dup'd copies: recover once
                    vseen.add(r.rid)
                    victims.append(r)
            inst.pending.clear()
            inst.stalled = False
            sched = inst.sim.scheduler
            while True:
                nxt = next(iter(sched.pt_queue), None) \
                    or next(iter(sched.gt_queue), None)
                if nxt is None:
                    nxt = next((m for g in sched.running_groups
                                for m in g.members), None)
                if nxt is None:
                    break
                c = sched.cancel(nxt.rid, t)
                if c is None:          # defensive: avoid an infinite sweep
                    break
                victims.append(c)
            for r in victims:
                race = self._races.get(r.rid)
                if race is not None and race["clone"] is r:
                    # the clone died with its host: the race dissolves
                    self._abandon_race(r.rid, t)
                    continue
                if race is not None and race["orig"] is r:
                    # the primary died mid-race: the clone IS the
                    # recovery — resolution will crown it
                    race["p_gone"] = True
                    continue
                if id(r) in self._dead_objs:
                    continue
                self._recover(r, t, heap)
            if self.autoscaler is not None:
                self.autoscaler.invalidate()

    def _reclaim_zombie(self, inst: "ClusterInstance", t: float,
                        heap: List) -> None:
        """A *detected*-DEAD instance that never crashed is a partitioned
        zombie: it keeps crunching, but nothing it produces from here on
        is client-visible. Undelivered pendings are recovered normally
        (they never reached the device). Requests already on the zombie
        are *fenced*: a same-rid clone re-enters service elsewhere (the
        original object stays with the zombie, so a late completion can
        never mutate what the client finally reads past the winner
        snapshot), the zombie's scheduler state is reclaimed by a cancel
        deferred to the partition heal — the first instant the control
        plane can reach it again — and any completion it produces
        meanwhile is counted, never delivered."""
        victims, vseen = [], set()
        for _, r, _, _ in inst.pending:
            if r.rid not in vseen and id(r) not in self._dead_objs:
                vseen.add(r.rid)
                victims.append(r)
        inst.pending.clear()
        inst.stalled = False
        for r in victims:
            race = self._races.get(r.rid)
            if race is not None and race["clone"] is r:
                self._abandon_race(r.rid, t)
                continue
            self._recover(r, t, heap)
        heal = t
        if self.transport is not None:
            heal = max(t, self.transport.partition_heal(inst.id, t))
        sched = inst.sim.scheduler
        held = list(sched.pt_queue) + list(sched.gt_queue) \
            + [m for g in sched.running_groups for m in g.members]
        hseen = set()
        for r in held:
            if r.rid in hseen or r.t_complete is not None:
                continue
            hseen.add(r.rid)
            self._fenced.add((inst.id, r.rid))
            self._mig_seq += 1
            heapq.heappush(self._cancel_at,
                           (heal, self._mig_seq, inst.id, r.rid))
            if self._races.get(r.rid) is not None:
                continue     # racing: the hedge clone is the recovery
            if id(r) in self._dead_objs:
                continue
            clone = self._clone_request(r)
            self._swap_result[r.rid] = (r, clone)
            self._hedge_live.pop(r.rid, None)
            if self.hedge is not None:
                self.hedge.watchdog.forget(r.rid)
            self._recover(clone, t, heap)
        if self.autoscaler is not None and (victims or hseen):
            self.autoscaler.invalidate()

    def _recover(self, req: Request, t: float, heap: List) -> None:
        """Requeue a reclaimed request with bounded retries + exponential
        backoff. Progressed requests re-enter as queued GTs holding their
        context 'in host memory' (the swap-recompute path re-onboards
        them); unstarted ones are re-routed as fresh PTs."""
        if id(req) in self._dead_objs:
            return               # fenced race loser: never resurrected
        att = self._retries.get(req.rid, 0)
        if att >= self.recovery.max_retries:
            if req.rid in self._shed_rids:
                self.n_shed_terminal += 1    # retry tier exhausted: the
            req.set_state(State.ABORTED, t)  # shed becomes terminal
            self.aborted_rids.append(req.rid)
            return
        self._retries[req.rid] = att + 1
        delay = backoff_delay(self.recovery, req.rid, att)
        as_gt = req.generated > 0
        if as_gt:
            req.prompt_done = req.prompt_len
            req.occupied_kvc = req.prompt_len + req.generated
        else:
            req.prompt_done = 0
            req.occupied_kvc = 0
        req.n_preemptions += 1
        req.set_state(State.QUEUED_GT if as_gt else State.QUEUED_PT, t)
        self._mig_seq += 1
        heapq.heappush(heap, (t + delay, self._mig_seq, req, as_gt))
        self.n_recovered += 1

    # -- hedged execution ----------------------------------------------- #
    @staticmethod
    def _clone_request(src: Request) -> Request:
        """Private same-rid copy for re-delivery while the original is
        stranded behind a fence (or racing as a hedge): the rid is the
        fleet-level identity, but a distinct object means the fenced
        side can never mutate what the client finally reads."""
        c = Request(rid=src.rid, prompt_len=src.prompt_len,
                    true_rl=src.true_rl, arrival=src.arrival,
                    slo_deadline=src.slo_deadline)
        c.predicted_rl = src.predicted_rl
        c.padded_rl = src.padded_rl
        c.generated = src.generated
        c.t_first_token = src.t_first_token
        c.n_preemptions = src.n_preemptions
        return c

    @staticmethod
    def _apply_snapshot(orig: Request, src: Request) -> None:
        """Re-apply the winner's client-visible terminal fields onto the
        original (client-held) record — a fenced zombie may have stomped
        them with completions the client never saw."""
        if src is orig:
            return
        if src.t_complete is None and src.state != State.ABORTED:
            return
        orig.state = src.state
        orig.t_complete = src.t_complete
        orig.generated = src.generated
        if src.t_first_token is not None:
            orig.t_first_token = src.t_first_token \
                if orig.t_first_token is None \
                else min(orig.t_first_token, src.t_first_token)

    def _drop_pending(self, obj: Request) -> None:
        for inst in self.instances:
            if any(p[1] is obj for p in inst.pending):
                inst.pending = [p for p in inst.pending
                                if p[1] is not obj]

    def _cancel_loser(self, rid: int, loser: Request, t: float) -> None:
        """Fence + cancel the losing copy of a resolved race everywhere
        it could still run: live schedulers detach it now (releasing
        KVC/slots), zombies reconcile through the cancel already
        deferred to their partition heal, and the object is marked dead
        so the recovery/retransmit paths can never resurrect it. The
        winner is terminal, so by construction the scan can only ever
        detach the loser."""
        self._dead_objs.add(id(loser))
        self._drop_pending(loser)
        for inst in self.instances:
            if not inst.alive or inst.crashed:
                continue
            sched = inst.sim.scheduler
            held = any(q.rid == rid for q in list(sched.pt_queue)) \
                or any(q.rid == rid for q in list(sched.gt_queue)) \
                or any(m.rid == rid for g in sched.running_groups
                       for m in g.members)
            if held:
                sched.cancel(rid, t)

    def _abandon_race(self, rid: int, t: float) -> None:
        """The clone died without a client-visible completion (its host
        crashed, was fenced, or no peer could host it): the race
        dissolves with no winner. If the primary is still live it races
        on alone; if both copies are gone, recover from the
        furthest-progressed snapshot so the request still reaches
        exactly one terminal state."""
        ent = self._races.pop(rid)
        orig, clone = ent["orig"], ent["clone"]
        self.hedge.abandon(rid)
        self._dead_objs.add(id(clone))
        self._drop_pending(clone)
        p_live = not ent.get("p_gone") \
            and (ent["piid"], rid) not in self._fenced
        if p_live:
            return
        src = clone if clone.generated >= orig.generated else orig
        c2 = self._clone_request(src)
        self._swap_result[rid] = (orig, c2)
        self._hedge_live.pop(rid, None)
        self.hedge.watchdog.forget(rid)
        self._recover(c2, t, self._migrations)

    def _launch_hedge(self, r: Request, piid: Optional[int], reason: str,
                      t: float) -> None:
        """Race a stalled/suspect-hosted request on the best live peer:
        a same-rid clone seeded with the client-visible progress enters
        under a fresh delivery epoch; first terminal transition wins."""
        as_gt = r.generated > 0
        cands = [i for i in self.instances
                 if (i.accepts_decodes() if as_gt else i.accepts_prompts())
                 and i.id != piid]
        if not cands:
            return               # no live peer to race against
        clone = self._clone_request(r)
        if as_gt:
            clone.prompt_done = clone.prompt_len
            clone.occupied_kvc = clone.prompt_len + clone.generated
            clone.n_preemptions += 1
            clone.set_state(State.QUEUED_GT, t)
        demand = clone.prompt_len + max(clone.padded_rl,
                                        clone.predicted_rl, 1)
        router = self.decode_router if as_gt else self.router
        inst = router.choose(cands, demand)
        self._hedge_seq += 1
        self.hedge.launch(r.rid, (self._hedge_seq,), inst.id, reason)
        self._races[r.rid] = {"orig": r, "clone": clone, "piid": piid,
                              "ciid": inst.id}
        self._deliver(inst, clone, t, as_gt)

    def _resolve_races(self, t: float) -> None:
        """First terminal transition wins; the loser is fenced+cancelled.
        A terminal transition behind a fence is not client-visible and
        can never win."""
        for rid, ent in list(self._races.items()):
            orig, clone, piid = ent["orig"], ent["clone"], ent["piid"]
            ciid = ent.get("ciid")
            p_live = not ent.get("p_gone") \
                and (piid, rid) not in self._fenced
            c_live = ciid is None or (ciid, rid) not in self._fenced
            if orig.t_complete is not None and p_live:
                self.hedge.resolve(rid, "primary", piid)
                self._cancel_loser(rid, clone, t)
                self._hedge_live.pop(rid, None)
                del self._races[rid]
                continue
            if clone.t_complete is not None and c_live:
                self.hedge.resolve(rid, "clone", piid)
                if p_live:
                    self._cancel_loser(rid, orig, t)
                else:
                    self._dead_objs.add(id(orig))
                self._swap_result[rid] = (orig, clone)
                self._apply_snapshot(orig, clone)
                self._hedge_live.pop(rid, None)
                del self._races[rid]
                continue
            clone_dead = clone.state == State.ABORTED \
                or (clone.t_complete is not None and not c_live)
            if clone_dead:
                self._abandon_race(rid, t)

    def _hedge_tick(self, t: float, heap: List) -> None:
        """Per-event hedging pass: resolve finished races, feed the
        progress watchdog with client-visible progress, and launch a
        clone for any request that stalled or whose host went suspect.
        Runs after every step/detector event so a completion is always
        observed before any other instance can produce a second one."""
        hedge = self.hedge
        if hedge is None or not hedge.cfg.enabled:
            return
        self._resolve_races(t)
        for rid, r in list(self._hedge_live.items()):
            if rid in self._races:
                continue                  # racing: resolution handles it
            if r.t_complete is not None or r.state == State.ABORTED:
                hedge.mark_terminal(rid)
                del self._hedge_live[rid]
                continue
            hedge.observe_progress(rid, r.generated, t)
            piid = self._host_of.get(rid)
            inst = next((i for i in self.instances if i.id == piid), None)
            suspect = inst is not None and inst.health != HEALTHY
            reason = hedge.want_hedge(rid, t, host_suspect=suspect)
            if reason is None:
                continue
            if any(m[2] is r for m in heap):
                continue                  # mid-recovery: re-route first
            self._launch_hedge(r, piid, reason, t)

    def _apply_due_cancels(self, t: float) -> None:
        """Heal-deferred fencing cancels: the first instant the control
        plane can reach a zombie again, its fenced scheduler state is
        reclaimed (KVC freed, groups cascaded). A rid the zombie already
        finished cancels to nothing — the completion stays fenced."""
        while self._cancel_at and self._cancel_at[0][0] <= t + _EPS:
            _, _, iid, rid = heapq.heappop(self._cancel_at)
            inst = next((i for i in self.instances if i.id == iid), None)
            if inst is None or inst.crashed:
                continue
            inst.sim.scheduler.cancel(rid, t)

    # ------------------------------------------------------------------ #
    def _spawn(self, t: float) -> None:
        iid = self._next_id
        self._next_id += 1
        inst = ClusterInstance(
            iid, SimInstance(self.factory(iid), self.cost,
                             self.collect_samples), "unified")
        inst.sim.advance_to(t)
        if self.detector is not None:
            inst.detected = True
        self.instances.append(inst)

    def _autoscale(self, t: float) -> None:
        if self.autoscaler is not None:
            execute_autoscale(self.autoscaler, t, self.instances,
                              self._spawn, self.scale_events)

    def publish_metrics(self, registry) -> None:
        """Publish the cluster — every instance's scheduler+KVC
        (instance-labelled), routers, autoscaler, detector, transport,
        and the conservation counters — into a ``repro.obs`` registry.
        Same families the real ``EngineFleet`` publishes, so dashboards
        and the trace replayer read one schema for both backends."""
        health_g = registry.gauge(
            "fleet_instance_health", "observed health: healthy=0 "
            "suspect=1 dead=2", ("instance",))
        from .base import HEALTH_STATES
        for inst in self.instances:
            inst.sim.scheduler.publish_metrics(
                registry, instance=str(inst.id))
            health_g.labels(instance=inst.id).set(
                HEALTH_STATES.index(inst.health))
            registry.gauge(
                "cluster_pending_deliveries",
                "routed-but-undelivered requests", ("instance",)) \
                .labels(instance=inst.id).set(len(inst.pending))
        self.router.publish_metrics(registry, side="arrival")
        self.decode_router.publish_metrics(registry, side="decode")
        if self.autoscaler is not None:
            self.autoscaler.publish_metrics(registry)

        def c(name, help, value):
            registry.counter(name, help).unlabeled.inc_to(value)

        c("cluster_routed_total", "requests routed", len(self.route_of))
        c("cluster_migrations_total", "prefill->decode KV migrations",
          self.n_migrations)
        c("cluster_double_routes_total", "conservation violations "
          "(must stay 0)", self.double_routes)
        c("cluster_recovered_total", "requests requeued off dead "
          "instances", self.n_recovered)
        c("cluster_aborted_total", "terminal aborts",
          len(self.aborted_rids))
        c("cluster_shed_reroutes_total", "rung-4 sheds handed to the "
          "retry tier", self.n_shed_reroutes)
        c("cluster_shed_rescued_total", "retried sheds delivered to a "
          "feasible peer", self.n_shed_rescued)
        c("cluster_shed_terminal_total", "sheds with no feasible peer",
          self.n_shed_terminal)
        c("cluster_dup_deliveries_total", "duplicates suppressed by "
          "idempotency", sum(i.n_dup_deliveries for i in self.instances))
        c("cluster_fenced_completions_total", "fenced-host completions "
          "counted, never delivered", self.n_fenced_completions)
        if self.transport is not None:
            tfam = registry.counter("transport_messages_total",
                                    "lossy-transport events by kind",
                                    ("kind",))
            tfam.labels(kind="dropped").inc_to(self.transport.n_dropped)
            tfam.labels(kind="duplicated").inc_to(
                self.transport.n_duplicated)
            tfam.labels(kind="delayed").inc_to(self.transport.n_delayed)
            tfam.labels(kind="retransmits").inc_to(
                self.transport.n_retransmits)
            tfam.labels(kind="partition_lost").inc_to(
                self.transport.n_partition_lost)
            tfam.labels(kind="partition_held").inc_to(
                self.transport.n_partition_held)
        if self.detector is not None:
            self.detector.publish_metrics(registry, self.instances)
        if self.hedge is not None:
            self.hedge.publish_metrics(registry)

    # ------------------------------------------------------------------ #
    def run(self, requests: Sequence[Request],
            max_iters: int = 2_000_000,
            sample_every: Optional[float] = None,
            on_sample: Optional[Callable[[float, "ClusterSim"], None]]
            = None) -> ClusterResult:
        reqs = sorted(requests, key=lambda r: r.arrival)
        n = len(reqs)
        i_arr = 0
        migrations: List[Tuple[float, int, Request, bool]] = []
        self._migrations = migrations    # _deliver/_route push retransmits
        total_iters = 0
        # time-series hook: fire on_sample every sample_every units of
        # event time (state as of the last event before each boundary)
        next_sample = sample_every if sample_every else _INF

        while total_iters < max_iters:
            t_arr = reqs[i_arr].arrival if i_arr < n else _INF
            t_mig = migrations[0][0] if migrations else _INF
            t_inst = _INF
            nxt: Optional[ClusterInstance] = None
            for inst in self.instances:
                ti = inst.next_time()
                if ti < t_inst:
                    t_inst, nxt = ti, inst
            t_evt = min(t_arr, t_mig, t_inst)
            t_det = _INF
            if self.detector is not None:
                # detection deadlines join the event horizon only while
                # work remains — a silent carcass holding requests must
                # be declared even when nothing else advances the clock
                work_left = (i_arr < n or bool(migrations)
                             or any(not i.idle() for i in self.instances))
                if work_left:
                    t_det = self.detector.next_deadline(self.instances)
            t_now = min(t_evt, t_det)
            if t_now == _INF:
                break
            if on_sample is not None:
                while t_now >= next_sample - _EPS:
                    on_sample(next_sample, self)
                    next_sample += sample_every
            if self._cancel_at:
                self._apply_due_cancels(t_now)
            if self.faults is not None:
                for inst in self.instances:
                    inst.update_health(t_now)
                if self.faults.poll(t_now, self.instances):
                    # faults change health/eligibility: reclaim any dead
                    # instance's work and re-evaluate the event horizon
                    self._reclaim_dead(t_now, migrations)
                    continue
            if self.detector is not None:
                # beat before observing: a live instance that reached
                # this wake is, by construction, still heartbeating
                for inst in self.instances:
                    inst.maybe_beat(self.transport, t_now,
                                    self.detector.cfg.beat_every)
                newly_dead = self.detector.observe(t_now, self.instances)
                if newly_dead:
                    self._reclaim_dead(t_now, migrations)
                self._hedge_tick(t_now, migrations)
                if newly_dead:
                    continue
                if t_det < t_evt:
                    continue             # pure detection wake: re-horizon
            if t_arr <= t_mig and t_arr <= t_inst:
                req = reqs[i_arr]
                i_arr += 1
                self._autoscale(t_arr)
                self._route(req, t_arr, as_gt=False)
                continue
            if t_mig <= t_inst:
                ready, _, req, as_gt = heapq.heappop(migrations)
                self._route(req, ready, as_gt=as_gt, rerouted=True)
                continue
            assert nxt is not None
            if nxt.frozen_until > nxt.sim.t:
                # thaw: the freeze consumed this wall-clock interval
                nxt.sim.advance_to(nxt.frozen_until)
            nxt.deliver_due()
            t_before = nxt.sim.t
            status = nxt.sim.step()
            sched = nxt.sim.scheduler
            if sched.infeasible_shed:
                # rung 4: a squeeze made these permanently inadmissible
                # on *this* instance. With the shed-retry tier on, a peer
                # whose total KVC can still fund the demand gets a
                # router-level re-route (bounded retries + backoff);
                # terminal shed only when no live peer can ever fit
                for r in sched.infeasible_shed:
                    demand = r.prompt_len + max(r.padded_rl,
                                                r.predicted_rl, 1)
                    if (self.recovery.shed_retry
                            and any(i.alive
                                    and i.scheduler.fits_ever(demand)
                                    for i in self.instances)):
                        self._shed_rids.add(r.rid)
                        self.n_shed_reroutes += 1
                        self._recover(r, nxt.sim.t, migrations)
                    else:
                        if self.recovery.shed_retry:
                            self.n_shed_terminal += 1
                        r.set_state(State.ABORTED, nxt.sim.t)
                        self.aborted_rids.append(r.rid)
                sched.infeasible_shed.clear()
            if status == SimInstance.STEPPED:
                total_iters += 1
                nxt.stalled = False
                if nxt.slow_factor > 1 and t_before < nxt.slow_until:
                    # straggler: dilate the iteration it just committed
                    nxt.sim.t += (nxt.slow_factor - 1) \
                        * (nxt.sim.t - t_before)
                if nxt.role == "prefill":
                    self._collect_migrations(nxt, migrations)
                if self.autoscaler is not None:
                    nxt.harvest_completions(self.autoscaler)
                self._hedge_tick(t_now, migrations)
            else:
                # empty plan while work remains: nothing placeable until a
                # new delivery arrives (mirrors the single-engine loop's
                # jump-to-next-arrival; here the next event wakes it)
                nxt.stalled = True

        # partition fences: a fenced host's post-fence completions are
        # counted, never credited — the clone that re-entered service
        # elsewhere is the one completion the client sees
        completed_by: Dict[int, List[int]] = {}
        for inst in self.instances:
            kept = []
            for r in inst.sim.scheduler.completed:
                if (inst.id, r.rid) in self._fenced:
                    self.n_fenced_completions += 1
                    if self.hedge is not None:
                        self.hedge.n_fenced += 1
                    continue
                kept.append(r.rid)
            completed_by[inst.id] = kept
        # re-apply winner snapshots: the client record must show what the
        # winning copy produced, whatever a fenced zombie wrote meanwhile
        for orig, src in self._swap_result.values():
            self._apply_snapshot(orig, src)
        wall = max((inst.sim.t for inst in self.instances), default=0.0)
        return ClusterResult(
            name=self.name, requests=list(reqs),
            per_instance=[inst.sim.result([]) for inst in self.instances],
            wall_time=wall, n_routed=len(self.route_of),
            n_migrations=self.n_migrations,
            double_routes=self.double_routes,
            route_of=dict(self.route_of), completed_by=completed_by,
            scale_events=list(self.scale_events),
            aborted=list(self.aborted_rids),
            n_recovered=self.n_recovered,
            fault_log=list(self.faults.log) if self.faults else [],
            n_shed_reroutes=self.n_shed_reroutes,
            n_shed_rescued=self.n_shed_rescued,
            n_shed_terminal=self.n_shed_terminal,
            n_dup_deliveries=sum(i.n_dup_deliveries
                                 for i in self.instances),
            n_false_suspects=(self.detector.n_reinstated
                              if self.detector else 0),
            detector_transitions=(list(self.detector.transitions)
                                  if self.detector else []),
            n_fenced_completions=self.n_fenced_completions,
            n_hedges_fired=(self.hedge.n_fired if self.hedge else 0),
            n_hedges_won=(self.hedge.n_won if self.hedge else 0),
            n_hedges_cancelled=(self.hedge.n_cancelled
                                if self.hedge else 0),
            transport_stats=({"dropped": self.transport.n_dropped,
                              "duplicated": self.transport.n_duplicated,
                              "delayed": self.transport.n_delayed,
                              "retransmits": self.transport.n_retransmits,
                              "partition_lost":
                                  self.transport.n_partition_lost,
                              "partition_held":
                                  self.transport.n_partition_held}
                             if self.transport else {}))
