"""Deterministic chaos layer for the cluster backends.

``FaultInjector`` fires scripted (or seeded-probabilistic) instance
faults — crash, freeze, straggler slowdown, live KVC capacity squeeze —
and corrupts KV-migration payloads in flight.  Both backends poll it
from their event loops:
``EngineFleet`` (real engines) and ``ClusterSim`` (discrete-event model)
share the same injector, so a fault schedule reproduces bit-for-bit on
either.

``RecoveryConfig`` bounds what the fleet does about it: per-request
retry budget with exponential backoff, a hard deadline multiple past
which requests are aborted, and admission shedding when projected
completion would blow the SLO anyway.

``check_fleet_invariants`` is the conservation audit run after every
chaos battery: every submitted request reaches exactly one terminal
state (completed | aborted | shed), and no live engine leaks KVC
blocks, batch slots, or ring/drain state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .base import DEAD, HEALTHY, SUSPECT

FAULT_KINDS = ("kill", "freeze", "slow", "corrupt_kv", "squeeze",
               "drop", "dup", "delay", "part")

#: kinds that perturb the message transport, not an instance's health
TRANSPORT_KINDS = ("drop", "dup", "delay", "part")

#: kinds that set (or, detected, eventually cause) a health transition —
#: two different ones on the same instance at the same tick contradict
HEALTH_KINDS = ("kill", "freeze", "slow")


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One scripted fault. ``target`` is an instance id (-1 = injector
    picks among the alive; for transport kinds, every link);
    ``duration``/``factor`` only apply to freeze/slow (for transport
    kinds ``duration`` is the fault-window length); ``count`` only to
    corrupt_kv (number of payloads); ``frac`` only to squeeze (fraction
    of KVC capacity removed) and drop/dup (per-message probability);
    ``delay`` only to the delay kind (added latency); ``peer`` only to
    ``part`` (the instance standing in for the majority side of the
    cut — ``target`` is the partitioned-away minority)."""
    t: float
    kind: str = "kill"
    target: int = -1
    duration: float = 8.0
    factor: int = 2
    count: int = 1
    frac: float = 0.5
    delay: float = 2.0
    peer: int = -1

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind
        if self.kind in ("squeeze", "drop", "dup"):
            assert 0.0 < self.frac <= 1.0, self.frac
        if self.kind == "part":
            assert self.target >= 0 and self.peer >= 0, \
                "part needs explicit a|b instance ids"
            assert self.target != self.peer, "self-partition"
            assert self.duration > 0, self.duration


@dataclass
class RecoveryConfig:
    """Fleet-side policy for surviving injected (or real) faults.

    ``jitter`` spreads redelivery backoff to avoid synchronized retry
    herds after a mass reclaim: each delay is stretched by up to
    ``jitter`` (fractionally), keyed on a CRC of (rid, attempt,
    ``jitter_seed``) — fully deterministic under a fixed seed, and the
    default ``jitter=0.0`` reproduces the legacy schedule bit-for-bit."""
    max_retries: int = 3          # recovery attempts per request
    backoff_base: float = 2.0     # redelivery delay = base * 2**attempt
    deadline_factor: float = 0.0  # abort past submit + k*(deadline-submit);
                                  # 0 disables the watchdog
    shed: bool = False            # reject admissions projected to miss SLO
    shed_headroom: float = 1.0    # safety multiplier on the projection
    jitter: float = 0.0           # max fractional backoff stretch
    jitter_seed: int = 0          # decorrelates fleets sharing a schedule
    shed_retry: bool = False      # fleet-level second chance for rung-4
                                  # kvc-infeasible sheds: re-route to a
                                  # peer whose total KVC can fund the
                                  # frozen demand (bounded by max_retries);
                                  # terminal shed only when no live peer
                                  # can ever fit the request


def backoff_delay(rc: RecoveryConfig, rid: int, attempt: int) -> float:
    """Exponential backoff with deterministic seeded jitter (shared by
    both backends so a recovery schedule reproduces bit-for-bit)."""
    import zlib
    delay = rc.backoff_base * (2.0 ** attempt)
    if rc.jitter:
        h = zlib.crc32(f"{rid}:{attempt}:{rc.jitter_seed}".encode())
        delay *= 1.0 + rc.jitter * (h / 0xFFFFFFFF)
    return delay


class InvariantViolation(AssertionError):
    """A conservation / leak invariant failed after a chaos run."""


class FaultInjector:
    """Schedule-driven + seeded-probabilistic fault source.

    ``poll(t, instances)`` fires every scheduled event with ``ev.t <= t``
    and then rolls per-alive-instance probabilistic faults; it returns
    the list of events fired this call (empty most of the time).
    ``corrupt_payload`` is called by the migration path on every KV
    payload and flips one tensor element when a corruption is pending.

    Scheduled kills always fire; probabilistic kills never reduce the
    fleet below ``min_alive``.

    **Declared vs detected.** By default the injector *declares* health
    (kill writes ``DEAD``, freeze/slow write ``SUSPECT``) — the legacy
    oracle mode. When a backend attaches a failure detector it flips
    ``detected`` on and binds ``transport``: a kill then only sets
    ``crashed`` (the instance falls silent) and a freeze only sets
    ``frozen_until`` — the *observed* health is owned by the detector,
    which must notice the missing heartbeats. Transport kinds
    (drop/dup/delay) open fault windows on the bound transport and
    require one.
    """

    def __init__(self, schedule: Sequence[FaultEvent] = (),
                 p_kill: float = 0.0, p_freeze: float = 0.0,
                 p_corrupt: float = 0.0, freeze_duration: float = 8.0,
                 seed: int = 0, min_alive: int = 1):
        self.schedule = sorted(schedule)
        self._idx = 0
        self.p_kill = p_kill
        self.p_freeze = p_freeze
        self.p_corrupt = p_corrupt
        self.freeze_duration = freeze_duration
        self.min_alive = min_alive
        self.rng = np.random.default_rng(seed)
        self._pending_corrupt = 0     # payloads left to corrupt
        self.n_corrupted = 0
        self.detected = False         # failure-detector mode (see class doc)
        self.transport = None         # bound by the backend (drop/dup/delay)
        self.log: List[Tuple[float, str, int]] = []

    # ------------------------------------------------------------------ #
    def poll(self, t: float, instances: Sequence) -> List[FaultEvent]:
        fired: List[FaultEvent] = []
        while self._idx < len(self.schedule) and self.schedule[self._idx].t <= t:
            ev = self.schedule[self._idx]
            self._idx += 1
            if self._apply(ev, t, instances, forced=True):
                fired.append(ev)
        if self.p_kill or self.p_freeze or self.p_corrupt:
            for inst in instances:
                if not inst.alive:
                    continue
                if self.p_kill and self.rng.random() < self.p_kill:
                    ev = FaultEvent(t=t, kind="kill", target=inst.id)
                    if self._apply(ev, t, instances, forced=False):
                        fired.append(ev)
                elif self.p_freeze and self.rng.random() < self.p_freeze:
                    ev = FaultEvent(t=t, kind="freeze", target=inst.id,
                                    duration=self.freeze_duration)
                    if self._apply(ev, t, instances, forced=False):
                        fired.append(ev)
            if self.p_corrupt and self.rng.random() < self.p_corrupt:
                self._pending_corrupt += 1
                self.log.append((t, "corrupt_kv", -1))
        return fired

    def _apply(self, ev: FaultEvent, t: float, instances: Sequence,
               forced: bool) -> bool:
        if ev.kind == "corrupt_kv":
            self._pending_corrupt += ev.count
            self.log.append((t, ev.kind, ev.target))
            return True
        if ev.kind in TRANSPORT_KINDS:
            assert self.transport is not None, \
                f"{ev.kind} fault needs a transport-backed fleet " \
                f"(detector mode) — plain fleets have no message layer"
            self.transport.add_fault(ev)
            self.log.append((t, ev.kind, ev.target))
            return True
        inst = self._resolve(ev.target, instances)
        if inst is None:
            return False
        if ev.kind == "kill":
            alive = sum(1 for i in instances
                        if i.alive and not getattr(i, "crashed", False))
            if not forced and alive <= self.min_alive:
                return False            # probabilistic kills spare the last
            if self.detected:
                inst.crashed = True     # falls silent; detection follows
            else:
                inst.health = DEAD
        elif ev.kind == "freeze":
            if not self.detected:
                inst.health = SUSPECT
            inst.frozen_until = max(inst.frozen_until, t + ev.duration)
        elif ev.kind == "slow":
            if not self.detected:
                inst.health = SUSPECT
            inst.slow_until = max(inst.slow_until, t + ev.duration)
            inst.slow_factor = max(2, int(ev.factor))
        elif ev.kind == "squeeze":
            # live capacity reduction: the instance sheds `frac` of its
            # KVC (free blocks immediately, held blocks as they free) and
            # must degrade through the ladder, not crash on allocation
            inst.squeeze_kvc(ev.frac)
        self.log.append((t, ev.kind, inst.id))
        return True

    def _resolve(self, target: int, instances: Sequence):
        if target >= 0:
            for i in instances:
                if i.id == target:
                    return i if i.alive and not getattr(i, "crashed", False) \
                        else None
            return None
        cands = [i for i in instances if i.health == HEALTHY
                 and not getattr(i, "crashed", False)]
        if not cands:
            return None
        return cands[int(self.rng.integers(len(cands)))]

    # ------------------------------------------------------------------ #
    def corrupt_payload(self, payload: dict) -> dict:
        """Bit-flip one element of the first KV tensor when a corruption
        is pending. The checksum in the payload is left as exported, so
        the receiver's verify step rejects it."""
        if self._pending_corrupt <= 0 or payload.get("kv") is None:
            return payload
        self._pending_corrupt -= 1
        self.n_corrupted += 1
        kv = {kind: {n: np.array(a) for n, a in kv_part.items()}
              for kind, kv_part in payload["kv"].items()}
        kind = sorted(kv)[0]
        arr = kv[kind]["k"]
        arr.flat[0] = arr.flat[0] + 1
        out = dict(payload)
        out["kv"] = kv
        return out


# ---------------------------------------------------------------------- #
# chaos spec parsing — "kill@25:1,freeze@40:2/20,slow@10:-1/30x3"
# ---------------------------------------------------------------------- #
class ChaosSpecError(ValueError):
    """A malformed ``--chaos`` clause, named precisely. A typo in a chaos
    schedule must fail loudly at parse time — not half-parse into a no-op
    (or wrong-target) fault that silently weakens the chaos run."""


def _chaos_num(text: str, what: str, clause: str, conv):
    try:
        return conv(text)
    except ValueError:
        raise ChaosSpecError(
            f"bad {what} {text!r} in chaos clause {clause!r}") from None


def parse_chaos_spec(spec: str,
                     n_instances: Optional[int] = None) -> List[FaultEvent]:
    """Parse ``kind@t[:target][/duration][xfactor]`` items, comma-separated.

    Examples::

        kill@25            kill some healthy instance at t=25
        kill@25:1          kill instance 1 at t=25
        freeze@40:2/20     freeze instance 2 for 20s at t=40
        slow@10:0/30x3     slow instance 0 by 3x for 30s at t=10
        corrupt@15         corrupt the next KV migration after t=15
        squeeze@30:1/0.5   drop half of instance 1's KVC capacity at t=30
        drop@10:1/0.6      drop messages on instance 1's link w.p. 0.6
        dup@12:2/0.5       duplicate messages on instance 2's link w.p. 0.5
        delay@8:0/2.5      delay instance 0's messages by 2.5
        part@6:2|0/12      partition instance 2 from instance 0's side
                           (and the control plane) for 12 time units

    For ``squeeze`` and the transport kinds drop/dup/delay the ``/``
    clause is *not* a duration: it is the capacity fraction removed
    (squeeze, permanent), the per-message probability (drop/dup), or
    the added latency (delay). For ``part`` it *is* the partition
    duration (required positive). Transport fault windows last the
    ``FaultEvent.duration`` default (8 time units) from their fire time
    and need a detector/transport-backed fleet. Malformed input raises
    :class:`ChaosSpecError` naming the offending clause and field:
    unknown kinds, a ``part`` self-partition (``a|a``), a non-positive
    partition duration, a target outside ``range(n_instances)`` (when
    the caller passes the fleet size), and two contradictory health
    faults (kill/freeze/slow) aimed at the same instance at the same
    tick — injector order must not decide which one silently wins.
    """
    events: List[FaultEvent] = []
    clauses: List[str] = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        raw_kind, sep, rest = item.partition("@")
        if not sep or not rest:
            raise ChaosSpecError(
                f"chaos clause {item!r} is not of the form "
                f"'kind@t[:target][/duration][xfactor]'")
        kind = {"corrupt": "corrupt_kv"}.get(raw_kind, raw_kind)
        if kind not in FAULT_KINDS:
            raise ChaosSpecError(
                f"unknown fault kind {raw_kind!r} in chaos clause "
                f"{item!r} (valid: kill, freeze, slow, corrupt, squeeze, "
                f"drop, dup, delay, part)")
        factor = 2
        if "x" in rest and kind != "part":
            rest, _, f = rest.rpartition("x")
            factor = _chaos_num(f, "slowdown factor", item, int)
        duration, frac, delay = 8.0, 0.5, 2.0
        if "/" in rest:
            rest, _, d = rest.partition("/")
            if kind == "squeeze" or kind in ("drop", "dup"):
                what = "capacity fraction" if kind == "squeeze" \
                    else "message probability"
                frac = _chaos_num(d, what, item, float)
                if not 0.0 < frac <= 1.0:
                    raise ChaosSpecError(
                        f"{what} {frac} outside (0, 1] in "
                        f"chaos clause {item!r}")
            elif kind == "delay":
                delay = _chaos_num(d, "delay", item, float)
                if delay <= 0:
                    raise ChaosSpecError(
                        f"delay {delay} must be positive in "
                        f"chaos clause {item!r}")
            else:
                duration = _chaos_num(d, "duration", item, float)
                if kind == "part" and duration <= 0:
                    raise ChaosSpecError(
                        f"partition duration {duration:g} must be "
                        f"positive in chaos clause {item!r}")
        target, peer = -1, -1
        if ":" in rest:
            rest, _, tg = rest.partition(":")
            if kind == "part":
                a_txt, bar, b_txt = tg.partition("|")
                if not bar:
                    raise ChaosSpecError(
                        f"part clause {item!r} needs an 'a|b' target "
                        f"(partitioned instance | majority-side peer)")
                target = _chaos_num(a_txt, "partitioned instance", item,
                                    int)
                peer = _chaos_num(b_txt, "partition peer", item, int)
                if target == peer:
                    raise ChaosSpecError(
                        f"self-partition {target}|{peer} in chaos "
                        f"clause {item!r}: an instance cannot be cut "
                        f"off from itself")
            else:
                target = _chaos_num(tg, "target instance", item, int)
        elif kind == "part":
            raise ChaosSpecError(
                f"part clause {item!r} needs an ':a|b' target "
                f"(partitioned instance | majority-side peer)")
        if kind == "part" and n_instances is not None:
            for label, iid in (("partitioned instance", target),
                               ("partition peer", peer)):
                if not 0 <= iid < n_instances:
                    raise ChaosSpecError(
                        f"unknown instance {iid} as {label} in chaos "
                        f"clause {item!r} (fleet has instances "
                        f"0..{n_instances - 1})")
        t = _chaos_num(rest, "fire time", item, float)
        events.append(FaultEvent(t=t, kind=kind, target=target,
                                 duration=duration, factor=factor,
                                 frac=frac, delay=delay, peer=peer))
        clauses.append(item)
    # contradictory health faults on the same instance at the same tick:
    # applying them in injector order would silently pick a winner
    seen: dict = {}
    for ev, clause in zip(events, clauses):
        if ev.kind not in HEALTH_KINDS or ev.target < 0:
            continue
        key = (ev.t, ev.target)
        prev = seen.get(key)
        if prev is not None and prev[0].kind != ev.kind:
            raise ChaosSpecError(
                f"contradictory chaos clauses {prev[1]!r} and {clause!r}: "
                f"both target instance {ev.target} at t={ev.t:g} with "
                f"conflicting health faults "
                f"({prev[0].kind} vs {ev.kind})")
        seen[key] = (ev, clause)
    return events


# ---------------------------------------------------------------------- #
# conservation / leak audit
# ---------------------------------------------------------------------- #
def check_fleet_invariants(fleet, strict: bool = True) -> dict:
    """Audit an ``EngineFleet`` after it drained: exactly-once terminal
    states over everything submitted, zero resource leaks on every live
    engine, and — with at-least-once delivery in play — no ghost
    registrations (one request live on two engines at once) and no
    duplicate terminal transitions (a second completion writer is
    suppressed first-writer-wins and *counted*; any non-zero count means
    the delivery-dedup boundary leaked a duplicate through). Returns a
    report dict; raises ``InvariantViolation`` listing every failure
    when ``strict``."""
    problems: List[str] = []
    n_completed = n_aborted = n_shed = 0
    for g in fleet.submitted:
        status = getattr(g, "status", None)
        if status == "completed" or (status is None and g.t_done is not None):
            n_completed += 1
        elif status == "aborted":
            n_aborted += 1
        elif status == "shed":
            n_shed += 1
        else:
            problems.append(f"request non-terminal: status={status!r} "
                            f"t_done={g.t_done} prompt_len={len(g.prompt)}")
    if fleet.double_routes:
        problems.append(f"double routes: {fleet.double_routes}")
    if getattr(fleet, "_redeliver", None):
        problems.append(f"undelivered recoveries: {len(fleet._redeliver)}")
    transport = getattr(fleet, "transport", None)
    if transport is not None and transport.pending():
        problems.append(
            f"undelivered transport messages: {transport.pending()}")
    # ghost/duplicate registration: the same GenRequest live under two
    # engines means a duplicated delivery was accepted twice
    owners: dict = {}
    for inst in fleet.instances:
        if inst.crashed or (not inst.alive and not inst.detected):
            continue    # device state lost; zombies stay auditable
        for rid, g in inst.engine.requests.items():
            owners.setdefault(id(g), []).append(f"i{inst.id}:rid{rid}")
    n_ghosts = 0
    for tags in owners.values():
        if len(tags) > 1:
            n_ghosts += 1
            problems.append(f"ghost registration: one request live on "
                            f"{tags}")
    n_dup_completions = sum(getattr(i.engine, "n_dup_completions", 0)
                            for i in fleet.instances)
    if n_dup_completions:
        problems.append(f"duplicate terminal transitions suppressed "
                        f"first-writer-wins: {n_dup_completions} "
                        f"(delivery dedup leaked a duplicate)")
    for inst in fleet.instances:
        if inst.crashed or (not inst.alive and not inst.detected):
            continue    # crashed (or oracle-declared dead): state is by
                        # definition lost. A *detected* DEAD instance
                        # that never crashed is a zombie — it kept
                        # stepping through its partition and must hold
                        # zero leaked resources after the heal.
        eng = inst.engine
        tag = f"instance {inst.id}"
        if eng.has_work():
            problems.append(f"{tag}: engine still has work")
        try:
            eng.scheduler.kvc.check_invariants()
        except AssertionError as e:
            problems.append(f"{tag}: KVC invariant: {e}")
        if eng.scheduler.kvc.allocs:
            problems.append(f"{tag}: leaked KVC allocs "
                            f"{sorted(eng.scheduler.kvc.allocs)}")
        if eng.scheduler.kvc.swapped:
            problems.append(f"{tag}: leaked swap-ledger entries "
                            f"{sorted(eng.scheduler.kvc.swapped)}")
        if getattr(eng.scheduler, "swap_hold", None):
            problems.append(f"{tag}: leaked swap holds "
                            f"{sorted(eng.scheduler.swap_hold)}")
        if len(eng.free_slots) != eng.max_batch:
            problems.append(f"{tag}: slot leak {len(eng.free_slots)}/"
                            f"{eng.max_batch}")
        if eng.slot_of:
            problems.append(f"{tag}: slot_of not empty {sorted(eng.slot_of)}")
        for name in ("_pending_drain", "_chunk_progress", "_rec_state",
                     "_arrivals", "_pending_injects", "_pending_aborts",
                     "_host_swap", "shed_handback"):
            v = getattr(eng, name, None)
            if v:
                problems.append(f"{tag}: {name} not empty ({len(v)})")
    report = {
        "completed": n_completed, "aborted": n_aborted, "shed": n_shed,
        "submitted": len(fleet.submitted),
        "ghost_registrations": n_ghosts,
        "dup_completions": n_dup_completions,
        "dup_deliveries": sum(getattr(i.engine, "n_dup_deliveries", 0)
                              for i in fleet.instances),
        "problems": problems,
        "ok": not problems,
    }
    if strict and problems:
        raise InvariantViolation("; ".join(problems))
    return report
