"""Deterministic chaos layer for the cluster backends.

``FaultInjector`` fires scripted (or seeded-probabilistic) instance
faults — crash, freeze, straggler slowdown, live KVC capacity squeeze —
and corrupts KV-migration payloads in flight.  Both backends poll it
from their event loops:
``EngineFleet`` (real engines) and ``ClusterSim`` (discrete-event model)
share the same injector, so a fault schedule reproduces bit-for-bit on
either.

``RecoveryConfig`` bounds what the fleet does about it: per-request
retry budget with exponential backoff, a hard deadline multiple past
which requests are aborted, and admission shedding when projected
completion would blow the SLO anyway.

``check_fleet_invariants`` is the conservation audit run after every
chaos battery: every submitted request reaches exactly one terminal
state (completed | aborted | shed), and no live engine leaks KVC
blocks, batch slots, or ring/drain state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from .base import DEAD, HEALTHY, SUSPECT

FAULT_KINDS = ("kill", "freeze", "slow", "corrupt_kv", "squeeze")


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One scripted fault. ``target`` is an instance id (-1 = injector
    picks among the alive); ``duration``/``factor`` only apply to
    freeze/slow; ``count`` only to corrupt_kv (number of payloads);
    ``frac`` only to squeeze (fraction of KVC capacity removed)."""
    t: float
    kind: str = "kill"
    target: int = -1
    duration: float = 8.0
    factor: int = 2
    count: int = 1
    frac: float = 0.5

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind
        if self.kind == "squeeze":
            assert 0.0 < self.frac <= 1.0, self.frac


@dataclass
class RecoveryConfig:
    """Fleet-side policy for surviving injected (or real) faults.

    ``jitter`` spreads redelivery backoff to avoid synchronized retry
    herds after a mass reclaim: each delay is stretched by up to
    ``jitter`` (fractionally), keyed on a CRC of (rid, attempt,
    ``jitter_seed``) — fully deterministic under a fixed seed, and the
    default ``jitter=0.0`` reproduces the legacy schedule bit-for-bit."""
    max_retries: int = 3          # recovery attempts per request
    backoff_base: float = 2.0     # redelivery delay = base * 2**attempt
    deadline_factor: float = 0.0  # abort past submit + k*(deadline-submit);
                                  # 0 disables the watchdog
    shed: bool = False            # reject admissions projected to miss SLO
    shed_headroom: float = 1.0    # safety multiplier on the projection
    jitter: float = 0.0           # max fractional backoff stretch
    jitter_seed: int = 0          # decorrelates fleets sharing a schedule


def backoff_delay(rc: RecoveryConfig, rid: int, attempt: int) -> float:
    """Exponential backoff with deterministic seeded jitter (shared by
    both backends so a recovery schedule reproduces bit-for-bit)."""
    import zlib
    delay = rc.backoff_base * (2.0 ** attempt)
    if rc.jitter:
        h = zlib.crc32(f"{rid}:{attempt}:{rc.jitter_seed}".encode())
        delay *= 1.0 + rc.jitter * (h / 0xFFFFFFFF)
    return delay


class InvariantViolation(AssertionError):
    """A conservation / leak invariant failed after a chaos run."""


class FaultInjector:
    """Schedule-driven + seeded-probabilistic fault source.

    ``poll(t, instances)`` fires every scheduled event with ``ev.t <= t``
    and then rolls per-alive-instance probabilistic faults; it returns
    the list of events fired this call (empty most of the time).
    ``corrupt_payload`` is called by the migration path on every KV
    payload and flips one tensor element when a corruption is pending.

    Scheduled kills always fire; probabilistic kills never reduce the
    fleet below ``min_alive``.
    """

    def __init__(self, schedule: Sequence[FaultEvent] = (),
                 p_kill: float = 0.0, p_freeze: float = 0.0,
                 p_corrupt: float = 0.0, freeze_duration: float = 8.0,
                 seed: int = 0, min_alive: int = 1):
        self.schedule = sorted(schedule)
        self._idx = 0
        self.p_kill = p_kill
        self.p_freeze = p_freeze
        self.p_corrupt = p_corrupt
        self.freeze_duration = freeze_duration
        self.min_alive = min_alive
        self.rng = np.random.default_rng(seed)
        self._pending_corrupt = 0     # payloads left to corrupt
        self.n_corrupted = 0
        self.log: List[Tuple[float, str, int]] = []

    # ------------------------------------------------------------------ #
    def poll(self, t: float, instances: Sequence) -> List[FaultEvent]:
        fired: List[FaultEvent] = []
        while self._idx < len(self.schedule) and self.schedule[self._idx].t <= t:
            ev = self.schedule[self._idx]
            self._idx += 1
            if self._apply(ev, t, instances, forced=True):
                fired.append(ev)
        if self.p_kill or self.p_freeze or self.p_corrupt:
            for inst in instances:
                if not inst.alive:
                    continue
                if self.p_kill and self.rng.random() < self.p_kill:
                    ev = FaultEvent(t=t, kind="kill", target=inst.id)
                    if self._apply(ev, t, instances, forced=False):
                        fired.append(ev)
                elif self.p_freeze and self.rng.random() < self.p_freeze:
                    ev = FaultEvent(t=t, kind="freeze", target=inst.id,
                                    duration=self.freeze_duration)
                    if self._apply(ev, t, instances, forced=False):
                        fired.append(ev)
            if self.p_corrupt and self.rng.random() < self.p_corrupt:
                self._pending_corrupt += 1
                self.log.append((t, "corrupt_kv", -1))
        return fired

    def _apply(self, ev: FaultEvent, t: float, instances: Sequence,
               forced: bool) -> bool:
        if ev.kind == "corrupt_kv":
            self._pending_corrupt += ev.count
            self.log.append((t, ev.kind, ev.target))
            return True
        inst = self._resolve(ev.target, instances)
        if inst is None:
            return False
        if ev.kind == "kill":
            alive = sum(1 for i in instances if i.alive)
            if not forced and alive <= self.min_alive:
                return False            # probabilistic kills spare the last
            inst.health = DEAD
        elif ev.kind == "freeze":
            inst.health = SUSPECT
            inst.frozen_until = max(inst.frozen_until, t + ev.duration)
        elif ev.kind == "slow":
            inst.health = SUSPECT
            inst.slow_until = max(inst.slow_until, t + ev.duration)
            inst.slow_factor = max(2, int(ev.factor))
        elif ev.kind == "squeeze":
            # live capacity reduction: the instance sheds `frac` of its
            # KVC (free blocks immediately, held blocks as they free) and
            # must degrade through the ladder, not crash on allocation
            inst.squeeze_kvc(ev.frac)
        self.log.append((t, ev.kind, inst.id))
        return True

    def _resolve(self, target: int, instances: Sequence):
        if target >= 0:
            for i in instances:
                if i.id == target:
                    return i if i.alive else None
            return None
        cands = [i for i in instances if i.health == HEALTHY]
        if not cands:
            return None
        return cands[int(self.rng.integers(len(cands)))]

    # ------------------------------------------------------------------ #
    def corrupt_payload(self, payload: dict) -> dict:
        """Bit-flip one element of the first KV tensor when a corruption
        is pending. The checksum in the payload is left as exported, so
        the receiver's verify step rejects it."""
        if self._pending_corrupt <= 0 or payload.get("kv") is None:
            return payload
        self._pending_corrupt -= 1
        self.n_corrupted += 1
        kv = {kind: {n: np.array(a) for n, a in kv_part.items()}
              for kind, kv_part in payload["kv"].items()}
        kind = sorted(kv)[0]
        arr = kv[kind]["k"]
        arr.flat[0] = arr.flat[0] + 1
        out = dict(payload)
        out["kv"] = kv
        return out


# ---------------------------------------------------------------------- #
# chaos spec parsing — "kill@25:1,freeze@40:2/20,slow@10:-1/30x3"
# ---------------------------------------------------------------------- #
class ChaosSpecError(ValueError):
    """A malformed ``--chaos`` clause, named precisely. A typo in a chaos
    schedule must fail loudly at parse time — not half-parse into a no-op
    (or wrong-target) fault that silently weakens the chaos run."""


def _chaos_num(text: str, what: str, clause: str, conv):
    try:
        return conv(text)
    except ValueError:
        raise ChaosSpecError(
            f"bad {what} {text!r} in chaos clause {clause!r}") from None


def parse_chaos_spec(spec: str) -> List[FaultEvent]:
    """Parse ``kind@t[:target][/duration][xfactor]`` items, comma-separated.

    Examples::

        kill@25            kill some healthy instance at t=25
        kill@25:1          kill instance 1 at t=25
        freeze@40:2/20     freeze instance 2 for 20s at t=40
        slow@10:0/30x3     slow instance 0 by 3x for 30s at t=10
        corrupt@15         corrupt the next KV migration after t=15
        squeeze@30:1/0.5   drop half of instance 1's KVC capacity at t=30

    For ``squeeze`` the ``/`` clause is the capacity *fraction* removed
    (default 0.5), not a duration — a squeeze is permanent. Malformed
    input raises :class:`ChaosSpecError` naming the offending clause and
    field.
    """
    events: List[FaultEvent] = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        raw_kind, sep, rest = item.partition("@")
        if not sep or not rest:
            raise ChaosSpecError(
                f"chaos clause {item!r} is not of the form "
                f"'kind@t[:target][/duration][xfactor]'")
        kind = {"corrupt": "corrupt_kv"}.get(raw_kind, raw_kind)
        if kind not in FAULT_KINDS:
            raise ChaosSpecError(
                f"unknown fault kind {raw_kind!r} in chaos clause "
                f"{item!r} (valid: kill, freeze, slow, corrupt, squeeze)")
        factor = 2
        if "x" in rest:
            rest, _, f = rest.rpartition("x")
            factor = _chaos_num(f, "slowdown factor", item, int)
        duration, frac = 8.0, 0.5
        if "/" in rest:
            rest, _, d = rest.partition("/")
            if kind == "squeeze":
                frac = _chaos_num(d, "capacity fraction", item, float)
                if not 0.0 < frac <= 1.0:
                    raise ChaosSpecError(
                        f"squeeze fraction {frac} outside (0, 1] in "
                        f"chaos clause {item!r}")
            else:
                duration = _chaos_num(d, "duration", item, float)
        target = -1
        if ":" in rest:
            rest, _, tg = rest.partition(":")
            target = _chaos_num(tg, "target instance", item, int)
        t = _chaos_num(rest, "fire time", item, float)
        events.append(FaultEvent(t=t, kind=kind, target=target,
                                 duration=duration, factor=factor,
                                 frac=frac))
    return events


# ---------------------------------------------------------------------- #
# conservation / leak audit
# ---------------------------------------------------------------------- #
def check_fleet_invariants(fleet, strict: bool = True) -> dict:
    """Audit an ``EngineFleet`` after it drained: exactly-once terminal
    states over everything submitted, and zero resource leaks on every
    live engine. Returns a report dict; raises ``InvariantViolation``
    listing every failure when ``strict``."""
    problems: List[str] = []
    n_completed = n_aborted = n_shed = 0
    for g in fleet.submitted:
        status = getattr(g, "status", None)
        if status == "completed" or (status is None and g.t_done is not None):
            n_completed += 1
        elif status == "aborted":
            n_aborted += 1
        elif status == "shed":
            n_shed += 1
        else:
            problems.append(f"request non-terminal: status={status!r} "
                            f"t_done={g.t_done} prompt_len={len(g.prompt)}")
    if fleet.double_routes:
        problems.append(f"double routes: {fleet.double_routes}")
    if getattr(fleet, "_redeliver", None):
        problems.append(f"undelivered recoveries: {len(fleet._redeliver)}")
    for inst in fleet.instances:
        if not inst.alive:
            continue                   # dead state is by definition lost
        eng = inst.engine
        tag = f"instance {inst.id}"
        if eng.has_work():
            problems.append(f"{tag}: engine still has work")
        try:
            eng.scheduler.kvc.check_invariants()
        except AssertionError as e:
            problems.append(f"{tag}: KVC invariant: {e}")
        if eng.scheduler.kvc.allocs:
            problems.append(f"{tag}: leaked KVC allocs "
                            f"{sorted(eng.scheduler.kvc.allocs)}")
        if eng.scheduler.kvc.swapped:
            problems.append(f"{tag}: leaked swap-ledger entries "
                            f"{sorted(eng.scheduler.kvc.swapped)}")
        if getattr(eng.scheduler, "swap_hold", None):
            problems.append(f"{tag}: leaked swap holds "
                            f"{sorted(eng.scheduler.swap_hold)}")
        if len(eng.free_slots) != eng.max_batch:
            problems.append(f"{tag}: slot leak {len(eng.free_slots)}/"
                            f"{eng.max_batch}")
        if eng.slot_of:
            problems.append(f"{tag}: slot_of not empty {sorted(eng.slot_of)}")
        for name in ("_pending_drain", "_chunk_progress", "_rec_state",
                     "_arrivals", "_pending_injects", "_pending_aborts",
                     "_host_swap"):
            v = getattr(eng, name, None)
            if v:
                problems.append(f"{tag}: {name} not empty ({len(v)})")
    report = {
        "completed": n_completed, "aborted": n_aborted, "shed": n_shed,
        "submitted": len(fleet.submitted), "problems": problems,
        "ok": not problems,
    }
    if strict and problems:
        raise InvariantViolation("; ".join(problems))
    return report
