"""Goodput-driven autoscaling with hysteresis.

The autoscaler watches SLO attainment (fraction of recently completed
requests that met their deadline — the per-request view of the paper's
goodput metric) and decides when to add an instance or drain one. It is
pure decision logic: the cluster backends (``ClusterSim`` /
``EngineFleet``) feed it observations and execute its actions, so the same
policy — and the same hysteresis tests — cover both.

Flap protection is layered (a bare threshold controller oscillates on any
step load change: attainment dips → scale up → attainment recovers → scale
down → dips again):

  * dual thresholds  — scale up below ``slo_low``, consider scaling down
    only above ``slo_high`` (the dead band between them absorbs noise);
  * patience         — a breach must persist for ``patience`` consecutive
    evaluations before acting;
  * cooldown         — after any action, hold for ``cooldown`` time units
    (new capacity needs time to show up in the attainment window);
  * load guard       — scale down only when the survivors could absorb the
    drained instance's load: mean allocated-KVC fraction projected onto
    n-1 instances must stay under ``down_load_cap``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass
class AutoscaleConfig:
    slo_low: float = 0.85          # scale up when attainment drops below
    slo_high: float = 0.98         # scale down only above (dead band)
    window: int = 32               # completions per attainment estimate
    min_window: int = 8            # don't act on fewer observations
    patience: int = 2              # consecutive breaches before acting
    cooldown: float = 50.0         # time units between actions
    down_load_cap: float = 0.70    # projected per-survivor load ceiling
    min_instances: int = 1
    max_instances: int = 8


class GoodputAutoscaler:
    """Feed it completions (``record``) and poll it (``decide``)."""

    def __init__(self, cfg: Optional[AutoscaleConfig] = None):
        self.cfg = cfg or AutoscaleConfig()
        self._met: List[bool] = []          # rolling completion window
        self._up_streak = 0
        self._down_streak = 0
        self._last_action_t = -float("inf")
        self.events: List[Tuple[float, int]] = []   # (t, +1/-1) log
        # registry-sourced mode (bind_registry): completions publish into
        # obs counters and attainment is reconstructed from that series
        self._c_met = None
        self._c_miss = None
        self._series: List[Tuple[float, float]] = []  # (total, met) reads
        self._baseline: Tuple[float, float] = (0.0, 0.0)

    # ------------------------------------------------------------------ #
    def bind_registry(self, registry) -> None:
        """Make a ``repro.obs`` registry the autoscaler's input signal:
        every ``record`` publishes into
        ``autoscaler_completions_total{met=...}`` and the attainment
        window is reconstructed from that registry time series —
        cumulative-counter deltas over the last ``window`` completions,
        floored at the invalidation baseline — instead of a private
        rolling list. Decisions are identical to the unbound mode; the
        metrics plane simply becomes the source of truth, so the same
        series dashboards plot is the one the controller acts on."""
        fam = registry.counter("autoscaler_completions_total",
                               "completions observed by the autoscaler",
                               ("met",))
        self._c_met = fam.labels(met="true")
        self._c_miss = fam.labels(met="false")
        self._series = []
        self._baseline = (self._c_met.value + self._c_miss.value,
                          self._c_met.value)
        self._met.clear()

    def record(self, met_slo: bool) -> None:
        if self._c_met is not None:
            (self._c_met if met_slo else self._c_miss).inc()
            tot = self._c_met.value + self._c_miss.value
            self._series.append((tot, self._c_met.value))
            if len(self._series) > self.cfg.window + 1:
                del self._series[:len(self._series) - self.cfg.window - 1]
            return
        self._met.append(met_slo)
        if len(self._met) > self.cfg.window:
            del self._met[:len(self._met) - self.cfg.window]

    def _window_bounds(self) -> Optional[Tuple[float, float, float, float]]:
        """Registry mode: (then_total, then_met, now_total, now_met) for
        the active window — the last ``window`` readings past the
        baseline."""
        now = self._series[-1] if self._series else self._baseline
        if now[0] <= self._baseline[0]:
            return None
        then = self._series[-1 - self.cfg.window] \
            if len(self._series) > self.cfg.window else self._baseline
        if then[0] < self._baseline[0]:
            then = self._baseline
        return then[0], then[1], now[0], now[1]

    @property
    def window_len(self) -> int:
        if self._c_met is not None:
            b = self._window_bounds()
            return 0 if b is None else int(b[2] - b[0])
        return len(self._met)

    @property
    def attainment(self) -> Optional[float]:
        if self._c_met is not None:
            b = self._window_bounds()
            if b is None:
                return None
            then_t, then_m, now_t, now_m = b
            n = now_t - then_t
            if n < self.cfg.min_window:
                return None
            return (now_m - then_m) / n
        if len(self._met) < self.cfg.min_window:
            return None
        return sum(self._met) / len(self._met)

    # ------------------------------------------------------------------ #
    def decide(self, t: float, n_live: int, n_draining: int = 0,
               load_frac: float = 1.0, can_drain: bool = True) -> int:
        """Returns +1 (add an instance), -1 (drain one), or 0 (hold).

        ``n_live`` counts routable instances (draining ones excluded),
        ``load_frac`` is the mean allocated-KVC fraction across them,
        ``can_drain`` is whether the caller actually has a drain victim
        (e.g. a unified-role instance). Action state (cooldown, window
        reset, event log) commits only on an executable decision — a
        capacity- or victim-blocked breach must not start a phantom
        cooldown that suppresses later legitimate actions.
        """
        cfg = self.cfg
        att = self.attainment
        if att is None:
            return 0
        if t - self._last_action_t < cfg.cooldown:
            # the previous action hasn't had time to show up in the
            # window: hold AND don't accumulate breaches against stale data
            self._up_streak = self._down_streak = 0
            return 0
        if att < cfg.slo_low:
            if n_live + n_draining >= cfg.max_instances:
                return 0                     # at capacity: nothing to do
            self._up_streak += 1
            self._down_streak = 0
            if self._up_streak >= cfg.patience:
                self._act(t, +1)
                return +1
            return 0
        if att > cfg.slo_high and n_live > cfg.min_instances and can_drain:
            projected = load_frac * n_live / max(1, n_live - 1)
            if projected <= cfg.down_load_cap:
                self._down_streak += 1
                self._up_streak = 0
                if self._down_streak >= cfg.patience:
                    self._act(t, -1)
                    return -1
                return 0
        self._up_streak = self._down_streak = 0
        return 0

    def publish_metrics(self, registry) -> None:
        """Publish attainment + action counters into a ``repro.obs``
        registry."""
        att = self.attainment
        registry.gauge("autoscaler_attainment_ratio",
                       "rolling SLO attainment (None -> -1: window too "
                       "small to act on)") \
            .unlabeled.set(-1.0 if att is None else att)
        registry.gauge("autoscaler_window_completions",
                       "completions in the attainment window") \
            .unlabeled.set(self.window_len)
        up = sum(1 for _, d in self.events if d > 0)
        fam = registry.counter("autoscaler_actions_total",
                               "scale actions executed", ("direction",))
        fam.labels(direction="up").inc_to(up)
        fam.labels(direction="down").inc_to(len(self.events) - up)

    def invalidate(self) -> None:
        """Discard the attainment window and breach streaks — called on an
        instance crash: the window's completions reflect the pre-crash
        capacity, and acting on them would double-count the failure."""
        self._reset_window()
        self._up_streak = self._down_streak = 0

    def _reset_window(self) -> None:
        """Start the next attainment estimate fresh. In registry mode the
        counters keep their full history (a monotonic series for the
        dashboards); only the controller's baseline moves."""
        self._met.clear()
        if self._c_met is not None:
            self._baseline = (self._c_met.value + self._c_miss.value,
                              self._c_met.value)

    def _act(self, t: float, delta: int) -> None:
        self._last_action_t = t
        self._up_streak = self._down_streak = 0
        # an action invalidates the window: completions in it reflect the
        # old capacity, so start the next estimate fresh
        self._reset_window()
        self.events.append((t, delta))
