"""Shared cluster-instance layer: roles, router-visible stats, and the
autoscale-decision executor — one implementation for both backends.

``ClusterSim`` instances wrap a discrete-event ``SimInstance`` and
``EngineFleet`` instances wrap a real ``ServingEngine``; everything the
router and autoscaler observe (role eligibility, KVC fractions,
outstanding work) only needs the underlying scheduler, so subclasses
provide a single ``scheduler`` property and inherit the rest. Keeping
this here — not copied per backend — means a policy fix lands in both.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .autoscale import GoodputAutoscaler
from .transport import BEAT, DETECTOR, Transport

ROLES = ("unified", "prefill", "decode")

# instance health lifecycle (fault injection / recovery):
#   healthy — routable, stepped normally
#   suspect — alive but degraded (frozen or slowed): no new routes; its
#             in-flight state is intact and reachable, so the fleet may
#             evacuate queued work via real KV re-migration
#   dead    — crashed: device state lost, never stepped or routed again;
#             in-flight requests are reclaimed and recovered elsewhere
#
# With a FailureDetector attached, health is *observed*, not declared:
# the injector only crashes/freezes the instance (it stops heartbeating)
# and the detector walks HEALTHY -> SUSPECT on missed-beat patience and
# SUSPECT -> DEAD on lease expiry. A false suspect that beats again is
# reinstated (SUSPECT -> HEALTHY) with all of its work intact; DEAD is
# final — a late beat from a fenced zombie never resurrects it.
HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
HEALTH_STATES = (HEALTHY, SUSPECT, DEAD)


def validate_roles(roles, n_instances: int) -> List[str]:
    """Normalize + sanity-check a role assignment: a prefill-only fleet
    would ping-pong migrated GTs forever, a decode-only one could never
    admit a prompt."""
    roles = list(roles) if roles is not None else ["unified"] * n_instances
    assert len(roles) == n_instances, (roles, n_instances)
    assert all(r in ROLES for r in roles), roles
    if any(r != "unified" for r in roles):
        assert any(r in ("prefill", "unified") for r in roles) and \
            any(r in ("decode", "unified") for r in roles), \
            "disaggregated cluster needs both prompt and decode capacity"
    return roles


class InstanceBase:
    """Role state + the InstanceStats protocol routers consume."""

    def __init__(self, iid: int, role: str = "unified"):
        assert role in ROLES, role
        self.id = iid
        self.role = role
        self.draining = False
        self._n_done = 0              # completions already fed upstream
        # -- health (fault injection / recovery) ----------------------- #
        self.health = HEALTHY
        self.frozen_until = 0.0       # suspect-frozen: not stepped until t
        self.slow_until = 0.0         # suspect-slow: degraded until t
        self.slow_factor = 1          # straggler slowdown multiple
        self._slow_tick = 0
        # -- detection (heartbeat/lease failure detector) -------------- #
        # ``crashed`` is ground truth (the device is gone: no stepping,
        # no beats); ``health`` stays the *observed* state. Without a
        # detector the injector writes health directly and crashed is
        # never set. ``detected`` hands health ownership to the detector
        # (the freeze-elapsed auto-recovery in update_health turns off).
        self.crashed = False
        self.detected = False
        self._last_beat_sent = float("-inf")

    @property
    def scheduler(self):
        raise NotImplementedError

    # -- health -------------------------------------------------------- #
    @property
    def alive(self) -> bool:
        return self.health != DEAD

    def update_health(self, t: float) -> None:
        """Recover a suspect instance whose freeze/slow episode elapsed.
        Under a failure detector this is a no-op: reinstatement happens
        when the detector sees the instance heartbeat again."""
        if self.detected:
            return
        if self.health == SUSPECT and t >= self.frozen_until \
                and t >= self.slow_until:
            self.health = HEALTHY
            self.slow_factor = 1

    def can_step(self, t: float) -> bool:
        """Whether the backend may advance this instance at time ``t``:
        crashed never, declared-dead (oracle mode) never, frozen not
        before thaw, slowed every Nth tick only. A falsely-*suspected*
        instance (beats lost in transit, not frozen) keeps stepping — it
        loses no work while the detector makes up its mind. A *detected*
        DEAD instance that never crashed is a zombie (e.g. partitioned
        away from the control plane): it cannot know it was declared
        dead, so it keeps stepping too — its output is fenced at the
        delivery boundary, not by freezing the device."""
        if self.crashed or (self.health == DEAD and not self.detected):
            return False
        if self.health == DEAD:
            # zombie: local freeze/slow windows still apply
            if t < self.frozen_until:
                return False
            if t < self.slow_until and self.slow_factor > 1:
                self._slow_tick += 1
                return self._slow_tick % self.slow_factor == 0
            return True
        if self.health == HEALTHY and t < self.frozen_until:
            return False              # detector-managed: frozen, not yet
                                      # suspected — still must not step
        if self.health == HEALTHY and t < self.slow_until \
                and self.slow_factor > 1:
            self._slow_tick += 1
            return self._slow_tick % self.slow_factor == 0
        if self.health == SUSPECT:
            if t < self.frozen_until:
                return False
            if t < self.slow_until and self.slow_factor > 1:
                self._slow_tick += 1
                return self._slow_tick % self.slow_factor == 0
        return True

    def maybe_beat(self, transport: Transport, now: float,
                   beat_every: float) -> None:
        """Emit a heartbeat through the (lossy) transport when one is
        due. A crashed instance is silent forever; a frozen one is silent
        until the thaw — missed beats are exactly what the detector
        observes. A slowed instance still beats (stragglers are not
        crash-detectable from liveness alone)."""
        if self.crashed or now < self.frozen_until:
            return
        if now - self._last_beat_sent >= beat_every - 1e-9:
            self._last_beat_sent = now
            transport.send(DETECTOR, BEAT, self.id, now, link=self.id)

    def squeeze_kvc(self, frac: float) -> int:
        """Chaos ``squeeze``: permanently remove ``frac`` of this
        instance's KVC capacity (free blocks immediately, held blocks
        harvested as allocations free — ``BlockKVC.shrink``). Backends
        with stricter timing contracts (the real engine's megastep
        windows) override to defer the cut to a safe boundary."""
        kvc = self.scheduler.kvc
        return kvc.shrink(int(kvc.capacity_tokens * frac))

    # -- routing eligibility ------------------------------------------- #
    def accepts_prompts(self) -> bool:
        return (self.health == HEALTHY
                and self.role in ("unified", "prefill")
                and not self.draining)

    def accepts_decodes(self) -> bool:
        return (self.health == HEALTHY
                and self.role in ("unified", "decode")
                and not self.draining)

    # -- InstanceStats protocol ---------------------------------------- #
    def kvc_allocated_frac(self) -> float:
        return self.scheduler.kvc.allocated_frac

    def kvc_capacity_tokens(self) -> int:
        return self.scheduler.kvc.capacity_tokens

    def outstanding_tokens(self) -> int:
        sched = self.scheduler
        tot = 0
        for r in sched.pt_queue:
            tot += (r.prompt_len - r.prompt_done) + r.remaining_predicted
        for r in sched.gt_queue:
            tot += r.remaining_predicted
        for r in getattr(sched, "running_gts", []):
            tot += r.remaining_predicted
        return tot

    def harvest_completions(self, scaler: GoodputAutoscaler) -> None:
        """Feed completions since the last harvest into the attainment
        window."""
        done = self.scheduler.completed
        for r in done[self._n_done:]:
            scaler.record(r.met_slo)
        self._n_done = len(done)


@dataclass
class DetectorConfig:
    """Heartbeat/lease failure-detection policy.

    An instance that has not beaten for ``patience`` beat periods is
    suspected (no new routes; work stays put); one silent past ``lease``
    is declared dead and its work reclaimed. ``lease`` must comfortably
    exceed ``patience * beat_every`` — the gap is the reinstatement
    window in which a false suspect (beats dropped by the transport, or
    a freeze shorter than the lease) recovers without losing anything."""
    beat_every: float = 1.0       # expected heartbeat period
    patience: float = 3.0         # missed beats before HEALTHY -> SUSPECT
    lease: float = 10.0           # silence before SUSPECT -> DEAD

    def __post_init__(self):
        assert self.lease > self.patience * self.beat_every, \
            "lease must exceed the suspicion threshold"


class FailureDetector:
    """Detects instance failure from heartbeats instead of being told.

    ``observe`` drains the beat channel and walks each instance's
    *observed* health: silence past patience suspects it, silence past
    the lease declares it dead (final — a zombie's late beat is fenced),
    and a fresh beat from a suspect reinstates it. The transition log is
    append-only and auditable (the Hypothesis state machine in
    ``tests`` checks no transition ever skips a state or resurrects the
    dead)."""

    def __init__(self, cfg: DetectorConfig, transport: Transport):
        self.cfg = cfg
        self.transport = transport
        self.last_beat: Dict[int, float] = {}
        self.last_observed = 0.0
        self.n_suspects = 0
        self.n_reinstated = 0
        self.n_declared_dead = 0
        self.transitions: List[Tuple[float, int, str, str]] = []

    def _set(self, inst, to: str, now: float) -> None:
        self.transitions.append((now, inst.id, inst.health, to))
        inst.health = to

    def observe(self, now: float, instances: Sequence) -> List[int]:
        """One detection pass; returns ids newly declared dead."""
        self.last_observed = now
        for msg in self.transport.recv(DETECTOR, now):
            iid = msg.payload
            if msg.send_t > self.last_beat.get(iid, float("-inf")):
                self.last_beat[iid] = msg.send_t
        newly_dead: List[int] = []
        for inst in instances:
            if inst.health == DEAD:
                continue               # final: never resurrected
            last = self.last_beat.setdefault(inst.id, now)
            age = now - last
            if inst.health == SUSPECT:
                if age <= self.cfg.patience * self.cfg.beat_every:
                    self._set(inst, HEALTHY, now)   # false suspect: back
                    self.n_reinstated += 1
                elif age > self.cfg.lease:
                    self._set(inst, DEAD, now)      # lease expired
                    self.n_declared_dead += 1
                    newly_dead.append(inst.id)
            elif inst.health == HEALTHY \
                    and age > self.cfg.patience * self.cfg.beat_every:
                self._set(inst, SUSPECT, now)
                self.n_suspects += 1
        return newly_dead

    def heartbeat_age(self, iid: int, now: Optional[float] = None) -> float:
        """Time since the last beat seen from ``iid`` (diagnostics)."""
        now = self.last_observed if now is None else now
        return now - self.last_beat.get(iid, float("-inf"))

    def publish_metrics(self, registry, instances: Sequence = ()) -> None:
        """Publish detection counters (and, per instance, the observed
        health state + heartbeat age) into a ``repro.obs`` registry.
        ``detector_health_state`` encodes healthy=0 / suspect=1 / dead=2
        so a dashboard can alert on any non-zero value."""
        registry.counter("detector_suspects_total",
                         "HEALTHY -> SUSPECT transitions") \
            .unlabeled.inc_to(self.n_suspects)
        registry.counter("detector_reinstated_total",
                         "false suspects reinstated by a fresh beat") \
            .unlabeled.inc_to(self.n_reinstated)
        registry.counter("detector_declared_dead_total",
                         "leases expired (final)") \
            .unlabeled.inc_to(self.n_declared_dead)
        registry.counter("detector_transitions_total",
                         "observed health transitions (append-only log)") \
            .unlabeled.inc_to(len(self.transitions))
        state_g = registry.gauge("detector_health_state",
                                 "observed health: healthy=0 suspect=1 "
                                 "dead=2", ("instance",))
        age_g = registry.gauge("detector_heartbeat_age_seconds",
                               "time since the last beat seen",
                               ("instance",))
        for inst in instances:
            state_g.labels(instance=inst.id).set(
                HEALTH_STATES.index(inst.health))
            age = self.heartbeat_age(inst.id)
            if age != float("inf"):
                age_g.labels(instance=inst.id).set(age)

    def next_deadline(self, instances: Sequence) -> float:
        """Earliest future time a detection state could change — the
        discrete-event backend folds this into its event horizon so a
        silent instance is eventually suspected/declared even when no
        other event would advance the clock. A hair past the threshold:
        ``observe`` transitions on *strictly* exceeded ages, so a wake at
        exactly ``last + patience`` would observe nothing and pin the
        horizon forever."""
        nxt = float("inf")
        for inst in instances:
            if inst.health == DEAD:
                continue
            last = self.last_beat.get(inst.id)
            if last is None:
                continue
            if inst.health == SUSPECT:
                nxt = min(nxt, last + self.cfg.lease)
            else:
                nxt = min(nxt, last + self.cfg.patience * self.cfg.beat_every)
        return nxt + 1e-6 if nxt != float("inf") else nxt


def execute_autoscale(scaler: GoodputAutoscaler, t: float,
                      instances: Sequence[InstanceBase],
                      spawn: Callable[[float], None],
                      events: List[Tuple[float, int]]) -> None:
    """Poll the scaler against the routable set and execute its decision:
    +1 spawns a fresh unified instance (via the backend's ``spawn``
    callback), -1 marks the least-loaded unified instance draining (no
    new routes; it retires once its in-flight work finishes). The scaler
    is told whether a drain victim exists, so a blocked action never
    commits cooldown state."""
    routable = [i for i in instances if not i.draining and i.alive]
    load = sum(i.kvc_allocated_frac() for i in routable) \
        / max(1, len(routable))
    n_drain = sum(1 for i in instances if i.draining and i.alive)
    victims = [i for i in routable if i.role == "unified"]
    action = scaler.decide(t, n_live=len(routable), n_draining=n_drain,
                           load_frac=load, can_drain=bool(victims))
    if action > 0:
        spawn(t)
        events.append((t, +1))
    elif action < 0:
        v = min(victims, key=lambda i: (i.outstanding_tokens(), -i.id))
        v.draining = True
        events.append((t, -1))
