"""Shared cluster-instance layer: roles, router-visible stats, and the
autoscale-decision executor — one implementation for both backends.

``ClusterSim`` instances wrap a discrete-event ``SimInstance`` and
``EngineFleet`` instances wrap a real ``ServingEngine``; everything the
router and autoscaler observe (role eligibility, KVC fractions,
outstanding work) only needs the underlying scheduler, so subclasses
provide a single ``scheduler`` property and inherit the rest. Keeping
this here — not copied per backend — means a policy fix lands in both.
"""
from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

from .autoscale import GoodputAutoscaler

ROLES = ("unified", "prefill", "decode")

# instance health lifecycle (fault injection / recovery):
#   healthy — routable, stepped normally
#   suspect — alive but degraded (frozen or slowed): no new routes; its
#             in-flight state is intact and reachable, so the fleet may
#             evacuate queued work via real KV re-migration
#   dead    — crashed: device state lost, never stepped or routed again;
#             in-flight requests are reclaimed and recovered elsewhere
HEALTHY = "healthy"
SUSPECT = "suspect"
DEAD = "dead"
HEALTH_STATES = (HEALTHY, SUSPECT, DEAD)


def validate_roles(roles, n_instances: int) -> List[str]:
    """Normalize + sanity-check a role assignment: a prefill-only fleet
    would ping-pong migrated GTs forever, a decode-only one could never
    admit a prompt."""
    roles = list(roles) if roles is not None else ["unified"] * n_instances
    assert len(roles) == n_instances, (roles, n_instances)
    assert all(r in ROLES for r in roles), roles
    if any(r != "unified" for r in roles):
        assert any(r in ("prefill", "unified") for r in roles) and \
            any(r in ("decode", "unified") for r in roles), \
            "disaggregated cluster needs both prompt and decode capacity"
    return roles


class InstanceBase:
    """Role state + the InstanceStats protocol routers consume."""

    def __init__(self, iid: int, role: str = "unified"):
        assert role in ROLES, role
        self.id = iid
        self.role = role
        self.draining = False
        self._n_done = 0              # completions already fed upstream
        # -- health (fault injection / recovery) ----------------------- #
        self.health = HEALTHY
        self.frozen_until = 0.0       # suspect-frozen: not stepped until t
        self.slow_until = 0.0         # suspect-slow: degraded until t
        self.slow_factor = 1          # straggler slowdown multiple
        self._slow_tick = 0

    @property
    def scheduler(self):
        raise NotImplementedError

    # -- health -------------------------------------------------------- #
    @property
    def alive(self) -> bool:
        return self.health != DEAD

    def update_health(self, t: float) -> None:
        """Recover a suspect instance whose freeze/slow episode elapsed."""
        if self.health == SUSPECT and t >= self.frozen_until \
                and t >= self.slow_until:
            self.health = HEALTHY
            self.slow_factor = 1

    def can_step(self, t: float) -> bool:
        """Whether the backend may advance this instance at time ``t``:
        dead never, frozen not before thaw, slowed every Nth tick only."""
        if self.health == DEAD:
            return False
        if self.health == SUSPECT:
            if t < self.frozen_until:
                return False
            if t < self.slow_until and self.slow_factor > 1:
                self._slow_tick += 1
                return self._slow_tick % self.slow_factor == 0
        return True

    def squeeze_kvc(self, frac: float) -> int:
        """Chaos ``squeeze``: permanently remove ``frac`` of this
        instance's KVC capacity (free blocks immediately, held blocks
        harvested as allocations free — ``BlockKVC.shrink``). Backends
        with stricter timing contracts (the real engine's megastep
        windows) override to defer the cut to a safe boundary."""
        kvc = self.scheduler.kvc
        return kvc.shrink(int(kvc.capacity_tokens * frac))

    # -- routing eligibility ------------------------------------------- #
    def accepts_prompts(self) -> bool:
        return (self.health == HEALTHY
                and self.role in ("unified", "prefill")
                and not self.draining)

    def accepts_decodes(self) -> bool:
        return (self.health == HEALTHY
                and self.role in ("unified", "decode")
                and not self.draining)

    # -- InstanceStats protocol ---------------------------------------- #
    def kvc_allocated_frac(self) -> float:
        return self.scheduler.kvc.allocated_frac

    def kvc_capacity_tokens(self) -> int:
        return self.scheduler.kvc.capacity_tokens

    def outstanding_tokens(self) -> int:
        sched = self.scheduler
        tot = 0
        for r in sched.pt_queue:
            tot += (r.prompt_len - r.prompt_done) + r.remaining_predicted
        for r in sched.gt_queue:
            tot += r.remaining_predicted
        for r in getattr(sched, "running_gts", []):
            tot += r.remaining_predicted
        return tot

    def harvest_completions(self, scaler: GoodputAutoscaler) -> None:
        """Feed completions since the last harvest into the attainment
        window."""
        done = self.scheduler.completed
        for r in done[self._n_done:]:
            scaler.record(r.met_slo)
        self._n_done = len(done)


def execute_autoscale(scaler: GoodputAutoscaler, t: float,
                      instances: Sequence[InstanceBase],
                      spawn: Callable[[float], None],
                      events: List[Tuple[float, int]]) -> None:
    """Poll the scaler against the routable set and execute its decision:
    +1 spawns a fresh unified instance (via the backend's ``spawn``
    callback), -1 marks the least-loaded unified instance draining (no
    new routes; it retires once its in-flight work finishes). The scaler
    is told whether a drain victim exists, so a blocked action never
    commits cooldown state."""
    routable = [i for i in instances if not i.draining and i.alive]
    load = sum(i.kvc_allocated_frac() for i in routable) \
        / max(1, len(routable))
    n_drain = sum(1 for i in instances if i.draining and i.alive)
    victims = [i for i in routable if i.role == "unified"]
    action = scaler.decide(t, n_live=len(routable), n_draining=n_drain,
                           load_frac=load, can_drain=bool(victims))
    if action > 0:
        spawn(t)
        events.append((t, +1))
    elif action < 0:
        v = min(victims, key=lambda i: (i.outstanding_tokens(), -i.id))
        v.draining = True
        events.append((t, -1))
