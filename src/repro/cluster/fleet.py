"""Real-engine fleet: N in-process ``ServingEngine`` instances behind one
event loop, one router, and (optionally) disaggregated prefill/decode
roles with live KV migration.

The fleet owns the request stream: ``submit`` routes each ``GenRequest``
to exactly one engine (conservation-guarded — a request object is never
routed twice), ``step`` advances every engine that has work on a shared
iteration clock, then sweeps prefill-role engines for finished prompts and
migrates them: ``engine.export_kv`` extracts the request's cache pages and
carried slot state, ``engine.inject_kv`` seeds them into a decode engine
chosen by the decode-side router. Engines that cannot produce a portable
KV image (recurrent stacks, ring caches) — or a receiver without a free
slot / KVC room — fall back transparently to the engine's existing
swap-recompute path; either way the greedy token stream is identical to
serving the request on a single engine (``tests/test_cluster.py``).

Model parameters are built once and shared by every engine (caches, slots
and schedulers stay per-engine), so an N-instance fleet costs N caches,
not N models. An optional ``GoodputAutoscaler`` is polled once per loop
tick: +1 spawns a fresh unified engine from the shared parameters, -1
marks one draining (no new routes; it retires via ``has_work``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.request import Request
from repro.models import model
from repro.models.config import ModelConfig
from repro.serving import GenRequest, ServingEngine
from repro.serving.engine import serve_stream

from .autoscale import GoodputAutoscaler
from .base import InstanceBase, ROLES, execute_autoscale, validate_roles
from .router import Router, make_router

__all__ = ["EngineFleet", "FleetInstance", "ROLES"]


class FleetInstance(InstanceBase):
    """One engine plus its routing-visible stats (InstanceStats)."""

    def __init__(self, iid: int, engine: ServingEngine,
                 role: str = "unified"):
        super().__init__(iid, role)
        self.engine = engine

    @property
    def scheduler(self):
        return self.engine.scheduler


class EngineFleet:
    def __init__(self, cfg: ModelConfig, n_instances: int = 2, *,
                 roles: Optional[Sequence[str]] = None,
                 router: str = "least-kvc", seed: int = 0,
                 kv_migration: bool = True,
                 autoscaler: Optional[GoodputAutoscaler] = None,
                 **engine_kwargs):
        """``engine_kwargs`` are forwarded to every ``ServingEngine``
        (max_batch, capacity, scheduler_cfg, engine_cfg, impl, ...).
        ``kv_migration=False`` forces the swap-recompute fallback for every
        migration (the reference path the KV image is tested against).
        Fleet size under autoscaling is bounded by the scaler's
        ``AutoscaleConfig.max_instances``."""
        self.cfg = cfg
        self.kv_migration = kv_migration
        self.engine_kwargs = dict(engine_kwargs)
        self.params = model.init(cfg, jax.random.PRNGKey(seed))
        self._seed = seed
        roles = validate_roles(roles, n_instances)
        self.instances: List[FleetInstance] = [
            FleetInstance(i, self._make_engine(i), roles[i])
            for i in range(n_instances)]
        self.router: Router = make_router(router, seed)
        self.decode_router: Router = make_router(router, seed + 1)
        self.autoscaler = autoscaler
        # conservation accounting: a GenRequest is routed exactly once
        self.route_of: Dict[int, int] = {}       # id(GenRequest) -> iid
        self.submitted: List[GenRequest] = []
        self.double_routes = 0
        self.n_migrations = 0
        self.n_kv_fallbacks = 0
        self.scale_events: List[Tuple[float, int]] = []
        self._next_id = n_instances

    def _make_engine(self, i: int) -> ServingEngine:
        return ServingEngine(self.cfg, params=self.params,
                             seed=self._seed + i, **self.engine_kwargs)

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float) -> int:
        """Route and submit one request; returns the serving instance id."""
        if id(req) in self.route_of:
            self.double_routes += 1
        cands = [i for i in self.instances if i.accepts_prompts()]
        if not cands:
            cands = [i for i in self.instances
                     if i.role in ("unified", "prefill")]
        demand = len(req.prompt) + req.params.max_new_tokens
        inst = self.router.choose(cands, demand)
        inst.engine.submit(req, now)
        self.route_of[id(req)] = inst.id
        self.submitted.append(req)
        return inst.id

    def has_work(self) -> bool:
        return any(i.engine.has_work() for i in self.instances)

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One fleet tick: step every engine with work, then migrate
        finished prompts off prefill-role engines. Returns completions."""
        now = time.monotonic() if now is None else now
        done = 0
        for inst in self.instances:
            if inst.engine.has_work():
                done += inst.engine.step(now)
        for inst in self.instances:
            if inst.role == "prefill":
                self._migrate_ready(inst, now)
        if self.autoscaler is not None:
            self._autoscale(now)
        return done

    def _migrate_ready(self, inst: FleetInstance, now: float) -> None:
        """Move every queued GT off a prefill engine to a decode engine."""
        sched = inst.engine.scheduler
        for r in list(sched.gt_queue):
            payload = inst.engine.export_kv(r.rid)
            if not self.kv_migration:
                payload["kv"] = None
            cands = [i for i in self.instances if i.accepts_decodes()]
            if not cands:
                cands = [i for i in self.instances
                         if i.role in ("unified", "decode")]
            demand = payload["req"].prompt_len \
                + payload["req"].remaining_predicted
            tgt = self.decode_router.choose(cands, demand)
            if payload["kv"] is None:
                self.n_kv_fallbacks += 1
            tgt.engine.inject_kv(payload, now)
            self.n_migrations += 1

    def _spawn(self, now: float) -> None:
        iid = self._next_id
        self._next_id += 1
        self.instances.append(
            FleetInstance(iid, self._make_engine(iid), "unified"))

    def _autoscale(self, now: float) -> None:
        scaler = self.autoscaler
        # harvest fresh completions for the attainment window
        for inst in self.instances:
            inst.harvest_completions(scaler)
        execute_autoscale(scaler, now, self.instances, self._spawn,
                          self.scale_events)

    # ------------------------------------------------------------------ #
    def run(self, gen_requests: Sequence[GenRequest],
            arrivals: Optional[Sequence[float]] = None,
            max_steps: int = 100_000) -> List[GenRequest]:
        """Serve a batch (or, with ``arrivals``, an online stream on the
        fleet's iteration clock) to completion — the same contract as
        ``ServingEngine.run``, one shared driver."""
        return serve_stream(self, gen_requests, arrivals, max_steps)

    def flush(self) -> None:
        for inst in self.instances:
            inst.engine.flush()

    # ------------------------------------------------------------------ #
    def completed_requests(self) -> List[Request]:
        """Scheduler-side Request records across all engines (TTFT etc.)."""
        return [r for inst in self.instances
                for r in inst.engine.scheduler.completed]

    def conservation(self) -> Dict[str, int]:
        """Every submitted request finished exactly once, somewhere."""
        done = sum(1 for g in self.submitted if g.t_done is not None)
        return {"submitted": len(self.submitted),
                "completed": done,
                "double_routes": self.double_routes,
                "migrations": self.n_migrations,
                "ok": int(self.double_routes == 0
                          and done == len(self.submitted))}
