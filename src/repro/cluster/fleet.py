"""Real-engine fleet: N in-process ``ServingEngine`` instances behind one
event loop, one router, and (optionally) disaggregated prefill/decode
roles with live KV migration.

The fleet owns the request stream: ``submit`` routes each ``GenRequest``
to exactly one engine (conservation-guarded — a request object is never
routed twice), ``step`` advances every engine that has work on a shared
iteration clock, then sweeps prefill-role engines for finished prompts and
migrates them: ``engine.export_kv`` extracts the request's cache pages and
carried slot state, ``engine.inject_kv`` seeds them into a decode engine
chosen by the decode-side router. Engines that cannot produce a portable
KV image (recurrent stacks, ring caches) — or a receiver without a free
slot / KVC room — fall back transparently to the engine's existing
swap-recompute path; either way the greedy token stream is identical to
serving the request on a single engine (``tests/test_cluster.py``).

Model parameters are built once and shared by every engine (caches, slots
and schedulers stay per-engine), so an N-instance fleet costs N caches,
not N models. An optional ``GoodputAutoscaler`` is polled once per loop
tick: +1 spawns a fresh unified engine from the shared parameters, -1
marks one draining (no new routes; it retires via ``has_work``).

Fault tolerance (``faults``/``recovery`` kwargs):

  * an optional ``FaultInjector`` is polled every tick; it crashes,
    freezes, or slows instances (``InstanceBase`` health lifecycle) and
    corrupts KV payloads in flight (caught by the checksum at inject);
  * **crash recovery** — when an instance dies, every in-flight request
    on it is reclaimed and redelivered with bounded retries and
    exponential backoff (optionally jittered — ``RecoveryConfig.jitter``
    — to spread the retry herd, deterministically under a fixed seed).
    A host-offloaded KV image that survived the crash (the device state
    is gone, the host pool is not) is salvaged and re-seeded into the
    receiving engine; otherwise a request with generated tokens goes
    through the swap-recompute path (greedy decoding regenerates the
    lost tail bit-exactly), and one with none is simply resubmitted at
    its original arrival time;
  * **degradation** — a frozen (suspect) instance keeps its device state,
    so its *queued* GTs are evacuated by real KV re-migration while its
    running batch waits for the thaw;
  * **deadline watchdog / shedding** — ``RecoveryConfig.deadline_factor``
    aborts requests a multiple past their SLO deadline;
    ``RecoveryConfig.shed`` fast-fails admissions whose projected finish
    already misses it (typed ``RequestShed``).

``repro.cluster.faults.check_fleet_invariants`` audits the terminal
exactly-once + zero-leak contract after any run, chaotic or not.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.predictor import apply_padding, bucketize
from repro.core.request import Request
from repro.models import model
from repro.models.config import ModelConfig
from repro.serving import GenRequest, RequestShed, ServingEngine
from repro.serving.engine import serve_stream

from .autoscale import GoodputAutoscaler
from .base import (DetectorConfig, FailureDetector, HEALTH_STATES,
                   HEALTHY, SUSPECT, InstanceBase, ROLES,
                   execute_autoscale, validate_roles)
from .faults import FaultInjector, RecoveryConfig, backoff_delay
from .hedge import HedgeConfig, HedgeCoordinator
from .router import Router, make_router
from .transport import CANCEL, INJECT, SUBMIT, Transport

__all__ = ["EngineFleet", "FleetInstance", "ROLES"]


class FleetInstance(InstanceBase):
    """One engine plus its routing-visible stats (InstanceStats)."""

    def __init__(self, iid: int, engine: ServingEngine,
                 role: str = "unified"):
        super().__init__(iid, role)
        self.engine = engine

    @property
    def scheduler(self):
        return self.engine.scheduler

    def squeeze_kvc(self, frac: float) -> int:
        # the engine defers a mid-megastep cut to the window boundary
        return self.engine.squeeze_kvc(frac)


class EngineFleet:
    def __init__(self, cfg: ModelConfig, n_instances: int = 2, *,
                 roles: Optional[Sequence[str]] = None,
                 router: str = "least-kvc", seed: int = 0,
                 kv_migration: bool = True,
                 autoscaler: Optional[GoodputAutoscaler] = None,
                 faults: Optional[FaultInjector] = None,
                 recovery: Optional[RecoveryConfig] = None,
                 detector: Optional[DetectorConfig] = None,
                 hedge: Optional[HedgeConfig] = None,
                 **engine_kwargs):
        """``engine_kwargs`` are forwarded to every ``ServingEngine``
        (max_batch, capacity, scheduler_cfg, engine_cfg, impl, ...).
        ``kv_migration=False`` forces the swap-recompute fallback for every
        migration (the reference path the KV image is tested against).
        Fleet size under autoscaling is bounded by the scaler's
        ``AutoscaleConfig.max_instances``. ``faults=None`` (the default)
        leaves every fault-tolerance path dormant: no injector polls, no
        recovery bookkeeping touches the hot loop.

        ``detector`` switches the fleet from *declared* to *detected*
        failure: every routed message (submit / KV inject) travels
        through a seeded lossy :class:`Transport`, instances heartbeat
        through it, and the :class:`FailureDetector` owns observed
        health (missed-beat patience -> suspect, lease expiry -> dead,
        fresh beat -> reinstated). An attached injector stops declaring
        health and merely crashes/freezes instances; its drop/dup/delay
        events open transport fault windows. With no fault events the
        detector-on path is bitwise-identical to the direct path: beats
        are pure host-side bookkeeping and the transport delivers
        same-tick FIFO.

        ``hedge`` enables straggler-aware hedged execution (needs
        detector mode): a per-request progress watchdog launches a clone
        of a stalled (or suspect-hosted) request on the best live peer;
        the first terminal transition wins and the loser is cancelled
        through the megastep-safe abort path, its host fenced so a late
        completion is counted, never double-delivered. With
        ``hedge=None`` (or ``HedgeConfig(enabled=False)``) every hedging
        path is dormant and the fleet is bitwise-unchanged."""
        self.cfg = cfg
        self.kv_migration = kv_migration
        self.engine_kwargs = dict(engine_kwargs)
        self.params = model.init(cfg, jax.random.PRNGKey(seed))
        self._seed = seed
        roles = validate_roles(roles, n_instances)
        self.instances: List[FleetInstance] = [
            FleetInstance(i, self._make_engine(i), roles[i])
            for i in range(n_instances)]
        self.router: Router = make_router(router, seed)
        self.decode_router: Router = make_router(router, seed + 1)
        self.autoscaler = autoscaler
        self.faults = faults
        self.recovery = recovery or RecoveryConfig()
        # detection-and-delivery substrate (None = legacy direct calls)
        self.detector_cfg = detector
        self.transport = Transport(seed=seed + 7) \
            if detector is not None else None
        self.detector = FailureDetector(detector, self.transport) \
            if detector is not None else None
        if self.detector is not None:
            for inst in self.instances:
                inst.detected = True
            if self.faults is not None:
                self.faults.detected = True
                self.faults.transport = self.transport
        if self.recovery.shed_retry:
            for inst in self.instances:
                inst.engine.fleet_shed_handback = True
        # at-least-once delivery epochs: each intentional (re)delivery of
        # a GenRequest gets a fresh key; transport dups share the key and
        # are suppressed at the engine boundary
        self._epoch: Dict[int, int] = {}
        # conservation accounting: a GenRequest is routed exactly once
        self.route_of: Dict[int, int] = {}       # id(GenRequest) -> iid
        self.submitted: List[GenRequest] = []
        self.double_routes = 0
        self.n_migrations = 0
        self.n_kv_fallbacks = 0
        self._metrics_registry = None
        self.scale_events: List[Tuple[float, int]] = []
        self._next_id = n_instances
        # crash recovery state
        self._redeliver: List[Tuple[float, GenRequest]] = []
        self._retries: Dict[int, int] = {}       # id(GenRequest) -> attempts
        self._dead_handled: set = set()          # instance ids reclaimed
        # host-pool KV images harvested off dead engines, keyed by
        # id(GenRequest): redelivery re-seeds pages instead of recomputing
        self._salvaged: Dict[int, dict] = {}
        self.n_salvaged_restores = 0
        self.n_recovered = 0
        self.n_failed_recoveries = 0
        self.n_evacuations = 0
        self.n_shed = 0
        self.n_deadline_aborts = 0
        # shed-retry tier: rung-4 kvc-infeasible hand-backs re-routed
        # fleet-wide instead of shed terminally
        self._shed_origin: set = set()   # id(GenRequest) in the retry tier
        self.n_shed_reroutes = 0         # hand-backs requeued for re-route
        self.n_shed_rescued = 0          # delivered to a feasible peer
        # hedged execution (straggler racing with first-winner fencing)
        self.hedge = HedgeCoordinator(hedge) if hedge is not None else None
        if self.hedge is not None:
            assert detector is not None, \
                "hedging needs detector mode (transport + observed health)"
        self._hedge_live: Dict[int, GenRequest] = {}   # gid -> primary g
        # gid -> (clone GenRequest, clone iid, primary iid at launch)
        self._hedge_clone: Dict[int, Tuple[GenRequest, int, int]] = {}
        self._hedge_seq = 0              # coordinator epoch source
        # registration-detach fences: the loser engine's registration is
        # swapped to a private clone, so its late drains/completions land
        # on a record the client never sees (counted, never delivered)
        self._fences: List[Tuple[int, int, GenRequest]] = []
        self.n_fenced_completions = 0
        self.n_stale_drops = 0           # stale-epoch deliveries fenced

    def _make_engine(self, i: int) -> ServingEngine:
        return ServingEngine(self.cfg, params=self.params,
                             seed=self._seed + i, **self.engine_kwargs)

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float) -> int:
        """Route and submit one request; returns the serving instance id.
        Raises ``RequestShed`` (after recording the terminal state) when
        admission control projects an unavoidable SLO miss, or when no
        live instance exists to serve it."""
        if id(req) in self.route_of:
            self.double_routes += 1
        cands = [i for i in self.instances if i.accepts_prompts()]
        if not cands:
            cands = [i for i in self.instances
                     if i.alive and i.role in ("unified", "prefill")]
        if not cands:
            return self._shed(req, now, "no-live-instance")
        demand = len(req.prompt) + req.params.max_new_tokens
        inst = self.router.choose(cands, demand)
        if self.recovery.shed and req.deadline != float("inf"):
            # projected finish on the chosen instance, on the fleet's
            # iteration clock: drain the backlog (~1 token/slot/iter),
            # then produce this request's own tokens
            backlog = inst.outstanding_tokens() / max(1, inst.engine.max_batch)
            eta = now + (backlog + len(req.prompt) / 64.0
                         + req.params.max_new_tokens) \
                * self.recovery.shed_headroom
            if eta > req.deadline:
                return self._shed(req, now, "projected-slo-miss")
        if self.transport is not None:
            # routed decision is made here; the delivery itself rides the
            # (lossy) transport — a clean link delivers synchronously in
            # the pump below (bit-for-bit the direct path), a faulted one
            # leaves it in flight for a later tick's sweep
            inst.engine.validate(req)
            self.transport.send(inst.id, SUBMIT, (req, now), now,
                                dkey=self._dkey(req))
            self._pump(inst, now)
        else:
            inst.engine.submit(req, now)
        self.route_of[id(req)] = inst.id
        self.submitted.append(req)
        if self.hedge is not None and self.hedge.cfg.enabled:
            self.hedge.track(id(req), now)
            self._hedge_live[id(req)] = req
        return inst.id

    def _dkey(self, g: GenRequest) -> tuple:
        """Fresh delivery key (epoch) for one intentional (re)delivery."""
        ep = self._epoch.get(id(g), 0) + 1
        self._epoch[id(g)] = ep
        return (id(g), ep)

    def _shed(self, req: GenRequest, now: float, reason: str) -> int:
        req.t_submit = now
        req.status = "shed"
        req.fail_reason = reason
        self.submitted.append(req)
        self.n_shed += 1
        raise RequestShed(req, reason)

    def _steppable(self, inst: FleetInstance) -> bool:
        """Instances the fleet still advances: every live one, plus
        *detected* DEAD instances that never crashed — zombies (e.g.
        partitioned away from the control plane) keep stepping their
        fenced work until the heal reconciles them."""
        return inst.alive or (inst.detected and not inst.crashed)

    def has_work(self) -> bool:
        return (any(self._steppable(i) and i.engine.has_work()
                    for i in self.instances)
                or bool(self._redeliver)
                or any(i.engine.shed_handback for i in self.instances)
                or (self.transport is not None
                    and self.transport.pending() > 0))

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One fleet tick: inject scheduled faults, run heartbeat/lease
        detection and deliver in-flight transport messages, reclaim and
        redeliver crashed work, enforce deadlines, step every live engine
        with work, sweep rung-4 shed hand-backs into the retry tier, then
        migrate finished prompts off prefill-role engines. Returns
        completions."""
        now = time.monotonic() if now is None else now
        if self.faults is not None:
            self.faults.poll(now, self.instances)
        if self.detector is not None:
            for inst in self.instances:
                inst.maybe_beat(self.transport, now,
                                self.detector.cfg.beat_every)
            self.detector.observe(now, self.instances)
            self._deliver_transport(now)
        self._reclaim_dead(now)
        if self._redeliver:
            self._deliver_redeliveries(now)
        if self.recovery.deadline_factor > 0:
            self._enforce_deadlines(now)
        done = 0
        for inst in self.instances:
            inst.update_health(now)
            if self._steppable(inst) and inst.engine.has_work() \
                    and inst.can_step(now):
                done += inst.engine.step(now)
        if self.hedge is not None and self.hedge.cfg.enabled:
            self._hedge_tick(now)
        if self._fences:
            self._sweep_fences(now)
        if self.recovery.shed_retry:
            self._retry_sheds(now)
        for inst in self.instances:
            if not inst.alive or inst.crashed:
                continue
            if inst.role == "prefill" and inst.health == HEALTHY:
                self._migrate_ready(inst, now)
            elif inst.health == SUSPECT and now < inst.frozen_until:
                # frozen-but-reachable: evacuate queued GTs by real KV
                # re-migration so they decode elsewhere during the outage
                self._evacuate(inst, now)
        if self.autoscaler is not None:
            self._autoscale(now)
        return done

    # -- transport delivery / shed-retry tier ---------------------------- #
    def _deliver_transport(self, now: float) -> None:
        for inst in self.instances:
            self._pump(inst, now)

    def _pump(self, inst: FleetInstance, now: float) -> None:
        """Drain one instance's due in-flight messages. Senders pump the
        recipient right after ``transport.send`` — a clean link delivers
        synchronously, reproducing the direct-call path bit-for-bit —
        and the per-tick sweep picks up delayed/retransmitted copies. A
        message landing on an instance already declared dead is
        orphaned: if the fleet still thinks the request lives there it
        re-enters recovery; stale copies of work re-routed since
        (fencing) are dropped."""
        for msg in self.transport.recv(inst.id, now):
            if msg.kind == CANCEL:
                # fencing reclaim: abort the (possibly clone-swapped)
                # registration so KVC/slot/ring state is provably freed.
                # Handled before the alive check — a zombie's engine is
                # exactly who a partition-held cancel reconciles at heal.
                # Idempotent (abort of a terminal rid is a no-op) and
                # pointless on a crashed device.
                if not inst.crashed:
                    rid, reason = msg.payload
                    inst.engine.abort(rid, now, reason)
                continue
            if msg.kind == SUBMIT:
                g, t_arr = msg.payload
            else:
                g, t_arr = msg.payload["gen"], now
            if g.finished:
                # terminal while this copy was in flight (redelivery
                # fast path, deadline abort, hedge winner): fenced here,
                # never registered
                continue
            if msg.dkey is not None \
                    and msg.dkey[1] < self._epoch.get(id(g), 0):
                # stale epoch: the fleet intentionally re-delivered this
                # request since the copy was sent (re-route past a
                # partition, hedge fencing) — the old copy must never
                # race the new registration
                self.n_stale_drops += 1
                continue
            if not inst.alive:
                if (not g.finished
                        and self.route_of.get(id(g)) == inst.id
                        and not any(q is g for _, q in self._redeliver)
                        and (self.hedge is None
                             or not self.hedge.active(id(g)))):
                    if (msg.kind == INJECT
                            and msg.payload.get("kv") is not None):
                        # the image in flight is as salvageable as a
                        # host-pool one: restore instead of recompute
                        self._salvaged[id(g)] = {
                            "kv": msg.payload["kv"],
                            "ctx": msg.payload["ctx"],
                            "crc": msg.payload.get("kv_crc")}
                    self._requeue(g, now, "undeliverable")
                continue
            if msg.kind == SUBMIT:
                inst.engine.submit(g, t_arr, dkey=msg.dkey)
            else:
                inst.engine.inject_kv(msg.payload, now)

    def _retry_sheds(self, now: float) -> None:
        """Sweep rung-4 ``kvc-infeasible`` hand-backs into the fleet
        retry tier: a request whose frozen exact-alloc demand some live
        peer's total KVC can still fund is requeued for a router-level
        re-route (bounded retries + the existing jittered backoff); one
        no live peer can *ever* fit is shed terminally — same contract,
        decided fleet-globally instead of per-instance."""
        for inst in self.instances:
            if not inst.engine.shed_handback:
                continue
            handed, inst.engine.shed_handback = \
                inst.engine.shed_handback, []
            for g in handed:
                self._shed_origin.add(id(g))
                demand = len(g.prompt) + g.params.max_new_tokens
                if any(i.alive and i.scheduler.fits_ever(demand)
                       for i in self.instances):
                    self.n_shed_reroutes += 1
                    self._requeue(g, now, "kvc-infeasible")
                else:
                    self._shed_terminal(g)

    def _shed_terminal(self, g: GenRequest) -> None:
        g.status = "shed"
        g.fail_reason = "kvc-infeasible"
        self.n_shed += 1
        self._salvaged.pop(id(g), None)

    # -- crash recovery ------------------------------------------------- #
    def _reclaim_dead(self, now: float) -> None:
        """Sweep newly-dead instances: every non-terminal request they
        held is queued for redelivery (bounded retries + backoff). The
        dead engine's undrained ring tokens are dropped — device state is
        gone; greedy recompute regenerates them bit-exactly."""
        for inst in self.instances:
            if inst.alive or inst.id in self._dead_handled:
                continue
            self._dead_handled.add(inst.id)
            if inst.detected and not inst.crashed:
                # declared dead but still stepping: a zombie (partition,
                # or a false death from lost beats). Its device state is
                # intact and must NOT be touched — fence instead.
                self._reclaim_zombie(inst, now)
                continue
            eng = inst.engine
            eng._pending_drain.clear()       # ring state died with the device
            victims = [g for g in eng.requests.values() if not g.finished]
            # host-offloaded KV images outlive the device: harvest them so
            # redelivery restores pages instead of recomputing
            for rid, img in eng._host_swap.items():
                g = eng.requests.get(rid)
                if g is not None and not g.finished:
                    self._salvaged[id(g)] = img
            eng._host_swap.clear()
            for payload, _ in eng._pending_injects:   # migrated in, unapplied
                if not payload["gen"].finished:
                    victims.append(payload["gen"])
                    if payload.get("kv") is not None:
                        # an in-flight KV image is just as salvageable
                        self._salvaged[id(payload["gen"])] = {
                            "kv": payload["kv"], "ctx": payload["ctx"],
                            "crc": payload.get("kv_crc")}
            eng._pending_injects.clear()
            eng._pending_aborts.clear()
            for g in victims:
                self._requeue(g, now, "crash")
            if self.autoscaler is not None:
                self.autoscaler.invalidate()

    def _reclaim_zombie(self, inst: FleetInstance, now: float) -> None:
        """Reconcile an instance the detector declared dead while its
        device kept running (asymmetric partition: outbound beats lost,
        the engine none the wiser). Every fleet-routed request on it is
        *fenced* — the engine's registration is swapped to a private
        clone, so the zombie's late drains/completions land on a record
        the client never sees — and a CANCEL rides the transport to
        reclaim the clone's KVC/slot/ring: a partitioned link holds it
        until the heal, which is exactly when the zombie becomes
        reachable again. Fenced requests re-enter recovery unless a
        hedge clone is already racing for them (the clone *is* the
        recovery)."""
        eng = inst.engine
        victims = [g for g in eng.requests.values()
                   if not g.finished
                   and self.route_of.get(id(g)) == inst.id]
        for payload, _ in eng._pending_injects:
            pg = payload.get("gen")
            if (pg is not None and not pg.finished
                    and self.route_of.get(id(pg)) == inst.id
                    and all(pg is not v for v in victims)):
                victims.append(pg)
        for g in victims:
            registered = eng.requests.get(g.rid) is g
            self._fence_registration(inst, g)
            if registered:
                self.transport.send(inst.id, CANCEL,
                                    (g.rid, "fenced-zombie"), now)
                self._pump(inst, now)
            if self.hedge is not None and self.hedge.active(id(g)):
                continue          # racing clone is the recovery path
            self._requeue(g, now, "partition")
        if victims and self.autoscaler is not None:
            self.autoscaler.invalidate()

    def _fence_registration(self, inst: FleetInstance,
                            g: GenRequest) -> None:
        """Detach ``g`` from ``inst``'s engine by swapping the
        registration (and any unapplied inject payload) to a private
        clone seeded with the drained-so-far output. The engine keeps
        running undisturbed — its device state still maps rid to a live
        request — but every subsequent drain/terminal write lands on the
        clone, which ``_sweep_fences`` counts and discards. This is the
        first-winner fence: the client-visible record can no longer be
        written by the losing side."""
        eng = inst.engine
        if eng.requests.get(g.rid) is g:
            clone = GenRequest(prompt=g.prompt, params=g.params,
                               rid=g.rid, output=list(g.output),
                               t_submit=g.t_submit, deadline=g.deadline)
            eng.requests[g.rid] = clone
            self._fences.append((inst.id, g.rid, clone))
        for payload, _ in eng._pending_injects:
            if payload.get("gen") is g:
                clone = GenRequest(prompt=g.prompt, params=g.params,
                                   output=list(g.output),
                                   t_submit=g.t_submit,
                                   deadline=g.deadline)
                payload["gen"] = clone
                self._fences.append((inst.id, -1, clone))

    def _sweep_fences(self, now: float) -> None:
        """Count completions that landed on fence clones — the loser's
        late terminal transitions, observed but never delivered (the
        invariant the partition chaos exists to stress: counted, not
        double-delivered). Aborted clones (the CANCEL landed first)
        simply retire."""
        still: List[Tuple[int, int, GenRequest]] = []
        for iid, rid, clone in self._fences:
            if not clone.finished:
                still.append((iid, rid, clone))
            elif clone.status == "completed" or clone.t_done is not None:
                self.n_fenced_completions += 1
                if self.hedge is not None:
                    self.hedge.n_fenced += 1
        self._fences = still

    # -- hedged execution ------------------------------------------------ #
    def _inst(self, iid: int) -> Optional[FleetInstance]:
        for i in self.instances:
            if i.id == iid:
                return i
        return None

    def _hedge_tick(self, now: float) -> None:
        """Per-tick hedge pass: feed host-visible progress to the
        watchdog, launch clones for stalled / suspect-hosted requests,
        and resolve races on the first terminal transition."""
        hedge = self.hedge
        for gid, g in list(self._hedge_live.items()):
            racing = self._hedge_clone.get(gid)
            if racing is None:
                hedge.observe_progress(gid, len(g.output), now)
                if g.finished:
                    hedge.mark_terminal(gid)
                    del self._hedge_live[gid]
                    continue
                primary = self._inst(self.route_of.get(gid, -1))
                suspect = primary is not None \
                    and primary.health != HEALTHY
                reason = hedge.want_hedge(gid, now, host_suspect=suspect)
                if reason is not None \
                        and not any(q is g for _, q in self._redeliver):
                    self._launch_hedge(g, primary, reason, now)
                continue
            clone, ciid, piid = racing
            ci = self._inst(ciid)
            if g.finished:
                # primary side won (completion, deadline abort, or the
                # redelivery fast path): cancel the clone, megastep-safe
                hedge.resolve(gid, "primary", piid)
                if ci is not None and not ci.crashed and clone.rid >= 0:
                    ci.engine.abort(clone.rid, now, "hedge-lost")
                del self._hedge_clone[gid]
                del self._hedge_live[gid]
                hedge.mark_terminal(gid)
                continue
            clone_dead = clone.rid < 0 and (ci is None or not ci.alive)
            if clone.finished and (clone.status == "completed"
                                   or clone.t_done is not None):
                # clone won: fence the primary registration FIRST (its
                # engine may be mid-window and must not write g again),
                # then publish the winning stream and cancel the loser
                pi = self._inst(piid)
                primary_rid = g.rid
                was_registered = (pi is not None
                                  and pi.engine.requests.get(g.rid) is g)
                if pi is not None:
                    self._fence_registration(pi, g)
                hedge.resolve(gid, "clone", piid)
                g.output[:] = clone.output
                g.status = "completed"
                g.t_done = clone.t_done
                self.route_of[gid] = ciid
                if was_registered and not pi.crashed:
                    self.transport.send(piid, CANCEL,
                                        (primary_rid, "hedge-lost"), now)
                    self._pump(pi, now)
                del self._hedge_clone[gid]
                del self._hedge_live[gid]
                continue
            if clone.finished or clone_dead:
                # clone died without completing (deadline abort, host
                # crash, undeliverable): dissolve the race — the primary
                # keeps running; if it no longer serves the request
                # (zombie-fenced meanwhile), recovery takes over
                hedge.abandon(gid)
                del self._hedge_clone[gid]
                pi = self._inst(piid)
                if (pi is None
                        or pi.engine.requests.get(g.rid) is not g) \
                        and not any(q is g for _, q in self._redeliver):
                    self._requeue(g, now, "hedge-failed")

    def _launch_hedge(self, g: GenRequest,
                      primary: Optional[FleetInstance], reason: str,
                      now: float) -> None:
        """Race ``g`` on the best live peer (router-scored, skipping the
        primary) under a fresh delivery epoch. The clone is a private
        ``GenRequest`` seeded with the drained-so-far prefix and rides
        the existing inject-recompute path — greedy decoding makes its
        stream bitwise-equal to the fault-free one."""
        piid = -1 if primary is None else primary.id
        cands = [i for i in self.instances
                 if i.accepts_prompts() and i.id != piid]
        if not cands:
            return
        out = list(g.output)
        rl = g.params.max_new_tokens
        eos = g.params.eos_token
        if eos is not None and eos in out:
            rl = out.index(eos) + 1
        if len(out) >= rl:
            return                   # drained tail already complete
        demand = len(g.prompt) + rl - len(out)
        tgt = self.router.choose(cands, demand)
        clone = GenRequest(prompt=g.prompt, params=g.params, output=out,
                           t_submit=g.t_submit, deadline=g.deadline)
        self._hedge_seq += 1
        self.hedge.launch(id(g), (self._hedge_seq,), tgt.id, reason)
        self._hedge_clone[id(g)] = (clone, tgt.id, piid)
        if out:
            r = Request(rid=-1, prompt_len=len(g.prompt), true_rl=rl,
                        arrival=g.t_submit, slo_deadline=g.deadline)
            r.generated = len(out)
            r.prompt_done = r.prompt_len
            r.n_preemptions = 1
            r.predicted_rl = tgt.engine.predictor.predict(r)
            scfg = tgt.engine.scheduler.cfg
            r.padded_rl = apply_padding(r.predicted_rl, scfg.pad_ratio,
                                        scfg.bucket)
            if r.padded_rl <= r.generated:
                r.padded_rl = bucketize(r.generated + scfg.bucket,
                                        scfg.bucket)
            payload = {"gen": clone, "req": r, "kv": None,
                       "ctx": len(g.prompt) + len(out) - 1,
                       "last_tok": out[-1], "kv_crc": None,
                       "dkey": self._dkey(clone)}
            self.transport.send(tgt.id, INJECT, payload, now,
                                dkey=payload["dkey"])
            self._pump(tgt, now)
        else:
            self.transport.send(tgt.id, SUBMIT, (clone, now), now,
                                dkey=self._dkey(clone))
            self._pump(tgt, now)

    def _requeue(self, g: GenRequest, now: float, reason: str) -> None:
        att = self._retries.get(id(g), 0)
        if att >= self.recovery.max_retries:
            if id(g) in self._shed_origin:
                self._shed_terminal(g)   # retry tier exhausted: shed, not
                return                   # aborted — exactly-once terminal
            g.status = "aborted"
            g.fail_reason = f"retries-exhausted({reason})"
            self.n_failed_recoveries += 1
            self._salvaged.pop(id(g), None)
            return
        self._retries[id(g)] = att + 1
        delay = backoff_delay(self.recovery, g.rid, att)
        self._redeliver.append((now + delay, g))

    def _deliver_redeliveries(self, now: float) -> None:
        due = [(t, g) for t, g in self._redeliver if t <= now]
        if not due:
            return
        self._redeliver = [(t, g) for t, g in self._redeliver if t > now]
        for _, g in due:
            if g.finished:               # aborted while waiting (deadline)
                self._salvaged.pop(id(g), None)
                continue
            out, eos = g.output, g.params.eos_token
            rl = g.params.max_new_tokens
            if eos is not None and eos in out:
                rl = out.index(eos) + 1
            if len(out) >= rl:
                # everything needed was already drained before the crash
                del out[rl:]
                g.status = "completed"
                g.t_done = now
                self.n_recovered += 1
                self._salvaged.pop(id(g), None)
                continue
            cands = [i for i in self.instances if i.accepts_prompts()] \
                or [i for i in self.instances if i.alive and not i.draining] \
                or [i for i in self.instances if i.alive]
            if not cands:
                self._requeue(g, now, "no-live-instance")  # burns a retry
                continue
            if id(g) in self._shed_origin:
                # shed-retry tier: route only to a peer whose total KVC
                # can fund the frozen exact-alloc demand; if none exists
                # anywhere alive, the shed becomes terminal after all
                total = len(g.prompt) + rl
                fits = [i for i in cands if i.scheduler.fits_ever(total)]
                if not fits:
                    if any(i.alive and i.scheduler.fits_ever(total)
                           for i in self.instances):
                        self._requeue(g, now, "kvc-infeasible")
                    else:
                        self._shed_terminal(g)
                    continue
                cands = fits
                self.n_shed_rescued += 1
            demand = len(g.prompt) + rl - len(out)
            tgt = self.router.choose(cands, demand)
            if out:
                # re-seed through the swap-recompute inject path: the
                # receiver re-prefills prompt + generated-so-far and
                # continues decoding from the last drained token
                r = Request(rid=-1, prompt_len=len(g.prompt), true_rl=rl,
                            arrival=g.t_submit, slo_deadline=g.deadline)
                r.generated = len(out)
                r.prompt_done = r.prompt_len
                r.n_preemptions = 1      # recovery is a forced preemption
                r.predicted_rl = tgt.engine.predictor.predict(r)
                scfg = tgt.engine.scheduler.cfg
                r.padded_rl = apply_padding(r.predicted_rl, scfg.pad_ratio,
                                            scfg.bucket)
                if r.padded_rl <= r.generated:
                    r.padded_rl = bucketize(r.generated + scfg.bucket,
                                            scfg.bucket)
                payload = {"gen": g, "req": r, "kv": None,
                           "ctx": len(g.prompt) + len(out) - 1,
                           "last_tok": out[-1], "kv_crc": None}
                # a salvaged host-pool image whose extent matches the
                # drained tail restores pages instead of recomputing;
                # a mismatch (undrained ring tokens died with the
                # device) falls back — the recompute path regenerates
                # them bit-exactly
                img = self._salvaged.pop(id(g), None)
                if (img is not None and img.get("kv") is not None
                        and img["ctx"] == payload["ctx"]):
                    payload["kv"] = img["kv"]
                    payload["kv_crc"] = img.get("crc")
                    self.n_salvaged_restores += 1
                if self.faults is not None:
                    payload = self.faults.corrupt_payload(payload)
                if self.transport is not None:
                    payload["dkey"] = self._dkey(g)
                    self.transport.send(tgt.id, INJECT, payload, now,
                                        dkey=payload["dkey"])
                    self._pump(tgt, now)
                else:
                    tgt.engine.inject_kv(payload, now)
            else:
                self._salvaged.pop(id(g), None)
                if self.transport is not None:
                    self.transport.send(tgt.id, SUBMIT, (g, g.t_submit),
                                        now, dkey=self._dkey(g))
                    self._pump(tgt, now)
                else:
                    tgt.engine.submit(g, g.t_submit)
            self.route_of[id(g)] = tgt.id    # re-route, not a double route
            self.n_recovered += 1
            if self.hedge is not None and self.hedge.cfg.enabled:
                # re-arm the stall clocks: the new host deserves a full
                # threshold window before being called a straggler
                self.hedge.reset_progress(id(g), len(g.output), now)

    # -- deadline watchdog ---------------------------------------------- #
    def _enforce_deadlines(self, now: float) -> None:
        k = self.recovery.deadline_factor
        for inst in self.instances:
            if not inst.alive:
                continue
            for g in list(inst.engine.requests.values()):
                if g.finished or g.deadline == float("inf"):
                    continue
                if now > g.t_submit + k * (g.deadline - g.t_submit):
                    if inst.engine.abort(g.rid, now, "deadline"):
                        self.n_deadline_aborts += 1
        kept = []
        for t, g in self._redeliver:
            if (not g.finished and g.deadline != float("inf")
                    and now > g.t_submit + k * (g.deadline - g.t_submit)):
                g.status = "aborted"
                g.fail_reason = "deadline"
                self.n_deadline_aborts += 1
            else:
                kept.append((t, g))
        self._redeliver = kept

    # -- migration / evacuation ----------------------------------------- #
    def _decode_targets(self, exclude_id: int = -1) -> List[FleetInstance]:
        cands = [i for i in self.instances
                 if i.accepts_decodes() and i.id != exclude_id]
        if not cands:
            cands = [i for i in self.instances
                     if i.health == HEALTHY
                     and i.role in ("unified", "decode")
                     and i.id != exclude_id]
        return cands

    def _transfer(self, src: FleetInstance, r, tgt: FleetInstance,
                  now: float) -> None:
        payload = src.engine.export_kv(r.rid)
        if not self.kv_migration:
            payload["kv"] = None
        if self.faults is not None:
            payload = self.faults.corrupt_payload(payload)
        if payload["kv"] is None:
            self.n_kv_fallbacks += 1
        if self.transport is not None:
            payload["dkey"] = self._dkey(payload["gen"])
            self.transport.send(tgt.id, INJECT, payload, now,
                                dkey=payload["dkey"])
            self._pump(tgt, now)
        else:
            tgt.engine.inject_kv(payload, now)
        self.route_of[id(payload["gen"])] = tgt.id

    def _migrate_ready(self, inst: FleetInstance, now: float) -> None:
        """Move every queued GT off a prefill engine to a decode engine."""
        if inst.engine._mega_left > 0:
            # only possible when a prior tick had no live decode target and
            # the stranded GTs started decoding here; wait for the window
            return
        sched = inst.engine.scheduler
        for r in list(sched.gt_queue):
            cands = self._decode_targets()
            if not cands:
                return                   # no live receiver; retry next tick
            demand = r.prompt_len + r.remaining_predicted
            tgt = self.decode_router.choose(cands, demand)
            self._transfer(inst, r, tgt, now)
            self.n_migrations += 1

    def _evacuate(self, inst: FleetInstance, now: float) -> None:
        """Drain a frozen instance's *queued* GTs to healthy peers via
        real KV re-migration (its device state is intact, just slow to
        schedule); the running batch rides out the freeze in place."""
        if inst.engine._mega_left > 0:
            return                       # window open: state not exportable
        sched = inst.engine.scheduler
        for r in list(sched.gt_queue):
            cands = self._decode_targets(exclude_id=inst.id)
            if not cands:
                return
            demand = r.prompt_len + r.remaining_predicted
            tgt = self.decode_router.choose(cands, demand)
            self._transfer(inst, r, tgt, now)
            self.n_evacuations += 1

    # ------------------------------------------------------------------ #
    def _spawn(self, now: float) -> None:
        iid = self._next_id
        self._next_id += 1
        inst = FleetInstance(iid, self._make_engine(iid), "unified")
        if self._metrics_registry is not None:
            from repro.obs import MetricsSampler
            MetricsSampler(self._metrics_registry,
                           instance=str(iid)).attach(inst.engine)
        if self.detector is not None:
            inst.detected = True
        if self.recovery.shed_retry:
            inst.engine.fleet_shed_handback = True
        self.instances.append(inst)

    def _autoscale(self, now: float) -> None:
        scaler = self.autoscaler
        # harvest fresh completions for the attainment window
        for inst in self.instances:
            inst.harvest_completions(scaler)
        execute_autoscale(scaler, now, self.instances, self._spawn,
                          self.scale_events)

    # ------------------------------------------------------------------ #
    def run(self, gen_requests: Sequence[GenRequest],
            arrivals: Optional[Sequence[float]] = None,
            max_steps: int = 100_000,
            stall_limit: int = 2_000) -> List[GenRequest]:
        """Serve a batch (or, with ``arrivals``, an online stream on the
        fleet's iteration clock) to completion — the same contract as
        ``ServingEngine.run``, one shared driver."""
        return serve_stream(self, gen_requests, arrivals, max_steps,
                            stall_limit)

    def flush(self) -> None:
        for inst in self.instances:
            if self._steppable(inst):   # zombies drain their fences too
                inst.engine.flush()
        if self._fences:
            self._sweep_fences(0.0)

    # -- liveness / diagnostics ----------------------------------------- #
    def progress_state(self) -> tuple:
        """Monotone fleet fingerprint for the ``serve_stream`` watchdog."""
        insts = tuple((i.id, i.health, i.engine.progress_state())
                      for i in self.instances)
        term = sum(1 for g in self.submitted if g.finished)
        return (insts, term, self.n_migrations, self.n_recovered,
                self.n_evacuations, len(self._redeliver),
                self.n_shed, self.n_shed_reroutes, self.n_shed_rescued,
                0 if self.transport is None else self.transport.pending(),
                0 if self.detector is None
                else len(self.detector.transitions),
                self.n_fenced_completions, len(self._fences),
                0 if self.hedge is None
                else (self.hedge.n_fired, self.hedge.n_won,
                      self.hedge.n_cancelled))

    def attach_metrics(self, registry) -> None:
        """Attach a per-iteration ``MetricsSampler`` to every engine
        (instances spawned later by the autoscaler are attached in
        ``_spawn``). Sampling follows the zero-sync contract: device
        values come only from the lag-N drain ring, host values at the
        step boundary the engine already takes."""
        from repro.obs import MetricsSampler
        self._metrics_registry = registry
        for inst in self.instances:
            MetricsSampler(registry,
                           instance=str(inst.id)).attach(inst.engine)

    def publish_metrics(self, registry) -> None:
        """Publish the whole fleet — every engine (instance-labelled),
        instance lifecycle state, routers, fault-tolerance counters,
        transport and detector — into one ``repro.obs`` registry. This
        is the single publication path behind ``debug_state`` and the
        ``--metrics`` exit dumps."""
        health_g = registry.gauge(
            "fleet_instance_health", "observed health: healthy=0 "
            "suspect=1 dead=2", ("instance",))
        role_g = registry.gauge(
            "fleet_instance_state", "per-instance lifecycle flags",
            ("instance", "flag"))
        for inst in self.instances:
            inst.engine.publish_metrics(registry, instance=str(inst.id))
            health_g.labels(instance=inst.id).set(
                HEALTH_STATES.index(inst.health))
            role_g.labels(instance=inst.id,
                          flag="draining").set(int(inst.draining))
            role_g.labels(instance=inst.id,
                          flag="crashed").set(int(inst.crashed))

        def c(name, help, value):
            registry.counter(name, help).unlabeled.inc_to(value)

        c("fleet_migrations_total", "KV migrations (live image or "
          "recompute fallback)", self.n_migrations)
        c("fleet_kv_fallbacks_total", "migrations that fell back to "
          "swap-recompute", self.n_kv_fallbacks)
        c("fleet_recovered_total", "requests requeued off a dead "
          "instance", self.n_recovered)
        c("fleet_salvaged_restores_total", "redeliveries re-seeded from "
          "a salvaged host-pool image", self.n_salvaged_restores)
        c("fleet_evacuations_total", "queued work evacuated off a "
          "suspect", self.n_evacuations)
        c("fleet_shed_total", "terminal sheds", self.n_shed)
        c("fleet_deadline_aborts_total", "deadline-infeasible aborts",
          self.n_deadline_aborts)
        c("fleet_shed_reroutes_total", "rung-4 hand-backs requeued for "
          "re-route", self.n_shed_reroutes)
        c("fleet_shed_rescued_total", "hand-backs delivered to a "
          "feasible peer", self.n_shed_rescued)
        c("fleet_double_routes_total", "conservation violations (must "
          "stay 0)", self.double_routes)
        c("fleet_fenced_completions_total", "loser-side completions that "
          "landed on a registration fence: counted, never delivered",
          self.n_fenced_completions)
        c("fleet_stale_drops_total", "stale-epoch deliveries fenced at "
          "the pump", self.n_stale_drops)
        if self.hedge is not None:
            self.hedge.publish_metrics(registry)
        registry.gauge("fleet_redeliver_queue_depth",
                       "recoveries awaiting backoff expiry") \
            .unlabeled.set(len(self._redeliver))
        self.router.publish_metrics(registry, side="arrival")
        self.decode_router.publish_metrics(registry, side="decode")
        if self.autoscaler is not None:
            self.autoscaler.publish_metrics(registry)
        if self.transport is not None:
            tfam = registry.counter("transport_messages_total",
                                    "lossy-transport events by kind",
                                    ("kind",))
            tfam.labels(kind="dropped").inc_to(self.transport.n_dropped)
            tfam.labels(kind="duplicated").inc_to(
                self.transport.n_duplicated)
            tfam.labels(kind="delayed").inc_to(self.transport.n_delayed)
            tfam.labels(kind="retransmits").inc_to(
                self.transport.n_retransmits)
            tfam.labels(kind="partition_lost").inc_to(
                self.transport.n_partition_lost)
            tfam.labels(kind="partition_held").inc_to(
                self.transport.n_partition_held)
            registry.gauge("transport_pending_messages",
                           "messages in flight") \
                .unlabeled.set(self.transport.pending())
        if self.detector is not None:
            self.detector.publish_metrics(registry, self.instances)

    def debug_state(self) -> Dict[str, object]:
        """Stall post-mortem: per-instance health *as observed* (detected
        mode: heartbeat age + crashed ground truth), fault-tolerance
        counters and in-flight transport/redelivery queues — derived
        from one registry snapshot (the same publication path live
        metrics use), so stall diagnostics and metrics can never
        disagree. The two append-only event logs (fired faults, detector
        transitions) ride along verbatim: they are post-mortem context,
        not scalar samples."""
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        self.publish_metrics(reg)
        state: Dict[str, object] = dict(reg.snapshot().flat())
        if self.faults is not None:
            state["faults_fired"] = list(self.faults.log)
        if self.detector is not None:
            state["detector_transitions"] = list(self.detector.transitions)
        return state

    # ------------------------------------------------------------------ #
    def completed_requests(self) -> List[Request]:
        """Scheduler-side Request records across all engines (TTFT etc.)."""
        return [r for inst in self.instances
                for r in inst.engine.scheduler.completed]

    def conservation(self) -> Dict[str, int]:
        """Every submitted request reached exactly one terminal state."""
        done = aborted = shed = 0
        for g in self.submitted:
            status = getattr(g, "status", None)
            if status == "completed" or (status is None
                                         and g.t_done is not None):
                done += 1
            elif status == "aborted":
                aborted += 1
            elif status == "shed":
                shed += 1
        pending = len(self.submitted) - done - aborted - shed
        return {"submitted": len(self.submitted),
                "completed": done,
                "aborted": aborted,
                "shed": shed,
                "pending": pending,
                "double_routes": self.double_routes,
                "migrations": self.n_migrations,
                "recovered": self.n_recovered,
                "salvaged": self.n_salvaged_restores,
                "evacuations": self.n_evacuations,
                "kv_rejects": sum(i.engine.n_kv_rejects
                                  for i in self.instances),
                "shed_reroutes": self.n_shed_reroutes,
                "shed_rescued": self.n_shed_rescued,
                "dup_deliveries": sum(i.engine.n_dup_deliveries
                                      for i in self.instances),
                "dup_completions": sum(i.engine.n_dup_completions
                                       for i in self.instances),
                "fenced_completions": self.n_fenced_completions,
                "stale_drops": self.n_stale_drops,
                "hedges_fired": 0 if self.hedge is None
                else self.hedge.n_fired,
                "hedges_won": 0 if self.hedge is None
                else self.hedge.n_won,
                "hedges_cancelled": 0 if self.hedge is None
                else self.hedge.n_cancelled,
                "ok": int(self.double_routes == 0 and pending == 0)}
