"""Real-engine fleet: N in-process ``ServingEngine`` instances behind one
event loop, one router, and (optionally) disaggregated prefill/decode
roles with live KV migration.

The fleet owns the request stream: ``submit`` routes each ``GenRequest``
to exactly one engine (conservation-guarded — a request object is never
routed twice), ``step`` advances every engine that has work on a shared
iteration clock, then sweeps prefill-role engines for finished prompts and
migrates them: ``engine.export_kv`` extracts the request's cache pages and
carried slot state, ``engine.inject_kv`` seeds them into a decode engine
chosen by the decode-side router. Engines that cannot produce a portable
KV image (recurrent stacks, ring caches) — or a receiver without a free
slot / KVC room — fall back transparently to the engine's existing
swap-recompute path; either way the greedy token stream is identical to
serving the request on a single engine (``tests/test_cluster.py``).

Model parameters are built once and shared by every engine (caches, slots
and schedulers stay per-engine), so an N-instance fleet costs N caches,
not N models. An optional ``GoodputAutoscaler`` is polled once per loop
tick: +1 spawns a fresh unified engine from the shared parameters, -1
marks one draining (no new routes; it retires via ``has_work``).

Fault tolerance (``faults``/``recovery`` kwargs):

  * an optional ``FaultInjector`` is polled every tick; it crashes,
    freezes, or slows instances (``InstanceBase`` health lifecycle) and
    corrupts KV payloads in flight (caught by the checksum at inject);
  * **crash recovery** — when an instance dies, every in-flight request
    on it is reclaimed and redelivered with bounded retries and
    exponential backoff (optionally jittered — ``RecoveryConfig.jitter``
    — to spread the retry herd, deterministically under a fixed seed).
    A host-offloaded KV image that survived the crash (the device state
    is gone, the host pool is not) is salvaged and re-seeded into the
    receiving engine; otherwise a request with generated tokens goes
    through the swap-recompute path (greedy decoding regenerates the
    lost tail bit-exactly), and one with none is simply resubmitted at
    its original arrival time;
  * **degradation** — a frozen (suspect) instance keeps its device state,
    so its *queued* GTs are evacuated by real KV re-migration while its
    running batch waits for the thaw;
  * **deadline watchdog / shedding** — ``RecoveryConfig.deadline_factor``
    aborts requests a multiple past their SLO deadline;
    ``RecoveryConfig.shed`` fast-fails admissions whose projected finish
    already misses it (typed ``RequestShed``).

``repro.cluster.faults.check_fleet_invariants`` audits the terminal
exactly-once + zero-leak contract after any run, chaotic or not.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core.predictor import apply_padding, bucketize
from repro.core.request import Request
from repro.models import model
from repro.models.config import ModelConfig
from repro.serving import GenRequest, RequestShed, ServingEngine
from repro.serving.engine import serve_stream

from .autoscale import GoodputAutoscaler
from .base import (DetectorConfig, FailureDetector, HEALTH_STATES,
                   HEALTHY, SUSPECT, InstanceBase, ROLES,
                   execute_autoscale, validate_roles)
from .faults import FaultInjector, RecoveryConfig, backoff_delay
from .router import Router, make_router
from .transport import INJECT, SUBMIT, Transport

__all__ = ["EngineFleet", "FleetInstance", "ROLES"]


class FleetInstance(InstanceBase):
    """One engine plus its routing-visible stats (InstanceStats)."""

    def __init__(self, iid: int, engine: ServingEngine,
                 role: str = "unified"):
        super().__init__(iid, role)
        self.engine = engine

    @property
    def scheduler(self):
        return self.engine.scheduler

    def squeeze_kvc(self, frac: float) -> int:
        # the engine defers a mid-megastep cut to the window boundary
        return self.engine.squeeze_kvc(frac)


class EngineFleet:
    def __init__(self, cfg: ModelConfig, n_instances: int = 2, *,
                 roles: Optional[Sequence[str]] = None,
                 router: str = "least-kvc", seed: int = 0,
                 kv_migration: bool = True,
                 autoscaler: Optional[GoodputAutoscaler] = None,
                 faults: Optional[FaultInjector] = None,
                 recovery: Optional[RecoveryConfig] = None,
                 detector: Optional[DetectorConfig] = None,
                 **engine_kwargs):
        """``engine_kwargs`` are forwarded to every ``ServingEngine``
        (max_batch, capacity, scheduler_cfg, engine_cfg, impl, ...).
        ``kv_migration=False`` forces the swap-recompute fallback for every
        migration (the reference path the KV image is tested against).
        Fleet size under autoscaling is bounded by the scaler's
        ``AutoscaleConfig.max_instances``. ``faults=None`` (the default)
        leaves every fault-tolerance path dormant: no injector polls, no
        recovery bookkeeping touches the hot loop.

        ``detector`` switches the fleet from *declared* to *detected*
        failure: every routed message (submit / KV inject) travels
        through a seeded lossy :class:`Transport`, instances heartbeat
        through it, and the :class:`FailureDetector` owns observed
        health (missed-beat patience -> suspect, lease expiry -> dead,
        fresh beat -> reinstated). An attached injector stops declaring
        health and merely crashes/freezes instances; its drop/dup/delay
        events open transport fault windows. With no fault events the
        detector-on path is bitwise-identical to the direct path: beats
        are pure host-side bookkeeping and the transport delivers
        same-tick FIFO."""
        self.cfg = cfg
        self.kv_migration = kv_migration
        self.engine_kwargs = dict(engine_kwargs)
        self.params = model.init(cfg, jax.random.PRNGKey(seed))
        self._seed = seed
        roles = validate_roles(roles, n_instances)
        self.instances: List[FleetInstance] = [
            FleetInstance(i, self._make_engine(i), roles[i])
            for i in range(n_instances)]
        self.router: Router = make_router(router, seed)
        self.decode_router: Router = make_router(router, seed + 1)
        self.autoscaler = autoscaler
        self.faults = faults
        self.recovery = recovery or RecoveryConfig()
        # detection-and-delivery substrate (None = legacy direct calls)
        self.detector_cfg = detector
        self.transport = Transport(seed=seed + 7) \
            if detector is not None else None
        self.detector = FailureDetector(detector, self.transport) \
            if detector is not None else None
        if self.detector is not None:
            for inst in self.instances:
                inst.detected = True
            if self.faults is not None:
                self.faults.detected = True
                self.faults.transport = self.transport
        if self.recovery.shed_retry:
            for inst in self.instances:
                inst.engine.fleet_shed_handback = True
        # at-least-once delivery epochs: each intentional (re)delivery of
        # a GenRequest gets a fresh key; transport dups share the key and
        # are suppressed at the engine boundary
        self._epoch: Dict[int, int] = {}
        # conservation accounting: a GenRequest is routed exactly once
        self.route_of: Dict[int, int] = {}       # id(GenRequest) -> iid
        self.submitted: List[GenRequest] = []
        self.double_routes = 0
        self.n_migrations = 0
        self.n_kv_fallbacks = 0
        self._metrics_registry = None
        self.scale_events: List[Tuple[float, int]] = []
        self._next_id = n_instances
        # crash recovery state
        self._redeliver: List[Tuple[float, GenRequest]] = []
        self._retries: Dict[int, int] = {}       # id(GenRequest) -> attempts
        self._dead_handled: set = set()          # instance ids reclaimed
        # host-pool KV images harvested off dead engines, keyed by
        # id(GenRequest): redelivery re-seeds pages instead of recomputing
        self._salvaged: Dict[int, dict] = {}
        self.n_salvaged_restores = 0
        self.n_recovered = 0
        self.n_failed_recoveries = 0
        self.n_evacuations = 0
        self.n_shed = 0
        self.n_deadline_aborts = 0
        # shed-retry tier: rung-4 kvc-infeasible hand-backs re-routed
        # fleet-wide instead of shed terminally
        self._shed_origin: set = set()   # id(GenRequest) in the retry tier
        self.n_shed_reroutes = 0         # hand-backs requeued for re-route
        self.n_shed_rescued = 0          # delivered to a feasible peer

    def _make_engine(self, i: int) -> ServingEngine:
        return ServingEngine(self.cfg, params=self.params,
                             seed=self._seed + i, **self.engine_kwargs)

    # ------------------------------------------------------------------ #
    def submit(self, req: GenRequest, now: float) -> int:
        """Route and submit one request; returns the serving instance id.
        Raises ``RequestShed`` (after recording the terminal state) when
        admission control projects an unavoidable SLO miss, or when no
        live instance exists to serve it."""
        if id(req) in self.route_of:
            self.double_routes += 1
        cands = [i for i in self.instances if i.accepts_prompts()]
        if not cands:
            cands = [i for i in self.instances
                     if i.alive and i.role in ("unified", "prefill")]
        if not cands:
            return self._shed(req, now, "no-live-instance")
        demand = len(req.prompt) + req.params.max_new_tokens
        inst = self.router.choose(cands, demand)
        if self.recovery.shed and req.deadline != float("inf"):
            # projected finish on the chosen instance, on the fleet's
            # iteration clock: drain the backlog (~1 token/slot/iter),
            # then produce this request's own tokens
            backlog = inst.outstanding_tokens() / max(1, inst.engine.max_batch)
            eta = now + (backlog + len(req.prompt) / 64.0
                         + req.params.max_new_tokens) \
                * self.recovery.shed_headroom
            if eta > req.deadline:
                return self._shed(req, now, "projected-slo-miss")
        if self.transport is not None:
            # routed decision is made here; the delivery itself rides the
            # (lossy) transport — a clean link delivers synchronously in
            # the pump below (bit-for-bit the direct path), a faulted one
            # leaves it in flight for a later tick's sweep
            inst.engine.validate(req)
            self.transport.send(inst.id, SUBMIT, (req, now), now,
                                dkey=self._dkey(req))
            self._pump(inst, now)
        else:
            inst.engine.submit(req, now)
        self.route_of[id(req)] = inst.id
        self.submitted.append(req)
        return inst.id

    def _dkey(self, g: GenRequest) -> tuple:
        """Fresh delivery key (epoch) for one intentional (re)delivery."""
        ep = self._epoch.get(id(g), 0) + 1
        self._epoch[id(g)] = ep
        return (id(g), ep)

    def _shed(self, req: GenRequest, now: float, reason: str) -> int:
        req.t_submit = now
        req.status = "shed"
        req.fail_reason = reason
        self.submitted.append(req)
        self.n_shed += 1
        raise RequestShed(req, reason)

    def has_work(self) -> bool:
        return (any(i.alive and i.engine.has_work()
                    for i in self.instances)
                or bool(self._redeliver)
                or any(i.engine.shed_handback for i in self.instances)
                or (self.transport is not None
                    and self.transport.pending() > 0))

    # ------------------------------------------------------------------ #
    def step(self, now: Optional[float] = None) -> int:
        """One fleet tick: inject scheduled faults, run heartbeat/lease
        detection and deliver in-flight transport messages, reclaim and
        redeliver crashed work, enforce deadlines, step every live engine
        with work, sweep rung-4 shed hand-backs into the retry tier, then
        migrate finished prompts off prefill-role engines. Returns
        completions."""
        now = time.monotonic() if now is None else now
        if self.faults is not None:
            self.faults.poll(now, self.instances)
        if self.detector is not None:
            for inst in self.instances:
                inst.maybe_beat(self.transport, now,
                                self.detector.cfg.beat_every)
            self.detector.observe(now, self.instances)
            self._deliver_transport(now)
        self._reclaim_dead(now)
        if self._redeliver:
            self._deliver_redeliveries(now)
        if self.recovery.deadline_factor > 0:
            self._enforce_deadlines(now)
        done = 0
        for inst in self.instances:
            inst.update_health(now)
            if inst.alive and inst.engine.has_work() and inst.can_step(now):
                done += inst.engine.step(now)
        if self.recovery.shed_retry:
            self._retry_sheds(now)
        for inst in self.instances:
            if not inst.alive or inst.crashed:
                continue
            if inst.role == "prefill" and inst.health == HEALTHY:
                self._migrate_ready(inst, now)
            elif inst.health == SUSPECT and now < inst.frozen_until:
                # frozen-but-reachable: evacuate queued GTs by real KV
                # re-migration so they decode elsewhere during the outage
                self._evacuate(inst, now)
        if self.autoscaler is not None:
            self._autoscale(now)
        return done

    # -- transport delivery / shed-retry tier ---------------------------- #
    def _deliver_transport(self, now: float) -> None:
        for inst in self.instances:
            self._pump(inst, now)

    def _pump(self, inst: FleetInstance, now: float) -> None:
        """Drain one instance's due in-flight messages. Senders pump the
        recipient right after ``transport.send`` — a clean link delivers
        synchronously, reproducing the direct-call path bit-for-bit —
        and the per-tick sweep picks up delayed/retransmitted copies. A
        message landing on an instance already declared dead is
        orphaned: if the fleet still thinks the request lives there it
        re-enters recovery; stale copies of work re-routed since
        (fencing) are dropped."""
        for msg in self.transport.recv(inst.id, now):
            if msg.kind == SUBMIT:
                g, t_arr = msg.payload
            else:
                g, t_arr = msg.payload["gen"], now
            if not inst.alive:
                if (not g.finished
                        and self.route_of.get(id(g)) == inst.id):
                    if (msg.kind == INJECT
                            and msg.payload.get("kv") is not None):
                        # the image in flight is as salvageable as a
                        # host-pool one: restore instead of recompute
                        self._salvaged[id(g)] = {
                            "kv": msg.payload["kv"],
                            "ctx": msg.payload["ctx"],
                            "crc": msg.payload.get("kv_crc")}
                    self._requeue(g, now, "undeliverable")
                continue
            if msg.kind == SUBMIT:
                inst.engine.submit(g, t_arr, dkey=msg.dkey)
            else:
                inst.engine.inject_kv(msg.payload, now)

    def _retry_sheds(self, now: float) -> None:
        """Sweep rung-4 ``kvc-infeasible`` hand-backs into the fleet
        retry tier: a request whose frozen exact-alloc demand some live
        peer's total KVC can still fund is requeued for a router-level
        re-route (bounded retries + the existing jittered backoff); one
        no live peer can *ever* fit is shed terminally — same contract,
        decided fleet-globally instead of per-instance."""
        for inst in self.instances:
            if not inst.engine.shed_handback:
                continue
            handed, inst.engine.shed_handback = \
                inst.engine.shed_handback, []
            for g in handed:
                self._shed_origin.add(id(g))
                demand = len(g.prompt) + g.params.max_new_tokens
                if any(i.alive and i.scheduler.fits_ever(demand)
                       for i in self.instances):
                    self.n_shed_reroutes += 1
                    self._requeue(g, now, "kvc-infeasible")
                else:
                    self._shed_terminal(g)

    def _shed_terminal(self, g: GenRequest) -> None:
        g.status = "shed"
        g.fail_reason = "kvc-infeasible"
        self.n_shed += 1
        self._salvaged.pop(id(g), None)

    # -- crash recovery ------------------------------------------------- #
    def _reclaim_dead(self, now: float) -> None:
        """Sweep newly-dead instances: every non-terminal request they
        held is queued for redelivery (bounded retries + backoff). The
        dead engine's undrained ring tokens are dropped — device state is
        gone; greedy recompute regenerates them bit-exactly."""
        for inst in self.instances:
            if inst.alive or inst.id in self._dead_handled:
                continue
            self._dead_handled.add(inst.id)
            eng = inst.engine
            eng._pending_drain.clear()       # ring state died with the device
            victims = [g for g in eng.requests.values() if not g.finished]
            # host-offloaded KV images outlive the device: harvest them so
            # redelivery restores pages instead of recomputing
            for rid, img in eng._host_swap.items():
                g = eng.requests.get(rid)
                if g is not None and not g.finished:
                    self._salvaged[id(g)] = img
            eng._host_swap.clear()
            for payload, _ in eng._pending_injects:   # migrated in, unapplied
                if not payload["gen"].finished:
                    victims.append(payload["gen"])
                    if payload.get("kv") is not None:
                        # an in-flight KV image is just as salvageable
                        self._salvaged[id(payload["gen"])] = {
                            "kv": payload["kv"], "ctx": payload["ctx"],
                            "crc": payload.get("kv_crc")}
            eng._pending_injects.clear()
            eng._pending_aborts.clear()
            for g in victims:
                self._requeue(g, now, "crash")
            if self.autoscaler is not None:
                self.autoscaler.invalidate()

    def _requeue(self, g: GenRequest, now: float, reason: str) -> None:
        att = self._retries.get(id(g), 0)
        if att >= self.recovery.max_retries:
            if id(g) in self._shed_origin:
                self._shed_terminal(g)   # retry tier exhausted: shed, not
                return                   # aborted — exactly-once terminal
            g.status = "aborted"
            g.fail_reason = f"retries-exhausted({reason})"
            self.n_failed_recoveries += 1
            self._salvaged.pop(id(g), None)
            return
        self._retries[id(g)] = att + 1
        delay = backoff_delay(self.recovery, g.rid, att)
        self._redeliver.append((now + delay, g))

    def _deliver_redeliveries(self, now: float) -> None:
        due = [(t, g) for t, g in self._redeliver if t <= now]
        if not due:
            return
        self._redeliver = [(t, g) for t, g in self._redeliver if t > now]
        for _, g in due:
            if g.finished:               # aborted while waiting (deadline)
                self._salvaged.pop(id(g), None)
                continue
            out, eos = g.output, g.params.eos_token
            rl = g.params.max_new_tokens
            if eos is not None and eos in out:
                rl = out.index(eos) + 1
            if len(out) >= rl:
                # everything needed was already drained before the crash
                del out[rl:]
                g.status = "completed"
                g.t_done = now
                self.n_recovered += 1
                self._salvaged.pop(id(g), None)
                continue
            cands = [i for i in self.instances if i.accepts_prompts()] \
                or [i for i in self.instances if i.alive and not i.draining] \
                or [i for i in self.instances if i.alive]
            if not cands:
                self._requeue(g, now, "no-live-instance")  # burns a retry
                continue
            if id(g) in self._shed_origin:
                # shed-retry tier: route only to a peer whose total KVC
                # can fund the frozen exact-alloc demand; if none exists
                # anywhere alive, the shed becomes terminal after all
                total = len(g.prompt) + rl
                fits = [i for i in cands if i.scheduler.fits_ever(total)]
                if not fits:
                    if any(i.alive and i.scheduler.fits_ever(total)
                           for i in self.instances):
                        self._requeue(g, now, "kvc-infeasible")
                    else:
                        self._shed_terminal(g)
                    continue
                cands = fits
                self.n_shed_rescued += 1
            demand = len(g.prompt) + rl - len(out)
            tgt = self.router.choose(cands, demand)
            if out:
                # re-seed through the swap-recompute inject path: the
                # receiver re-prefills prompt + generated-so-far and
                # continues decoding from the last drained token
                r = Request(rid=-1, prompt_len=len(g.prompt), true_rl=rl,
                            arrival=g.t_submit, slo_deadline=g.deadline)
                r.generated = len(out)
                r.prompt_done = r.prompt_len
                r.n_preemptions = 1      # recovery is a forced preemption
                r.predicted_rl = tgt.engine.predictor.predict(r)
                scfg = tgt.engine.scheduler.cfg
                r.padded_rl = apply_padding(r.predicted_rl, scfg.pad_ratio,
                                            scfg.bucket)
                if r.padded_rl <= r.generated:
                    r.padded_rl = bucketize(r.generated + scfg.bucket,
                                            scfg.bucket)
                payload = {"gen": g, "req": r, "kv": None,
                           "ctx": len(g.prompt) + len(out) - 1,
                           "last_tok": out[-1], "kv_crc": None}
                # a salvaged host-pool image whose extent matches the
                # drained tail restores pages instead of recomputing;
                # a mismatch (undrained ring tokens died with the
                # device) falls back — the recompute path regenerates
                # them bit-exactly
                img = self._salvaged.pop(id(g), None)
                if (img is not None and img.get("kv") is not None
                        and img["ctx"] == payload["ctx"]):
                    payload["kv"] = img["kv"]
                    payload["kv_crc"] = img.get("crc")
                    self.n_salvaged_restores += 1
                if self.faults is not None:
                    payload = self.faults.corrupt_payload(payload)
                if self.transport is not None:
                    payload["dkey"] = self._dkey(g)
                    self.transport.send(tgt.id, INJECT, payload, now,
                                        dkey=payload["dkey"])
                    self._pump(tgt, now)
                else:
                    tgt.engine.inject_kv(payload, now)
            else:
                self._salvaged.pop(id(g), None)
                if self.transport is not None:
                    self.transport.send(tgt.id, SUBMIT, (g, g.t_submit),
                                        now, dkey=self._dkey(g))
                    self._pump(tgt, now)
                else:
                    tgt.engine.submit(g, g.t_submit)
            self.route_of[id(g)] = tgt.id    # re-route, not a double route
            self.n_recovered += 1

    # -- deadline watchdog ---------------------------------------------- #
    def _enforce_deadlines(self, now: float) -> None:
        k = self.recovery.deadline_factor
        for inst in self.instances:
            if not inst.alive:
                continue
            for g in list(inst.engine.requests.values()):
                if g.finished or g.deadline == float("inf"):
                    continue
                if now > g.t_submit + k * (g.deadline - g.t_submit):
                    if inst.engine.abort(g.rid, now, "deadline"):
                        self.n_deadline_aborts += 1
        kept = []
        for t, g in self._redeliver:
            if (not g.finished and g.deadline != float("inf")
                    and now > g.t_submit + k * (g.deadline - g.t_submit)):
                g.status = "aborted"
                g.fail_reason = "deadline"
                self.n_deadline_aborts += 1
            else:
                kept.append((t, g))
        self._redeliver = kept

    # -- migration / evacuation ----------------------------------------- #
    def _decode_targets(self, exclude_id: int = -1) -> List[FleetInstance]:
        cands = [i for i in self.instances
                 if i.accepts_decodes() and i.id != exclude_id]
        if not cands:
            cands = [i for i in self.instances
                     if i.health == HEALTHY
                     and i.role in ("unified", "decode")
                     and i.id != exclude_id]
        return cands

    def _transfer(self, src: FleetInstance, r, tgt: FleetInstance,
                  now: float) -> None:
        payload = src.engine.export_kv(r.rid)
        if not self.kv_migration:
            payload["kv"] = None
        if self.faults is not None:
            payload = self.faults.corrupt_payload(payload)
        if payload["kv"] is None:
            self.n_kv_fallbacks += 1
        if self.transport is not None:
            payload["dkey"] = self._dkey(payload["gen"])
            self.transport.send(tgt.id, INJECT, payload, now,
                                dkey=payload["dkey"])
            self._pump(tgt, now)
        else:
            tgt.engine.inject_kv(payload, now)
        self.route_of[id(payload["gen"])] = tgt.id

    def _migrate_ready(self, inst: FleetInstance, now: float) -> None:
        """Move every queued GT off a prefill engine to a decode engine."""
        if inst.engine._mega_left > 0:
            # only possible when a prior tick had no live decode target and
            # the stranded GTs started decoding here; wait for the window
            return
        sched = inst.engine.scheduler
        for r in list(sched.gt_queue):
            cands = self._decode_targets()
            if not cands:
                return                   # no live receiver; retry next tick
            demand = r.prompt_len + r.remaining_predicted
            tgt = self.decode_router.choose(cands, demand)
            self._transfer(inst, r, tgt, now)
            self.n_migrations += 1

    def _evacuate(self, inst: FleetInstance, now: float) -> None:
        """Drain a frozen instance's *queued* GTs to healthy peers via
        real KV re-migration (its device state is intact, just slow to
        schedule); the running batch rides out the freeze in place."""
        if inst.engine._mega_left > 0:
            return                       # window open: state not exportable
        sched = inst.engine.scheduler
        for r in list(sched.gt_queue):
            cands = self._decode_targets(exclude_id=inst.id)
            if not cands:
                return
            demand = r.prompt_len + r.remaining_predicted
            tgt = self.decode_router.choose(cands, demand)
            self._transfer(inst, r, tgt, now)
            self.n_evacuations += 1

    # ------------------------------------------------------------------ #
    def _spawn(self, now: float) -> None:
        iid = self._next_id
        self._next_id += 1
        inst = FleetInstance(iid, self._make_engine(iid), "unified")
        if self._metrics_registry is not None:
            from repro.obs import MetricsSampler
            MetricsSampler(self._metrics_registry,
                           instance=str(iid)).attach(inst.engine)
        if self.detector is not None:
            inst.detected = True
        if self.recovery.shed_retry:
            inst.engine.fleet_shed_handback = True
        self.instances.append(inst)

    def _autoscale(self, now: float) -> None:
        scaler = self.autoscaler
        # harvest fresh completions for the attainment window
        for inst in self.instances:
            inst.harvest_completions(scaler)
        execute_autoscale(scaler, now, self.instances, self._spawn,
                          self.scale_events)

    # ------------------------------------------------------------------ #
    def run(self, gen_requests: Sequence[GenRequest],
            arrivals: Optional[Sequence[float]] = None,
            max_steps: int = 100_000,
            stall_limit: int = 2_000) -> List[GenRequest]:
        """Serve a batch (or, with ``arrivals``, an online stream on the
        fleet's iteration clock) to completion — the same contract as
        ``ServingEngine.run``, one shared driver."""
        return serve_stream(self, gen_requests, arrivals, max_steps,
                            stall_limit)

    def flush(self) -> None:
        for inst in self.instances:
            if inst.alive:
                inst.engine.flush()

    # -- liveness / diagnostics ----------------------------------------- #
    def progress_state(self) -> tuple:
        """Monotone fleet fingerprint for the ``serve_stream`` watchdog."""
        insts = tuple((i.id, i.health, i.engine.progress_state())
                      for i in self.instances)
        term = sum(1 for g in self.submitted if g.finished)
        return (insts, term, self.n_migrations, self.n_recovered,
                self.n_evacuations, len(self._redeliver),
                self.n_shed, self.n_shed_reroutes, self.n_shed_rescued,
                0 if self.transport is None else self.transport.pending(),
                0 if self.detector is None
                else len(self.detector.transitions))

    def attach_metrics(self, registry) -> None:
        """Attach a per-iteration ``MetricsSampler`` to every engine
        (instances spawned later by the autoscaler are attached in
        ``_spawn``). Sampling follows the zero-sync contract: device
        values come only from the lag-N drain ring, host values at the
        step boundary the engine already takes."""
        from repro.obs import MetricsSampler
        self._metrics_registry = registry
        for inst in self.instances:
            MetricsSampler(registry,
                           instance=str(inst.id)).attach(inst.engine)

    def publish_metrics(self, registry) -> None:
        """Publish the whole fleet — every engine (instance-labelled),
        instance lifecycle state, routers, fault-tolerance counters,
        transport and detector — into one ``repro.obs`` registry. This
        is the single publication path behind ``debug_state`` and the
        ``--metrics`` exit dumps."""
        health_g = registry.gauge(
            "fleet_instance_health", "observed health: healthy=0 "
            "suspect=1 dead=2", ("instance",))
        role_g = registry.gauge(
            "fleet_instance_state", "per-instance lifecycle flags",
            ("instance", "flag"))
        for inst in self.instances:
            inst.engine.publish_metrics(registry, instance=str(inst.id))
            health_g.labels(instance=inst.id).set(
                HEALTH_STATES.index(inst.health))
            role_g.labels(instance=inst.id,
                          flag="draining").set(int(inst.draining))
            role_g.labels(instance=inst.id,
                          flag="crashed").set(int(inst.crashed))

        def c(name, help, value):
            registry.counter(name, help).unlabeled.inc_to(value)

        c("fleet_migrations_total", "KV migrations (live image or "
          "recompute fallback)", self.n_migrations)
        c("fleet_kv_fallbacks_total", "migrations that fell back to "
          "swap-recompute", self.n_kv_fallbacks)
        c("fleet_recovered_total", "requests requeued off a dead "
          "instance", self.n_recovered)
        c("fleet_salvaged_restores_total", "redeliveries re-seeded from "
          "a salvaged host-pool image", self.n_salvaged_restores)
        c("fleet_evacuations_total", "queued work evacuated off a "
          "suspect", self.n_evacuations)
        c("fleet_shed_total", "terminal sheds", self.n_shed)
        c("fleet_deadline_aborts_total", "deadline-infeasible aborts",
          self.n_deadline_aborts)
        c("fleet_shed_reroutes_total", "rung-4 hand-backs requeued for "
          "re-route", self.n_shed_reroutes)
        c("fleet_shed_rescued_total", "hand-backs delivered to a "
          "feasible peer", self.n_shed_rescued)
        c("fleet_double_routes_total", "conservation violations (must "
          "stay 0)", self.double_routes)
        registry.gauge("fleet_redeliver_queue_depth",
                       "recoveries awaiting backoff expiry") \
            .unlabeled.set(len(self._redeliver))
        self.router.publish_metrics(registry, side="arrival")
        self.decode_router.publish_metrics(registry, side="decode")
        if self.autoscaler is not None:
            self.autoscaler.publish_metrics(registry)
        if self.transport is not None:
            tfam = registry.counter("transport_messages_total",
                                    "lossy-transport events by kind",
                                    ("kind",))
            tfam.labels(kind="dropped").inc_to(self.transport.n_dropped)
            tfam.labels(kind="duplicated").inc_to(
                self.transport.n_duplicated)
            tfam.labels(kind="delayed").inc_to(self.transport.n_delayed)
            tfam.labels(kind="retransmits").inc_to(
                self.transport.n_retransmits)
            registry.gauge("transport_pending_messages",
                           "messages in flight") \
                .unlabeled.set(self.transport.pending())
        if self.detector is not None:
            self.detector.publish_metrics(registry, self.instances)

    def debug_state(self) -> Dict[str, object]:
        """Stall post-mortem: per-instance health *as observed* (detected
        mode: heartbeat age + crashed ground truth), fault-tolerance
        counters and in-flight transport/redelivery queues — derived
        from one registry snapshot (the same publication path live
        metrics use), so stall diagnostics and metrics can never
        disagree. The two append-only event logs (fired faults, detector
        transitions) ride along verbatim: they are post-mortem context,
        not scalar samples."""
        from repro.obs import MetricsRegistry
        reg = MetricsRegistry()
        self.publish_metrics(reg)
        state: Dict[str, object] = dict(reg.snapshot().flat())
        if self.faults is not None:
            state["faults_fired"] = list(self.faults.log)
        if self.detector is not None:
            state["detector_transitions"] = list(self.detector.transitions)
        return state

    # ------------------------------------------------------------------ #
    def completed_requests(self) -> List[Request]:
        """Scheduler-side Request records across all engines (TTFT etc.)."""
        return [r for inst in self.instances
                for r in inst.engine.scheduler.completed]

    def conservation(self) -> Dict[str, int]:
        """Every submitted request reached exactly one terminal state."""
        done = aborted = shed = 0
        for g in self.submitted:
            status = getattr(g, "status", None)
            if status == "completed" or (status is None
                                         and g.t_done is not None):
                done += 1
            elif status == "aborted":
                aborted += 1
            elif status == "shed":
                shed += 1
        pending = len(self.submitted) - done - aborted - shed
        return {"submitted": len(self.submitted),
                "completed": done,
                "aborted": aborted,
                "shed": shed,
                "pending": pending,
                "double_routes": self.double_routes,
                "migrations": self.n_migrations,
                "recovered": self.n_recovered,
                "salvaged": self.n_salvaged_restores,
                "evacuations": self.n_evacuations,
                "kv_rejects": sum(i.engine.n_kv_rejects
                                  for i in self.instances),
                "shed_reroutes": self.n_shed_reroutes,
                "shed_rescued": self.n_shed_rescued,
                "dup_deliveries": sum(i.engine.n_dup_deliveries
                                      for i in self.instances),
                "dup_completions": sum(i.engine.n_dup_completions
                                       for i in self.instances),
                "ok": int(self.double_routes == 0 and pending == 0)}
