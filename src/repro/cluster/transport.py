"""Unreliable message transport between the fleet/router and instances.

Every control-plane message — heartbeats, routed submissions, KV-inject
payloads — travels through a per-destination delivery queue keyed on
delivery time. Scripted fault windows (the ``drop``/``dup``/``delay``
chaos kinds) perturb each send with a seeded rng:

  * ``drop``  — the message is lost on the wire. Data-plane messages are
    *retransmitted* after ``retransmit_after`` (at-least-once delivery:
    the sender keeps the message until acknowledged; we model the retry
    timer, not the ACK round-trip). Heartbeats are fire-and-forget — a
    dropped beat is simply missing, which is what drives the failure
    detector's false suspects.
  * ``dup``   — the message is delivered twice (retransmit racing a slow
    ACK). Both copies carry the same delivery key (``dkey``), so the
    receiver's idempotency table suppresses the second.
  * ``delay`` — delivery is deferred by the window's delay; messages
    sent later through a clean link can overtake it (reordering falls
    out of the queue ordering, it is not a separate fault).
  * ``part``  — a network partition: instance ``a`` is cut off from the
    side holding instance ``b`` **and the fleet control plane** (the
    router/detector hub all control traffic transits). The two sides
    are asymmetric — a minority of one against the rest of the fleet —
    and so are the two directions: ``a``'s outbound heartbeats are
    fire-and-forget and simply *lost* (which is what drives the
    detector to suspect and eventually declare it dead, while the
    instance itself keeps stepping as a zombie), whereas data-plane
    messages crossing the cut in either direction are *held* by the
    sender's retry timer and land just after the heal (at-least-once
    delivery: the sender keeps retrying into the void until the link
    returns). A cancel sent to fence a zombie therefore reconciles it
    at heal time; the zombie's own late completions must be fenced by
    the receiving side, never double-delivered.

With no active window the transport draws **zero** rng samples and
delivers same-tick in FIFO order — a no-fault run is bitwise-identical
to calling the receiver directly. ``ClusterSim`` owns its own delivery
queues (the routed-``pending`` lists and the migration heap) and only
asks the transport to *judge* each send (``judge``), so one chaos
schedule reproduces on either backend.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# message kinds
BEAT = "beat"
SUBMIT = "submit"
INJECT = "inject"
CANCEL = "cancel"       # fence a re-routed request on its old host

#: destination address of the failure detector (heartbeat sink)
DETECTOR = -1

_INF = float("inf")


@dataclass
class Message:
    """One transport message. ``send_t`` is the sender's clock at send
    time (receivers that need the original timestamp — e.g. a submit's
    arrival time — read it from here, not from the delivery clock).
    ``dkey`` identifies the *logical* delivery for receiver-side
    idempotency: duplicated copies share it, retries get a fresh one."""
    dst: int
    kind: str
    payload: object
    send_t: float
    seq: int
    dkey: Optional[tuple] = None


@dataclass(frozen=True)
class Verdict:
    """What the fault windows decided about one send (``judge``).
    ``heal > 0`` means the link is partitioned: the message is held by
    the sender's retry timer and can land no earlier than ``heal``."""
    drop: bool = False
    dup: bool = False
    delay: float = 0.0
    heal: float = 0.0


@dataclass
class _Window:
    """One active transport-fault window on an instance's link.
    ``target == -1`` faults every link."""
    kind: str                 # drop | dup | delay
    target: int
    t0: float
    t1: float
    frac: float = 0.5         # per-message probability (drop/dup)
    delay: float = 2.0        # added latency (delay)

    def active(self, link: int, now: float) -> bool:
        return (self.t0 <= now < self.t1
                and (self.target < 0 or self.target == link))


@dataclass
class _Partition:
    """One partition window: instance ``a`` severed from the side that
    holds instance ``b`` and the control plane. Only ``a``'s link is
    cut — ``b`` stands in for the majority side, whose own links stay
    clean."""
    a: int
    b: int
    t0: float
    t1: float

    def covers(self, link: int, now: float) -> bool:
        return self.t0 <= now < self.t1 and link == self.a


class Transport:
    """Seeded lossy message layer. ``send``/``recv`` give the real-engine
    fleet an actual in-flight queue; ``judge`` lets the discrete-event
    sim apply identical fault decisions to its own delivery structures.
    """

    def __init__(self, seed: int = 0, retransmit_after: float = 4.0):
        self.rng = np.random.default_rng(seed)
        self.retransmit_after = retransmit_after
        self.windows: List[_Window] = []
        self.partitions: List[_Partition] = []
        self._q: Dict[int, List[Tuple[float, int, Message]]] = {}
        self._seq = 0
        self.n_dropped = 0
        self.n_duplicated = 0
        self.n_delayed = 0
        self.n_retransmits = 0
        self.n_partition_lost = 0      # beats swallowed by a partition
        self.n_partition_held = 0      # data-plane sends held until heal

    # -- fault windows -------------------------------------------------- #
    def add_fault(self, ev) -> None:
        """Open a fault window from a ``FaultEvent``. Transport kinds
        drop/dup/delay open a ``_Window`` on instance ``ev.target``'s
        link for ``[ev.t, ev.t + ev.duration)``; kind ``part`` opens a
        ``_Partition`` cutting ``ev.target`` off from the side holding
        ``ev.peer`` and the control plane."""
        if ev.kind == "part":
            assert ev.peer >= 0 and ev.peer != ev.target, (ev.target,
                                                           ev.peer)
            self.partitions.append(_Partition(
                a=ev.target, b=ev.peer, t0=ev.t, t1=ev.t + ev.duration))
            return
        assert ev.kind in ("drop", "dup", "delay"), ev.kind
        self.windows.append(_Window(
            kind=ev.kind, target=ev.target, t0=ev.t, t1=ev.t + ev.duration,
            frac=ev.frac, delay=ev.delay))

    def partition_heal(self, link: int, now: float) -> float:
        """Heal time of the latest active partition covering ``link``'s
        side, or 0.0 when the link is clean."""
        heal = 0.0
        for p in self.partitions:
            if p.covers(link, now):
                heal = max(heal, p.t1)
        return heal

    def partitioned(self, link: int, now: float) -> bool:
        return self.partition_heal(link, now) > 0.0

    def _roll(self, kind: str, link: int, now: float) -> Optional[_Window]:
        """The first active window of ``kind`` on ``link`` whose seeded
        coin lands, or None. No active window => no rng draw at all."""
        for w in self.windows:
            if w.kind == kind and w.active(link, now):
                if kind == "delay" or self.rng.random() < w.frac:
                    return w
                return None
        return None

    def judge(self, link: int, now: float) -> Verdict:
        """Fault decision for one send on ``link`` (sim data plane)."""
        if self.partitions:
            heal = self.partition_heal(link, now)
            if heal > 0.0:
                self.n_partition_held += 1
                return Verdict(heal=heal)
        if not self.windows:
            return Verdict()
        w_delay = self._roll("delay", link, now)
        delay = w_delay.delay if w_delay is not None else 0.0
        if delay:
            self.n_delayed += 1
        if self._roll("drop", link, now) is not None:
            self.n_dropped += 1
            return Verdict(drop=True, delay=delay)
        dup = self._roll("dup", link, now) is not None
        if dup:
            self.n_duplicated += 1
        return Verdict(dup=dup, delay=delay)

    # -- data plane (EngineFleet) --------------------------------------- #
    def _push(self, deliver_t: float, msg: Message) -> None:
        self._seq += 1
        heapq.heappush(self._q.setdefault(msg.dst, []),
                       (deliver_t, self._seq, msg))

    def send(self, dst: int, kind: str, payload, now: float,
             dkey: Optional[tuple] = None, link: Optional[int] = None
             ) -> None:
        """Send one message. ``link`` is the instance whose network link
        the fault windows match (defaults to ``dst``; heartbeats pass the
        *source* instance — the detector's address is not a link)."""
        self._seq += 1
        msg = Message(dst=dst, kind=kind, payload=payload, send_t=now,
                      seq=self._seq, dkey=dkey)
        link = dst if link is None else link
        if self.partitions:
            heal = self.partition_heal(link, now)
            if heal > 0.0:
                if kind == BEAT:
                    # fire-and-forget liveness: lost into the cut — the
                    # detector's missed-beat walk is the whole point
                    self.n_partition_lost += 1
                else:
                    # at-least-once: the sender's retry timer keeps the
                    # message alive and it lands just after the heal
                    self.n_partition_held += 1
                    self._push(max(now + self.retransmit_after, heal), msg)
                return
        v = self.judge(link, now)
        if v.drop:
            if kind != BEAT:
                # at-least-once: the sender's retry timer re-delivers
                self.n_retransmits += 1
                self._push(now + v.delay + self.retransmit_after, msg)
            return
        self._push(now + v.delay, msg)
        if v.dup:
            self._push(now + v.delay, msg)     # same dkey: receiver dedups

    def recv(self, dst: int, now: float) -> List[Message]:
        """Pop every message to ``dst`` whose delivery time has come,
        in (delivery time, send order)."""
        q = self._q.get(dst)
        if not q:
            return []
        out: List[Message] = []
        while q and q[0][0] <= now:
            out.append(heapq.heappop(q)[2])
        return out

    # -- introspection -------------------------------------------------- #
    def pending(self) -> int:
        """In-flight *data-plane* messages (beats excluded — they are
        periodic and carry no work)."""
        return sum(len(q) for dst, q in self._q.items() if dst != DETECTOR)

    def next_time(self) -> float:
        """Earliest pending data-plane delivery time (inf when idle)."""
        t = _INF
        for dst, q in self._q.items():
            if dst != DETECTOR and q:
                t = min(t, q[0][0])
        return t
