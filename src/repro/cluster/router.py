"""Request routing policies for the cluster serving layer.

A router picks which instance serves a new request (and, for disaggregated
roles, which decode instance receives a migrated one). Candidates expose a
small stats protocol (``InstanceStats``) implemented by both backends — the
discrete-event ``ClusterSim`` instances and the real ``EngineFleet``
engines — so one policy implementation serves both.

Policies:

  * ``round-robin``    — cycle over the live candidates (by instance id, so
    the cycle is stable under instances joining/leaving).
  * ``least-tokens``   — fewest outstanding tokens (unprocessed prompt +
    predicted-remaining RL over queued and running requests): the classic
    least-outstanding-work balancer.
  * ``least-kvc``      — EconoServe-aware: score each instance by its
    *allocated*-KVC fraction (exact allocation means allocated, not used,
    is what bounds admission, §3.3) plus the fraction the request's
    predicted demand (prompt + padded predicted RL) would add; route to
    the minimum. This places a request where its KVC reservation is most
    likely to be granted immediately.

Ties are broken by a seeded RNG so multi-instance runs are reproducible:
two routers constructed with the same seed make identical choices on
identical inputs (``tests/test_cluster.py``).
"""
from __future__ import annotations

from typing import List, Optional, Protocol, Sequence

import numpy as np

ROUTERS = ("round-robin", "least-tokens", "least-kvc")


class InstanceStats(Protocol):
    """What a router may observe about a candidate instance."""
    id: int

    def kvc_allocated_frac(self) -> float: ...
    def kvc_capacity_tokens(self) -> int: ...
    def outstanding_tokens(self) -> int: ...


class Router:
    """Base: seeded deterministic tie-breaking shared by all policies."""
    name = "base"

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self.n_decisions = 0
        self.n_tiebreaks = 0

    def choose(self, instances: Sequence[InstanceStats],
               demand_tokens: int) -> InstanceStats:
        """Pick one of ``instances`` (non-empty) for a request that is
        predicted to need ``demand_tokens`` of KVC. Counts the decision,
        then delegates to the policy's ``_choose``."""
        self.n_decisions += 1
        return self._choose(instances, demand_tokens)

    def _choose(self, instances: Sequence[InstanceStats],
                demand_tokens: int) -> InstanceStats:
        raise NotImplementedError

    def publish_metrics(self, registry, **labels) -> None:
        """Publish routing counters into a ``repro.obs`` registry."""
        ln = ("policy",) + tuple(sorted(labels))
        registry.counter(
            "router_decisions_total", "routing decisions made",
            ln).labels(policy=self.name, **labels).inc_to(self.n_decisions)
        registry.counter(
            "router_tiebreaks_total", "decisions settled by the seeded "
            "rng", ln).labels(policy=self.name,
                              **labels).inc_to(self.n_tiebreaks)

    def _pick_min(self, instances: Sequence[InstanceStats],
                  scores: Sequence[float]) -> InstanceStats:
        best = min(scores)
        tied = [i for i, s in enumerate(scores) if s == best]
        if len(tied) == 1:
            return instances[tied[0]]
        self.n_tiebreaks += 1
        return instances[tied[int(self._rng.integers(len(tied)))]]


class RoundRobinRouter(Router):
    name = "round-robin"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._last: Optional[int] = None

    def _choose(self, instances, demand_tokens):
        ids = sorted(inst.id for inst in instances)
        if self._last is None:
            nxt = ids[0]
        else:
            after = [i for i in ids if i > self._last]
            nxt = after[0] if after else ids[0]
        self._last = nxt
        return next(inst for inst in instances if inst.id == nxt)


class LeastOutstandingTokensRouter(Router):
    name = "least-tokens"

    def _choose(self, instances, demand_tokens):
        return self._pick_min(
            instances, [float(inst.outstanding_tokens())
                        for inst in instances])


class LeastKVCRouter(Router):
    name = "least-kvc"

    def _choose(self, instances, demand_tokens):
        scores = []
        for inst in instances:
            cap = max(1, inst.kvc_capacity_tokens())
            scores.append(inst.kvc_allocated_frac()
                          + demand_tokens / cap)
        return self._pick_min(instances, scores)


def make_router(name: str, seed: int = 0) -> Router:
    try:
        cls = {"round-robin": RoundRobinRouter,
               "least-tokens": LeastOutstandingTokensRouter,
               "least-kvc": LeastKVCRouter}[name]
    except KeyError:
        raise ValueError(f"unknown router {name!r}; one of {ROUTERS}")
    return cls(seed)
