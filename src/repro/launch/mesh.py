"""Production mesh construction.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per v5e pod; 2 pods over DCN for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU smoke)."""
    return jax.make_mesh((data, model), ("data", "model"))
