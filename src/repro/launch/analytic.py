"""Analytic roofline terms per (arch x shape) on TPU v5e.

Why this exists: XLA-CPU's ``cost_analysis`` counts while-loop bodies
*once* (layer scans, flash scans) and charges full-operand bytes to
in-place dynamic-update-slices, so raw HLO numbers under-count compute and
over-count decode memory (verified in EXPERIMENTS.md §Perf iteration 1).
The closed forms below are exact for the matmul/attention/state math this
framework emits; the dry-run's HLO is still the source for the collective
term (corrected for loop trip counts) and for memory-fit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.costmodel import _param_count
from repro.launch.shapes import LONG_WINDOW, SHAPES, ShapeSpec, adapt_config
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256


def _attn_flops_prefill(cfg: ModelConfig, S: int, B: int) -> float:
    """Causal (windowed) attention matmul flops, forward, all layers."""
    pat = cfg.pattern()
    n_attn = pat.count("A")
    if cfg.shared_attention_every:
        n_attn += cfg.num_layers // cfg.shared_attention_every
    d_attn = cfg.num_heads * cfg.resolved_head_dim
    if cfg.sliding_window and cfg.sliding_window < S:
        w = cfg.sliding_window
        pairs = S * w - w * w / 2
    else:
        pairs = S * S / 2
    per_layer = 4.0 * d_attn * pairs          # qk + av, 2 flops each
    # mLSTM chunkwise decay-matrix work ~ chunk-local quadratic
    n_x = pat.count("X")
    if n_x:
        Q = cfg.ssm_chunk
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        per_layer_x = 4.0 * di * S * Q / 2
    else:
        per_layer_x = 0.0
    # Mamba2 chunked SSD: intra-chunk quadratic + state terms
    n_m = pat.count("M")
    if n_m:
        Q = cfg.ssm_chunk
        di, n = cfg.d_inner, cfg.ssm_state
        per_layer_m = S * (2.0 * di * Q + 6.0 * di * n)
    else:
        per_layer_m = 0.0
    return B * (n_attn * per_layer + n_x * per_layer_x + n_m * per_layer_m)


def _state_bytes_per_token(cfg: ModelConfig, ctx: int) -> float:
    """KV/state bytes read per decoded token (one request)."""
    pat = cfg.pattern()
    hd = cfg.resolved_head_dim
    n_attn = pat.count("A")
    kv = 0.0
    if n_attn:
        c = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        kv += n_attn * 2 * cfg.num_kv_heads * hd * 2 * c
    if cfg.shared_attention_every:
        n_inv = cfg.num_layers // cfg.shared_attention_every
        kvh = cfg.shared_attn_kv_heads or cfg.num_kv_heads
        kv += n_inv * 2 * kvh * hd * 2 * ctx
    if pat.count("M"):
        kv += pat.count("M") * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4 * 2                    # fp32 read+write
    if pat.count("X"):
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        hdx = di // cfg.num_heads
        kv += pat.count("X") * cfg.num_heads * hdx * hdx * 4 * 2
    if pat.count("S"):
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        kv += pat.count("S") * 4 * di * 4 * 2
    return kv


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s,
                "bottleneck": self.bottleneck}


def analytic_roofline(cfg: ModelConfig, shape: ShapeSpec, *,
                      collective_bytes_per_chip: float = 0.0,
                      chips: int = CHIPS) -> Roofline:
    cfg = adapt_config(cfg, shape)
    pc = _param_count(cfg)
    B, S = shape.global_batch, shape.seq_len
    F = cfg.frontend_tokens if cfg.frontend else 0

    if shape.kind == "train":
        tokens = B * S
        # fwd + bwd + remat re-forward = 8 N D matmul flops
        flops = 8.0 * pc["compute"] * tokens \
            + 3.5 * _attn_flops_prefill(cfg, S, B)
        # weights streamed fwd/bwd/remat + AdamW state traffic
        wbytes = pc["compute"] * 2 * 3 + pc["total"] * (2 * 2 + 4 * 4)
        act = tokens * cfg.d_model * 2 * cfg.num_layers * 12
        logits = tokens * cfg.vocab_size * 2 * 3
        bytes_ = wbytes + act + logits
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * pc["compute"] * tokens \
            + _attn_flops_prefill(cfg, S, B)
        wbytes = pc["compute"] * 2
        act = tokens * cfg.d_model * 2 * cfg.num_layers * 6
        kv_write = B * _state_bytes_per_token(cfg, 1) / 2 * S
        bytes_ = wbytes + act + kv_write
    else:  # decode: one token per request against ctx
        flops = 2.0 * pc["compute"] * B \
            + 2.0 * B * _state_bytes_per_token(cfg, S) / 2
        bytes_ = pc["compute"] * 2 + B * _state_bytes_per_token(cfg, S)

    return Roofline(
        compute_s=flops / (chips * PEAK_FLOPS),
        memory_s=bytes_ / (chips * HBM_BW),
        collective_s=collective_bytes_per_chip / LINK_BW,
    )
