"""Serving launcher: trace-driven continuous batching on a real JAX model
(reduced configs on CPU) under any scheduler in the registry — single
engine or an N-instance cluster (``--cluster N``), with SLO-aware routing
and optional disaggregated prefill/decode roles (``--disagg``).

Usage:
  python -m repro.launch.serve --arch qwen3-8b --requests 16
  python -m repro.launch.serve --arch qwen3-8b --cluster 2 --router least-kvc
  python -m repro.launch.serve --arch opt-13b --sim --trace sharegpt \
      --requests 500 --rate 5.0 --scheduler econoserve --cluster 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.cluster import EngineFleet, ROUTERS
from repro.configs import get_config
from repro.core import registry, traces
from repro.core.costmodel import CostModel, ModelProfile
from repro.core.scheduler import SchedulerConfig
from repro.serving import GenRequest, SamplingParams, ServingEngine


def _roles(args):
    if not args.disagg:
        return None
    assert args.cluster >= 2, "--disagg needs --cluster >= 2"
    return ["prefill"] + ["decode"] * (args.cluster - 1)


def run_engine(args) -> int:
    cfg = get_config(args.arch).reduced().with_(dtype="float32",
                                                param_dtype="float32")
    kw = dict(max_batch=args.max_batch, capacity=args.capacity,
              variant=args.variant, impl=args.impl)
    if args.cluster:
        server = EngineFleet(cfg, n_instances=args.cluster,
                             roles=_roles(args), router=args.router,
                             seed=args.seed, **kw)
    else:
        server = ServingEngine(cfg, seed=args.seed, **kw)
    rng = np.random.default_rng(args.seed)
    reqs = [GenRequest(
        prompt=list(rng.integers(0, cfg.vocab_size,
                                 int(rng.integers(4, args.capacity // 4)))),
        params=SamplingParams(max_new_tokens=int(rng.integers(4, 24))))
        for _ in range(args.requests)]
    t0 = time.time()
    server.run(reqs)
    dt = time.time() - t0
    toks = sum(len(g.output) for g in reqs)
    done = sum(g.t_done is not None for g in reqs)
    mode = f"cluster={args.cluster} router={args.router}" if args.cluster \
        else "single"
    print(f"served {done}/{len(reqs)} requests, {toks} tokens "
          f"in {dt:.1f}s ({toks/dt:.1f} tok/s on CPU, arch={cfg.name}, "
          f"{mode})")
    if args.cluster:
        cons = server.conservation()
        print(f"conservation: {cons}")
        if not cons["ok"]:
            return 1
    return 0 if done == len(reqs) else 1


def run_sim(args) -> int:
    spec = traces.TRACES[args.trace]
    reqs = traces.generate(spec, args.requests, seed=args.seed,
                           rate=args.rate)
    cost = CostModel(model=ModelProfile.from_config(get_config(args.arch)))
    if args.cluster:
        res = registry.run_cluster(args.scheduler, reqs,
                                   n_instances=args.cluster,
                                   router=args.router, roles=_roles(args),
                                   cfg=SchedulerConfig(), cost=cost,
                                   seed=args.seed)
        print(f"cluster x{args.cluster} router={args.router} "
              f"roles={'disagg' if args.disagg else 'unified'}")
        print(f"{'goodput_req_s':26s} {res.goodput:.4f}")
        print(f"{'throughput_req_s':26s} {res.throughput_reqs:.4f}")
        print(f"{'ssr':26s} {res.ssr:.4f}")
        print(f"{'migrations':26s} {res.n_migrations}")
        print(f"conservation: {res.conservation()}")
        return 0 if res.conservation()["ok"] else 1
    res = registry.run_one(args.scheduler, reqs, SchedulerConfig(), cost)
    for k, v in res.summary().items():
        print(f"{k:26s} {v:.4f}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="opt-13b")
    ap.add_argument("--sim", action="store_true",
                    help="trace-driven simulation instead of the CPU engine")
    ap.add_argument("--scheduler", default="econoserve",
                    choices=registry.SCHEDULERS)
    ap.add_argument("--variant", default="full")
    ap.add_argument("--trace", default="sharegpt", choices=list(traces.TRACES))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=None)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=256)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--cluster", type=int, default=0, metavar="N",
                    help="serve across N instances (0 = single engine)")
    ap.add_argument("--router", default="least-kvc", choices=list(ROUTERS))
    ap.add_argument("--disagg", action="store_true",
                    help="instance 0 prefills, the rest decode (KV "
                         "migration); requires --cluster >= 2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    return run_sim(args) if args.sim else run_engine(args)


if __name__ == "__main__":
    raise SystemExit(main())
