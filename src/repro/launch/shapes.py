"""Assigned input shapes × step builders for the dry-run and launchers.

Shapes (assigned to this paper):
  train_4k     seq 4,096   global_batch 256   train_step
  prefill_32k  seq 32,768  global_batch 32    prefill step
  decode_32k   seq 32,768  global_batch 128   serve_step (1 token vs cache)
  long_500k    seq 524,288 global_batch 1     serve_step, sub-quadratic only

``long_500k`` policy (DESIGN.md §4): SSM/hybrid run natively; dense/MoE/
VLM/audio run the sliding-window (8192) attention variant; zamba2's 14
shared-attention caches are sequence-sharded over the "data" axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model
from repro.models.config import ModelConfig
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_loop import make_train_step

LONG_WINDOW = 8192


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def adapt_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Per-shape config adaptation (window variant for long-context dense;
    bf16 optimizer states for the 480B MoE — DESIGN.md §5)."""
    if shape.name == "long_500k" and cfg.has_attention \
            and cfg.arch_type not in ("ssm", "hybrid") \
            and cfg.sliding_window is None:
        cfg = cfg.with_(sliding_window=LONG_WINDOW)
    return cfg


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    if shape.name == "long_500k":
        c = adapt_config(cfg, shape)
        if not c.supports_long_context:
            return False, "pure full-attention arch at 500k context"
    return True, ""


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    # 480B-scale MoE: bf16 moments to fit one pod (DESIGN.md §5)
    if cfg.is_moe and cfg.num_experts >= 64:
        return AdamWConfig(state_dtype="bfloat16")
    return AdamWConfig()


# --------------------------------------------------------------------------- #
def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _batch_spec(mesh: Mesh) -> P:
    return P(shd.batch_axes(mesh))


def abstract_cache(cfg: ModelConfig, mesh: Mesh, batch: int, capacity: int,
                   *, shard_batch: bool, shard_seq: bool):
    shapes = jax.eval_shape(
        lambda: model.init_cache(cfg, batch, capacity))
    specs = shd.cache_specs(cfg, mesh, batch=batch, capacity=capacity,
                            shard_batch=shard_batch, shard_seq=shard_seq)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=_named(mesh, p)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
               ) -> Tuple[Callable, tuple, dict]:
    """Returns (step_fn, abstract_args, jit_kwargs) ready for
    jax.jit(step_fn, **jit_kwargs).lower(*abstract_args)."""
    cfg = adapt_config(cfg, shape)
    from repro.models.common import set_mesh_axes
    set_mesh_axes(mesh.axis_names,
                  dict(zip(mesh.axis_names, mesh.devices.shape)), mesh=mesh)
    bspec = _batch_spec(mesh)
    # Serving (prefill/decode) replicates weights across the data axis when
    # they fit model-parallel-only — FSDP all-gathers per layer are pure
    # overhead for inference (§Perf iteration 2). Training always FSDPs.
    from repro.core.costmodel import _param_count
    model_axis = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    per_chip_gb = _param_count(cfg)["total"] * 2 / model_axis / 2 ** 30
    fsdp = shape.kind == "train" or per_chip_gb > 8.0
    params_abs = shd.shard_params_abstract(cfg, mesh, fsdp=fsdp)
    F = cfg.frontend_tokens if cfg.frontend else 0
    B = shape.global_batch

    if shape.kind == "train":
        opt = opt_config_for(cfg)
        step_fn = make_train_step(cfg, opt)
        opt_abs = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(
                p.shape, jnp.dtype(opt.state_dtype), sharding=p.sharding),
            {"m": params_abs, "v": params_abs})
        opt_abs["step"] = jax.ShapeDtypeStruct((), jnp.int32)
        batch = {"tokens": jax.ShapeDtypeStruct(
            (B, shape.seq_len - F), jnp.int32,
            sharding=_named(mesh, bspec))}
        if F:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (B, F, cfg.d_model), jnp.dtype(cfg.dtype),
                sharding=_named(mesh, P(bspec[0] if bspec else None,
                                        None, None)))
        return step_fn, (params_abs, opt_abs, batch), \
            dict(donate_argnums=(0, 1))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, caches = model.prefill(cfg, params, batch["tokens"],
                                           batch.get("embeds"),
                                           last_only=True)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

        batch = {"tokens": jax.ShapeDtypeStruct(
            (B, shape.seq_len - F), jnp.int32,
            sharding=_named(mesh, bspec))}
        if F:
            batch["embeds"] = jax.ShapeDtypeStruct(
                (B, F, cfg.d_model), jnp.dtype(cfg.dtype),
                sharding=_named(mesh, P(bspec[0] if bspec else None,
                                        None, None)))
        return prefill_step, (params_abs, batch), {}

    # decode
    shard_batch = B > 1
    shard_seq = not shard_batch
    capacity = shape.seq_len

    def serve_step(params, tokens, pos, caches):
        logits, caches = model.decode_step(cfg, params, tokens, pos, caches)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

    tok_spec = bspec if shard_batch else P(None)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                  sharding=_named(mesh, P(tok_spec[0], None)))
    pos = jax.ShapeDtypeStruct((B,), jnp.int32,
                               sharding=_named(mesh, P(tok_spec[0])))
    caches = abstract_cache(cfg, mesh, B, capacity,
                            shard_batch=shard_batch, shard_seq=shard_seq)
    return serve_step, (params_abs, tokens, pos, caches), \
        dict(donate_argnums=(3,))
