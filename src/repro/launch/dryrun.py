import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo on
# placeholder devices, print memory/cost analysis, and dump the roofline raw
# terms to JSON for EXPERIMENTS.md.
#
# Usage:
#   python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k
#   python -m repro.launch.dryrun --all [--mesh single|multi|both]

import argparse
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, applicable, build_step

# roofline hardware constants (TPU v5e)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(\w+)\[([0-9,]*)\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str, loop_trip: int = 1) -> Dict[str, int]:
    """Sum result-tensor bytes of every collective op (per-device program).

    XLA's post-optimization module counts a while-loop body once; passing
    ``loop_trip`` (the layer count — the dominant loop) multiplies
    collectives that live inside loop-body computations ("while"/"wide."
    regions) by the trip count. Approximate but directionally exact: every
    per-layer collective is restored, outside-loop ops stay x1.
    """
    out: Dict[str, int] = {}
    mult = 1
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():       # computation header
            mult = loop_trip if ("while" in line or "wide." in line) else 1
        m = re.search(
            r"=\s*(.+?)\s+(all-reduce|all-gather|reduce-scatter|"
            r"all-to-all|collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue                       # avoid double count of async pair
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _TUPLE_RE.findall(shapes_str))
        out[kind] = out.get(kind, 0) + b * mult
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Optional[str] = None, verbose: bool = True) -> Dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        step_fn, args, jit_kw = build_step(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(step_fn, **jit_kw).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text(),
                                loop_trip=cfg.num_layers)
        rec.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            chips=int(n_chips),
            # memory_analysis is per-device
            mem_bytes={
                "argument": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp": int(getattr(mem, "temp_size_in_bytes", 0)),
                "alias": int(getattr(mem, "alias_size_in_bytes", 0)),
            },
            flops=float(cost.get("flops", 0.0)),
            hlo_bytes=float(cost.get("bytes accessed", 0.0)),
            collective_bytes=coll,
        )
        # raw HLO roofline terms (per-chip program → per-chip rates).
        # CAVEAT (EXPERIMENTS.md §Roofline): XLA-CPU cost_analysis counts
        # loop bodies once and charges in-place updates fully — compute is
        # under-counted, decode memory over-counted. The analytic terms
        # below are the calibrated numbers; collectives use the
        # loop-corrected HLO parse.
        coll_total = float(sum(coll.values()))
        rec["roofline_hlo_raw"] = {
            "compute_s": rec["flops"] / PEAK_FLOPS,
            "memory_s": rec["hlo_bytes"] / HBM_BW,
            "collective_s": coll_total / LINK_BW,
        }
        from repro.launch.analytic import analytic_roofline
        ana = analytic_roofline(get_config(arch), shape,
                                collective_bytes_per_chip=coll_total,
                                chips=int(n_chips))
        rec["roofline"] = ana.as_dict()
        rec["bottleneck"] = ana.bottleneck
        if verbose:
            # memory_analysis is already per-device
            per_dev = (rec["mem_bytes"]["argument"]
                       + rec["mem_bytes"]["temp"]
                       + rec["mem_bytes"]["output"]
                       - rec["mem_bytes"]["alias"])
            rec["mem_per_device"] = per_dev
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:8s} OK "
                  f"compile={rec['compile_s']:6.1f}s "
                  f"mem/dev={per_dev/2**30:6.2f}GiB "
                  f"bottleneck={rec['bottleneck']}", flush=True)
            print(f"  memory_analysis: {mem}", flush=True)
            print(f"  cost_analysis: flops={rec['flops']:.3e} "
                  f"bytes={rec['hlo_bytes']:.3e} coll={coll}", flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_name:8s} "
                  f"FAIL {rec['error']}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch.replace('/', '_')}_{shape_name}_{mesh_name}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    archs = list_archs(include_paper_model=False) if args.arch is None \
        else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if not args.all and args.arch is None and args.shape is None:
        ap.error("pass --all or --arch/--shape")

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, out_dir=args.out)
                n_fail += rec["status"] == "fail"
    print(f"[dryrun] done, failures={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
