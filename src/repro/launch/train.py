"""Training launcher.

On real hardware this runs the sharded train step on the production mesh;
on CPU it runs reduced configs for smoke/integration. The mesh/sharding
path is identical — only the device count differs.

Usage:
  python -m repro.launch.train --arch qwen3-8b --steps 50 --reduced
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import model
from repro.training.data import DataConfig, SyntheticDataset
from repro.training.optimizer import AdamWConfig, init_state
from repro.training.train_loop import make_train_step
from repro.training import checkpoint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the family")
    ap.add_argument("--save", default=None)
    ap.add_argument("--data-axis", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().with_(dtype="float32", param_dtype="float32")
    mesh = make_host_mesh(args.data_axis, args.model_axis)
    opt = AdamWConfig(lr=args.lr)
    if args.data_axis * args.model_axis > 1:
        from repro.models.common import set_mesh_axes
        set_mesh_axes(mesh.axis_names,
                      dict(zip(mesh.axis_names, mesh.devices.shape)))

    with mesh:
        specs = shd.param_specs(cfg, mesh)
        params = model.init(cfg, jax.random.PRNGKey(0))
        params = {k: jax.device_put(
            v, jax.sharding.NamedSharding(mesh, specs[k]))
            for k, v in params.items()}
        opt_state = init_state(params, opt)
        step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
        dcfg = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            batch_size=args.batch, seed=0,
            frontend_tokens=cfg.frontend_tokens if cfg.frontend else 0,
            d_model=cfg.d_model)
        ds = SyntheticDataset(dcfg)
        t0 = time.time()
        for i, batch in enumerate(ds.batches()):
            if i >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if "embeds" in batch:
                batch["embeds"] = batch["embeds"].astype(cfg.dtype)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
    if args.save:
        checkpoint.save(args.save, params,
                        meta={"step": np.asarray(args.steps)})
        print(f"saved {args.save}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
