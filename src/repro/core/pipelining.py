"""KVC Pipelining (§3.2): lend the allocated-but-unused tail of a hosting
GT's exact allocation to hosted GTs, recursively (Russian nesting dolls).

Model: a GT with an allocation span of R tokens grows into it at one
token/iteration. Any sub-interval [o, o+s) of the span is free until the
owner's usage reaches o — i.e. for `o` iterations. The usable slots of a
span are its dyadic second halves:

    offset R/2,  size R/2   (deadline R/2 iterations)
    offset R/4,  size R/4   (deadline R/4)
    ...

A hosted GT with (padded) remaining RL r fits a slot iff r <= size - b,
where b is the safety buffer (O4 / §3.2). The hosted GT's own span then
recursively offers slots. If the owner reaches a slot boundary and the
hosted GT has not completed (RL under-prediction), the hosted GT is
preempted (copy-on-write to host memory, per the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from .request import Request


@dataclass
class Slot:
    owner: Request             # whose allocation the slot lives in
    offset: int                # tokens from the owner's span start
    size: int                  # tokens available
    child: Optional[Request] = None

    @property
    def deadline_age(self) -> int:
        """Owner run-age (iterations) at which the slot must be vacated."""
        return self.offset


def dyadic_slots(owner: Request, span: int, min_size: int) -> List[Slot]:
    """The owner's own-growth slots: second half, second quarter, ..."""
    slots = []
    s = span // 2
    while s >= min_size:
        slots.append(Slot(owner=owner, offset=s, size=s))
        s //= 2
    return slots


@dataclass
class PipeBook:
    """Tracks live host→hosted relations for the scheduler."""
    buffer_tokens: int
    min_size: int = 32
    open_slots: List[Slot] = field(default_factory=list)
    active: List[Slot] = field(default_factory=list)   # slots with a child

    def offer(self, owner: Request, span: int) -> None:
        """Register a newly scheduled GT's lendable slots."""
        self.open_slots.extend(dyadic_slots(owner, span, self.min_size))
        self.open_slots.sort(key=lambda s: -s.size)

    def _effective(self, s: Slot, age_of) -> int:
        """Usable tokens: the owner has already grown ``age`` tokens toward
        the slot boundary, and b tokens are kept as the safety buffer."""
        return s.size - age_of(s.owner) - self.buffer_tokens

    def max_hostable(self, age_of=lambda r: 0) -> int:
        if not self.open_slots:
            return 0
        return max(self._effective(s, age_of) for s in self.open_slots)

    def place(self, req: Request, need: int,
              age_of=lambda r: 0) -> Optional[Slot]:
        """Host `req` (remaining padded RL = need) in the best-fit slot."""
        best_i, best_eff = -1, None
        for i, s in enumerate(self.open_slots):
            eff = self._effective(s, age_of)
            if eff >= need and (best_eff is None or eff < best_eff):
                best_i, best_eff = i, eff
        if best_i < 0:
            return None
        slot = self.open_slots.pop(best_i)
        slot.child = req
        req.hosted = True
        self.active.append(slot)
        # the hosted span recursively offers its own slots
        self.open_slots.extend(dyadic_slots(req, need, self.min_size))
        self.open_slots.sort(key=lambda s: -s.size)
        return slot

    def expired(self, run_age_of) -> List[Slot]:
        """Slots whose owner reached the boundary with the child unfinished."""
        out = []
        for s in self.active:
            if s.child is not None and run_age_of(s.owner) >= s.deadline_age:
                out.append(s)
        return out

    def release_child(self, req: Request) -> None:
        """Child finished or was preempted — slot is NOT reusable (the owner
        is about to grow into it / other shares were sub-let)."""
        for s in self.active:
            if s.child is req:
                s.child = None
        self.active = [s for s in self.active if s.child is not None]
        req.hosted = False

    def drop_owner(self, req: Request) -> List[Request]:
        """Owner's allocation is being freed (completion with no children, or
        preemption): retract its open slots; children still running must be
        preempted by the caller if the memory really disappears."""
        self.open_slots = [s for s in self.open_slots if s.owner is not req]
        orphans = [s.child for s in self.active
                   if s.owner is req and s.child is not None]
        self.active = [s for s in self.active if s.owner is not req]
        return orphans
