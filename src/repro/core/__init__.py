"""EconoServe core: the paper's scheduler, baselines, and simulator."""
