"""Every scheduler the paper compares against (§2, §4), on the shared
BaseScheduler substrate:

  ORCA          iteration-level FCFS, max-allocation, fixed batch size
  SRTF          shortest-remaining-time-first, max-allocation
  FastServe     5-level MLFQ (skip-join), max-allocation
  vLLM          FCFS + block-allocation + swap-based preemption
  Sarathi-Serve chunked prefill to TFS + block-allocation
  MultiRes      dual-resource Euclidean matching, exact-allocation (O(n^2))
  SyncCoupled   MultiRes + same-RL GT groups
  DistServe     disaggregated prefill/decode engines + KV transfer
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .costmodel import CostModel
from .kvc import blocks_for
from .metrics import IterSample, SimResult
from .predictor import bucketize
from .request import Request, State
from .scheduler import BaseScheduler, IterationPlan, SchedulerConfig


# ------------------------------------------------------------------------- #
class OrcaScheduler(BaseScheduler):
    """Iteration-level FCFS with max-allocation [11]."""
    name = "orca"

    def __init__(self, cfg: SchedulerConfig, cost: CostModel,
                 batch_size: int = 8):
        super().__init__(cfg, cost)
        self.batch_size = batch_size
        self.running: List[Request] = []

    def has_work(self) -> bool:
        return bool(self.pt_queue or self.running)

    def _max_alloc(self, req: Request) -> int:
        return req.prompt_len + self.cfg.max_model_len

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        n_sel = 0
        q = sorted(self.pt_queue, key=lambda r: r.arrival)
        for r in q:
            if len(self.running) + len(plan.prompt_items) >= self.batch_size:
                break
            need = self._max_alloc(r)
            if not self.kvc.can_allocate(need):
                break                      # FCFS head-of-line on KVC
            self.kvc.allocate(r.rid, need)
            r.alloc_rl = self.cfg.max_model_len
            r.set_state(State.RUNNING_PT, t)
            if r.t_start_exec is None:
                r.t_start_exec = t
            plan.prompt_items.append((r, r.prompt_len))
            self.pt_queue.remove(r)
            n_sel += 1
        plan.decode_reqs = list(self.running)
        plan.sched_time = self.cost.sched_time_fcfs(
            len(self.pt_queue), n_sel)
        self.current_plan = plan
        return plan

    def finish_iteration(self, t: float) -> None:
        plan = self.current_plan
        n_done = 0
        for r, _ in plan.prompt_items:
            r.prompt_done = r.prompt_len
            self.kvc.set_used(r.rid, r.prompt_len)
            if r.t_first_token is None:
                r.t_first_token = t
            r.set_state(State.RUNNING_GT, t)
            self.running.append(r)
        for r in list(self.running):
            if r.state != State.RUNNING_GT:
                continue
            if r in [p for p, _ in plan.prompt_items]:
                continue                   # prefilled this iteration
            r.generated += 1
            self.kvc.add_used(r.rid, 1)
            if r.done:
                self.running.remove(r)
                self._complete(r, t)
                n_done += 1
        self.iter_completion_counts.append(n_done)


# ------------------------------------------------------------------------- #
class SRTFScheduler(OrcaScheduler):
    """Shortest-remaining-time-first (known RL), max-allocation."""
    name = "srtf"

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        # preemptive: keep only the shortest-remaining `batch_size` running
        pool = self.running + [r for r in self.pt_queue]
        pool.sort(key=lambda r: r.true_rl - r.generated)
        chosen = []
        for r in pool:
            if len(chosen) >= self.batch_size:
                break
            if r.state in (State.RUNNING_GT, State.PREEMPTED) \
                    and r.prompt_done >= r.prompt_len:
                chosen.append(r)
            else:  # needs admission (max-alloc) + prefill
                need = self._max_alloc(r)
                if self.kvc.allocated_tokens(r.rid) >= need or \
                        self.kvc.can_allocate(need - self.kvc.allocated_tokens(r.rid)):
                    if self.kvc.allocated_tokens(r.rid) < need:
                        self.kvc.allocate(r.rid, need - self.kvc.allocated_tokens(r.rid))
                    r.alloc_rl = self.cfg.max_model_len
                    if r.t_start_exec is None:
                        r.t_start_exec = t
                    r.set_state(State.RUNNING_PT, t)
                    plan.prompt_items.append((r, r.prompt_len))
                    if r in self.pt_queue:
                        self.pt_queue.remove(r)
                    chosen.append(r)
        # displaced runners pause but keep their (max) allocation
        for r in self.running:
            if r not in chosen:
                r.set_state(State.PREEMPTED, t)
                r.n_preemptions += 1
                self.pt_queue.append(r)
        self.running = [r for r in chosen
                        if r.state in (State.RUNNING_GT, State.PREEMPTED)]
        for r in self.running:
            r.set_state(State.RUNNING_GT, t)
        plan.decode_reqs = list(self.running)
        plan.sched_time = self.cost.sched_time_fcfs(len(self.pt_queue),
                                                    len(chosen)) * 2
        self.current_plan = plan
        return plan


# ------------------------------------------------------------------------- #
class FastServeScheduler(BaseScheduler):
    """MLFQ with skip-join [12]; max-allocation."""
    name = "fastserve"

    def __init__(self, cfg: SchedulerConfig, cost: CostModel,
                 levels: int = 5, base_quantum: int = 2,
                 batch_size: int = 8):
        super().__init__(cfg, cost)
        self.levels = [[] for _ in range(levels)]
        self.quanta = [base_quantum * (2 ** i) for i in range(levels)]
        self.batch_size = batch_size
        self.running: List[Tuple[Request, int]] = []   # (req, level)
        self.used_quantum: dict = {}

    def has_work(self) -> bool:
        return bool(self.running or any(self.levels) or self.pt_queue)

    def on_arrival(self, req: Request, t: float) -> None:
        req.set_state(State.QUEUED_PT, t)
        # skip-join: longer prompts start at lower priority
        lvl = min(len(self.levels) - 1,
                  int(math.log2(max(1, req.prompt_len // 64)) + 1)
                  if req.prompt_len > 64 else 0)
        self.levels[lvl].append(req)

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        chosen: List[Tuple[Request, int]] = []
        # keep running requests that still have quantum at their level,
        # preferring higher-priority levels
        pool = sorted(self.running, key=lambda rl: rl[1])
        for lvl_i, level in enumerate(self.levels):
            for r in sorted(level, key=lambda r: r.arrival):
                pool.append((r, lvl_i))
        for r, lvl in pool:
            if len(chosen) >= self.batch_size:
                break
            if r.prompt_done < r.prompt_len:
                need = r.prompt_len + self.cfg.max_model_len \
                    - self.kvc.allocated_tokens(r.rid)
                if need > 0 and not self.kvc.can_allocate(need):
                    continue
                if need > 0:
                    self.kvc.allocate(r.rid, need)
                r.alloc_rl = self.cfg.max_model_len
                if r.t_start_exec is None:
                    r.t_start_exec = t
                r.set_state(State.RUNNING_PT, t)
                plan.prompt_items.append((r, r.prompt_len))
            else:
                r.set_state(State.RUNNING_GT, t)
            chosen.append((r, lvl))
            if r in self.levels[lvl]:
                self.levels[lvl].remove(r)
        # displaced
        for r, lvl in self.running:
            if all(r is not c for c, _ in chosen):
                r.set_state(State.PREEMPTED, t)
                r.n_preemptions += 1
                self.levels[lvl].append(r)
        self.running = chosen
        plan.decode_reqs = [r for r, _ in chosen
                            if r.prompt_done >= r.prompt_len]
        n_q = sum(len(l) for l in self.levels)
        plan.sched_time = self.cost.sched_time_mlfq(n_q, len(chosen))
        self.current_plan = plan
        return plan

    def finish_iteration(self, t: float) -> None:
        plan = self.current_plan
        n_done = 0
        nxt: List[Tuple[Request, int]] = []
        for r, lvl in self.running:
            if r.prompt_done < r.prompt_len:
                r.prompt_done = r.prompt_len
                self.kvc.set_used(r.rid, r.prompt_len)
                if r.t_first_token is None:
                    r.t_first_token = t
            else:
                r.generated += 1
                self.kvc.add_used(r.rid, 1)
            self.used_quantum[r.rid] = self.used_quantum.get(r.rid, 0) + 1
            if r.done:
                self._complete(r, t)
                n_done += 1
                continue
            if self.used_quantum[r.rid] >= self.quanta[lvl] \
                    and lvl < len(self.levels) - 1:
                # demote (keeps allocation — the KVC bottleneck of MLFQ)
                self.used_quantum[r.rid] = 0
                r.set_state(State.PREEMPTED, t)
                r.n_preemptions += 1
                self.levels[lvl + 1].append(r)
            else:
                nxt.append((r, lvl))
        self.running = nxt
        self.iter_completion_counts.append(n_done)


# ------------------------------------------------------------------------- #
class VLLMScheduler(BaseScheduler):
    """FCFS + block-allocation + swap-based preemption [13]."""
    name = "vllm"
    recompute_on_preempt = False

    def __init__(self, cfg: SchedulerConfig, cost: CostModel,
                 max_num_seqs: int = 256, watermark_blocks: int = 2):
        super().__init__(cfg, cost)
        self.running: List[Request] = []
        self.swapped: List[Request] = []
        self.max_num_seqs = max_num_seqs
        self.watermark = watermark_blocks

    def has_work(self) -> bool:
        return bool(self.pt_queue or self.swapped or self.running)

    # -------------------------------------------------------------- #
    def _admit_blocks(self, req: Request, tokens: int) -> bool:
        need_blocks = blocks_for(tokens, self.cfg.block_size) \
            - blocks_for(self.kvc.allocated_tokens(req.rid),
                         self.cfg.block_size)
        if need_blocks <= 0:
            return True
        if self.kvc.free_general - need_blocks < self.watermark:
            return False
        return self.kvc.extend(req.rid, need_blocks)

    def _resume_swapped(self, plan: IterationPlan, t: float) -> None:
        """vLLM's scheduler preserves FCFS — the oldest swapped group is
        resumed eagerly, preempting *newer* running groups if needed. Under
        KVC pressure this is the swap thrash the paper measures (74% / 67%
        allocation-failure rates for vLLM / Sarathi-Serve, fig 1d)."""
        for r in sorted(self.swapped, key=lambda r: r.arrival):
            tokens = r.prompt_len + r.generated + 1
            if len(self.running) >= self.max_num_seqs:
                break
            while not self._admit_blocks(r, tokens):
                newer = [v for v in self.running
                         if v.state == State.RUNNING_GT
                         and v.arrival > r.arrival]
                if not newer:
                    break
                victim = max(newer, key=lambda v: v.arrival)
                self.running.remove(victim)
                victim.n_preemptions += 1
                self.n_preempt_swap += 1
                vt = victim.prompt_len + victim.generated
                self.kvc.free(victim.rid)
                plan.extra_time += self.cost.swap_time(vt)
                victim.swap_time += self.cost.swap_time(vt)
                victim.set_state(State.PREEMPTED, t)
                self.swapped.append(victim)
            if self.kvc.allocated_tokens(r.rid) >= tokens:
                self.swapped.remove(r)
                self.kvc.set_used(r.rid, tokens - 1)
                plan.extra_time += self.cost.swap_time(tokens - 1)
                r.swap_time += self.cost.swap_time(tokens - 1)
                r.set_state(State.RUNNING_GT, t)
                self.running.append(r)
            else:
                break

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        self._resume_swapped(plan, t)
        n_new = 0
        for r in sorted(self.pt_queue, key=lambda r: r.arrival):
            if len(self.running) >= self.max_num_seqs:
                break
            if not self._admit_blocks(r, r.prompt_len + 1):
                break                        # FCFS head blocks
            if r.t_start_exec is None:
                r.t_start_exec = t
            r.set_state(State.RUNNING_PT, t)
            plan.prompt_items.append((r, r.prompt_len))
            self.pt_queue.remove(r)
            self.running.append(r)
            n_new += 1
        plan.decode_reqs = [r for r in self.running
                            if r.state == State.RUNNING_GT]
        plan.sched_time = self.cost.sched_time_fcfs(
            len(self.pt_queue) + len(self.swapped), n_new)
        self.current_plan = plan
        return plan

    def _preempt_victim(self, t: float) -> bool:
        """Swap out (or recompute-drop) the most recent running request."""
        gts = [r for r in self.running if r.state == State.RUNNING_GT]
        if not gts:
            return False
        victim = max(gts, key=lambda r: r.arrival)
        self.running.remove(victim)
        victim.n_preemptions += 1
        tokens = victim.prompt_len + victim.generated
        self.kvc.free(victim.rid)
        if self.recompute_on_preempt:
            self.n_preempt_free += 1
            victim.prompt_done = 0
            victim.occupied_kvc = 0
            victim.set_state(State.PREEMPTED, t)
            self.pt_queue.append(victim)
        else:
            self.n_preempt_swap += 1
            self.pending_extra_time += self.cost.swap_time(tokens)
            victim.swap_time += self.cost.swap_time(tokens)
            victim.set_state(State.PREEMPTED, t)
            self.swapped.append(victim)
        return True

    def finish_iteration(self, t: float) -> None:
        plan = self.current_plan
        n_done = 0
        for r, _ in plan.prompt_items:
            r.prompt_done = r.prompt_len
            self.kvc.set_used(r.rid, r.prompt_len)
            if r.t_first_token is None:
                r.t_first_token = t
            r.set_state(State.RUNNING_GT, t)
        for r in list(self.running):
            if r.state != State.RUNNING_GT:
                continue
            if any(r is p for p, _ in plan.prompt_items):
                continue
            # need one more token of space?
            tokens = r.prompt_len + r.generated + 1
            while tokens > self.kvc.allocated_tokens(r.rid):
                if not self.kvc.extend(r.rid, 1):
                    if not self._preempt_victim(t):
                        break
                    if r not in self.running:      # preempted itself
                        break
            if r not in self.running or r.state != State.RUNNING_GT:
                continue
            if tokens > self.kvc.allocated_tokens(r.rid):
                continue                           # could not grow: stall
            r.generated += 1
            self.kvc.add_used(r.rid, 1)
            if r.done:
                self.running.remove(r)
                self._complete(r, t)
                n_done += 1
        self.iter_completion_counts.append(n_done)


# ------------------------------------------------------------------------- #
class SarathiScheduler(VLLMScheduler):
    """Chunked prefill to the target forward size [15]."""
    name = "sarathi"

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        self._resume_swapped(plan, t)
        budget = self.cfg.tfs - len([r for r in self.running
                                     if r.state == State.RUNNING_GT])
        n_new = 0
        # continue partially prefilled first, then admit new
        partial = [r for r in self.running if r.prompt_done < r.prompt_len]
        newq = sorted(self.pt_queue, key=lambda r: r.arrival)
        for r in partial + newq:
            if budget <= 0 or len(self.running) >= self.max_num_seqs:
                break
            chunk = min(budget, r.prompt_len - r.prompt_done)
            if chunk <= 0:
                continue
            if not self._admit_blocks(r, r.prompt_done + chunk):
                break
            if r in self.pt_queue:
                self.pt_queue.remove(r)
                self.running.append(r)
                n_new += 1
            if r.t_start_exec is None:
                r.t_start_exec = t
            r.set_state(State.RUNNING_PT, t)
            plan.prompt_items.append((r, chunk))
            budget -= chunk
        plan.decode_reqs = [r for r in self.running
                            if r.state == State.RUNNING_GT]
        plan.sched_time = self.cost.sched_time_fcfs(
            len(self.pt_queue) + len(self.swapped), n_new) * 1.8
        self.current_plan = plan
        return plan

    def finish_iteration(self, t: float) -> None:
        plan = self.current_plan
        n_done = 0
        for r, chunk in plan.prompt_items:
            r.prompt_done += chunk
            self.kvc.set_used(r.rid, r.prompt_done)
            if r.prompt_done >= r.prompt_len:
                if r.t_first_token is None:
                    r.t_first_token = t
                r.set_state(State.RUNNING_GT, t)
        for r in list(self.running):
            if r.state != State.RUNNING_GT:
                continue
            if any(r is p for p, _ in plan.prompt_items):
                continue
            tokens = r.prompt_len + r.generated + 1
            while tokens > self.kvc.allocated_tokens(r.rid):
                if not self.kvc.extend(r.rid, 1):
                    if not self._preempt_victim(t):
                        break
                    if r not in self.running:
                        break
            if r not in self.running or r.state != State.RUNNING_GT:
                continue
            if tokens > self.kvc.allocated_tokens(r.rid):
                continue
            r.generated += 1
            self.kvc.add_used(r.rid, 1)
            if r.done:
                self.running.remove(r)
                self._complete(r, t)
                n_done += 1
        self.iter_completion_counts.append(n_done)


# ------------------------------------------------------------------------- #
class MultiResScheduler(BaseScheduler):
    """Dual-resource Euclidean matching (UnsyncCoupled) [32]-style."""
    name = "multires"
    sync_groups = False

    def __init__(self, cfg: SchedulerConfig, cost: CostModel):
        super().__init__(cfg, cost)
        self.running: List[Request] = []

    def has_work(self) -> bool:
        return bool(self.pt_queue or self.gt_queue or self.running)

    def _demand(self, r: Request) -> Tuple[float, float]:
        if r.prompt_done < r.prompt_len:
            gpu = r.prompt_len - r.prompt_done
            kvc = r.prompt_len + r.remaining_predicted \
                - self.kvc.allocated_tokens(r.rid)
        else:
            gpu = 1.0
            kvc = (r.prompt_len + r.generated + r.remaining_predicted
                   - self.kvc.allocated_tokens(r.rid))
        return float(gpu), float(max(0, kvc))

    def form_batch(self, t: float) -> IterationPlan:
        plan = IterationPlan()
        candidates = self.pt_queue + self.gt_queue
        if self.sync_groups:
            plan.sched_time = self.cost.sched_time_grouped(
                len(candidates), 1)
        else:
            plan.sched_time = self.cost.sched_time_quadratic(
                len(candidates), 1)
        n_sel = 0
        while candidates:
            gpu_avail = float(self.cfg.tfs - len(self.running)
                              - plan.prompt_tokens)
            kvc_avail = float(self.kvc.free_tokens())
            if gpu_avail <= 0 and kvc_avail <= 0:
                break
            feasible = []
            for r in candidates:
                g, k = self._demand(r)
                if g <= max(gpu_avail, 1) and k <= kvc_avail:
                    d = math.hypot((gpu_avail - g) / max(1, self.cfg.tfs),
                                   (kvc_avail - k) /
                                   max(1, self.kvc.capacity_tokens))
                    feasible.append((d, r.rid, r))
            if not feasible:
                break
            if self.sync_groups and feasible:
                # grouped selection: take the best AND its same-RL peers
                _, _, best = min(feasible)
                picks = [best]
                if best.prompt_done >= best.prompt_len:
                    key = bucketize(max(1, best.remaining_predicted),
                                    self.cfg.bucket)
                    for _, _, r in sorted(feasible):
                        if r is not best and r.prompt_done >= r.prompt_len \
                            and bucketize(max(1, r.remaining_predicted),
                                          self.cfg.bucket) == key:
                            picks.append(r)
            else:
                _, _, best = min(feasible)
                picks = [best]
            for r in picks:
                g, k = self._demand(r)
                if k > self.kvc.free_tokens():
                    continue
                if k > 0:
                    self.kvc.allocate(r.rid, int(k))
                r.alloc_rl = r.generated + r.remaining_predicted
                candidates.remove(r)
                n_sel += 1
                if r.prompt_done < r.prompt_len:
                    if r.t_start_exec is None:
                        r.t_start_exec = t
                    r.set_state(State.RUNNING_PT, t)
                    plan.prompt_items.append(
                        (r, r.prompt_len - r.prompt_done))
                    self.pt_queue.remove(r)
                else:
                    r.set_state(State.RUNNING_GT, t)
                    r._run_start = r.generated
                    self.gt_queue.remove(r)
                    self.running.append(r)
        plan.decode_reqs = [r for r in self.running
                            if r.state == State.RUNNING_GT]
        self.current_plan = plan
        return plan

    def finish_iteration(self, t: float) -> None:
        plan = self.current_plan
        n_done = 0
        for r, chunk in plan.prompt_items:
            r.prompt_done += chunk
            self.kvc.set_used(r.rid, r.prompt_done)
            if r.prompt_done >= r.prompt_len:
                if r.t_first_token is None:
                    r.t_first_token = t
                r.set_state(State.RUNNING_GT, t)
                self.running.append(r)
            else:
                r.set_state(State.QUEUED_PT, t)
                self.pt_queue.append(r)
        for r in list(self.running):
            if r.state != State.RUNNING_GT:
                continue
            if any(r is p for p, _ in plan.prompt_items):
                continue
            r.generated += 1
            self.kvc.add_used(r.rid, 1)
            if r.done:
                self.running.remove(r)
                self._complete(r, t)
                n_done += 1
            elif r.generated >= r.alloc_rl:
                # under-provision without reserve: swap-based preemption
                self.n_underprov += 1
                self.running.remove(r)
                r.n_preemptions += 1
                self.n_preempt_swap += 1
                tokens = r.prompt_len + r.generated
                self.pending_extra_time += 2 * self.cost.swap_time(tokens)
                r.swap_time += 2 * self.cost.swap_time(tokens)
                self.kvc.free(r.rid)
                r.occupied_kvc = tokens
                r.padded_rl = r.generated + bucketize(
                    self.cfg.bucket, self.cfg.bucket)
                r.set_state(State.PREEMPTED, t)
                self.gt_queue.append(r)
        self.iter_completion_counts.append(n_done)


class SyncCoupledScheduler(MultiResScheduler):
    name = "synccoupled"
    sync_groups = True


# ------------------------------------------------------------------------- #
# DistServe: disaggregated prefill / decode engines
# ------------------------------------------------------------------------- #
def simulate_distserve(requests, cfg: SchedulerConfig, cost: CostModel,
                       max_iters: int = 2_000_000) -> SimResult:
    """Two engines (prefill / decode) with a KV transfer in between.
    Each engine has its own KVC of cfg.kvc_tokens (2x GPUs total)."""
    from .kvc import BlockKVC

    reqs = sorted(requests, key=lambda r: r.arrival)
    n = len(reqs)
    i_arr = 0
    tP = tD = 0.0
    pq: List[Request] = []               # prefill queue
    dq: List[Tuple[float, Request]] = []  # (ready time at decode, req)
    running_d: List[Request] = []
    kvc_p = BlockKVC(cfg.kvc_tokens, cfg.block_size)
    kvc_d = BlockKVC(cfg.kvc_tokens, cfg.block_size)
    samples: List[IterSample] = []
    completed = 0
    iters = 0

    while iters < max_iters and completed < n:
        iters += 1
        t = min(tP, tD)
        while i_arr < n and reqs[i_arr].arrival <= max(tP, tD):
            r = reqs[i_arr]
            r.set_state(State.QUEUED_PT, r.arrival)
            pq.append(r)
            i_arr += 1
        progressed = False
        # ---- prefill engine ------------------------------------------
        if tP <= tD or not running_d:
            batch = []
            budget = cfg.tfs
            for r in sorted(pq, key=lambda r: r.arrival):
                if r.arrival > tP or budget < r.prompt_len:
                    continue
                if not kvc_p.can_allocate(r.prompt_len):
                    break
                kvc_p.allocate(r.rid, r.prompt_len)
                batch.append(r)
                budget -= r.prompt_len
            if batch:
                progressed = True
                dt = cost.iteration_time(sum(r.prompt_len for r in batch), [])
                tP += dt + cost.sched_time_fcfs(len(pq), len(batch))
                for r in batch:
                    pq.remove(r)
                    r.prompt_done = r.prompt_len
                    if r.t_start_exec is None:
                        r.t_start_exec = tP
                    if r.t_first_token is None:
                        r.t_first_token = tP
                    kvc_p.free(r.rid)
                    xfer = cost.kv_transfer_time(r.prompt_len)
                    r.swap_time += xfer
                    r.charge(tP)
                    dq.append((tP + xfer, r))
            elif i_arr < n and not running_d and not dq:
                tP = max(tP, reqs[i_arr].arrival)
                continue
            else:
                tP = max(tP, tD)          # idle prefill engine
        # ---- decode engine -------------------------------------------
        ready = [r for (rt, r) in dq if rt <= tD]
        for r in ready:
            tokens = r.prompt_len + r.generated + 1
            if not kvc_d.can_allocate(tokens):
                break
            kvc_d.allocate(r.rid, tokens)
            kvc_d.set_used(r.rid, tokens - 1)
            r.set_state(State.RUNNING_GT, tD)
            dq[:] = [(rt, x) for (rt, x) in dq if x is not r]
            running_d.append(r)
        if running_d:
            progressed = True
            ctxs = [r.prompt_len + r.generated for r in running_d]
            dt = cost.iteration_time(0, ctxs)
            tD += dt
            n_done = 0
            for r in list(running_d):
                tokens = r.prompt_len + r.generated + 1
                if tokens > kvc_d.allocated_tokens(r.rid):
                    while not kvc_d.extend(r.rid, 1):
                        # evict the newest running request (swap to host,
                        # re-admit later) — prevents a full-KVC stall
                        newer = [v for v in running_d
                                 if v.arrival > r.arrival and v is not r]
                        if not newer:
                            break
                        victim = max(newer, key=lambda v: v.arrival)
                        running_d.remove(victim)
                        victim.n_preemptions += 1
                        vt = victim.prompt_len + victim.generated
                        kvc_d.free(victim.rid)
                        xfer = 2 * cost.swap_time(vt)
                        victim.swap_time += xfer
                        victim.set_state(State.PREEMPTED, tD)
                        dq.append((tD + xfer, victim))
                    if tokens > kvc_d.allocated_tokens(r.rid):
                        continue           # could not grow this round
                r.generated += 1
                kvc_d.add_used(r.rid, 1)
                if r.done:
                    running_d.remove(r)
                    r.set_state(State.COMPLETED, tD)
                    r.t_complete = tD
                    kvc_d.free(r.rid)
                    completed += 1
                    n_done += 1
            samples.append(IterSample(
                t=tD, dt=dt, forward_size=len(ctxs), prompt_tokens=0,
                n_decode=len(ctxs),
                kvc_used_frac=(kvc_p.utilization + kvc_d.utilization) / 2,
                kvc_alloc_frac=(kvc_p.allocated_frac + kvc_d.allocated_frac) / 2,
                sched_time=0.0, extra_time=0.0, n_completed=n_done))
        elif dq:
            tD = max(tD, min(rt for rt, _ in dq))
        elif i_arr < n:
            tD = max(tD, reqs[i_arr].arrival)
        elif not progressed and not pq:
            break
        if not progressed and not ready and not running_d and not pq \
                and i_arr >= n and not dq:
            break

    return SimResult(name="distserve", requests=list(reqs), samples=samples,
                     wall_time=max(tP, tD), tfs=cfg.tfs,
                     n_alloc_failures=kvc_d.n_failures + kvc_p.n_failures,
                     n_allocs=kvc_d.n_allocs + kvc_p.n_allocs)
