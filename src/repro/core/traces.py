"""Synthetic trace generators calibrated to the paper's Table 2.

The real Alpaca / ShareGPT / BookCorpus request logs are not available in
this offline environment, so we generate lognormal prompt/response lengths
clipped to the table's min/max, with Poisson arrivals at the table's rate.
True response length is correlated with prompt length through a latent
factor so that a learned predictor has signal (and a noisy-oracle predictor
can be calibrated to the paper's reported accuracies).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from .request import Request


@dataclass(frozen=True)
class TraceSpec:
    name: str
    in_mean: float
    in_min: int
    in_max: int
    out_mean: float
    out_min: int
    out_max: int
    rate: float                      # requests / s (Poisson)
    in_sigma: float = 0.9            # lognormal shape
    out_sigma: float = 0.7
    rl_corr: float = 0.45            # prompt→response latent correlation


ALPACA = TraceSpec("alpaca", 19.31, 9, 2470, 58.41, 13, 292, 36.0,
                   in_sigma=0.6)
SHAREGPT = TraceSpec("sharegpt", 161.31, 16, 3200, 337.99, 19, 991, 28.0)
BOOKCORPUS = TraceSpec("bookcorpus", 1952.11, 18, 2048, 681.2, 32, 1041, 1.2,
                       in_sigma=0.35)

TRACES = {t.name: t for t in (ALPACA, SHAREGPT, BOOKCORPUS)}


def _lognormal_mean(mean: float, sigma: float, rng: np.random.Generator,
                    n: int) -> np.ndarray:
    mu = math.log(mean) - 0.5 * sigma * sigma
    return rng.lognormal(mu, sigma, size=n)


def generate(spec: TraceSpec, n: int, seed: int = 0,
             rate: Optional[float] = None,
             slo_scale: float = 2.0,
             t_p: float = 0.06, t_g: float = 0.04) -> List[Request]:
    """Generate ``n`` requests. SLO deadline follows §4:
    arrival + slo_scale * (t_p + t_g * RL)."""
    rng = np.random.default_rng(seed)
    rate = rate if rate is not None else spec.rate

    plen = np.clip(_lognormal_mean(spec.in_mean, spec.in_sigma, rng, n),
                   spec.in_min, spec.in_max).astype(int)
    # correlated latent: z shared between prompt and response
    z = (np.log(plen) - np.mean(np.log(plen))) / (np.std(np.log(plen)) + 1e-9)
    eps = rng.normal(size=n)
    mix = spec.rl_corr * z + math.sqrt(1 - spec.rl_corr ** 2) * eps
    mu = math.log(spec.out_mean) - 0.5 * spec.out_sigma ** 2
    rl = np.clip(np.exp(mu + spec.out_sigma * mix),
                 spec.out_min, spec.out_max).astype(int)

    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)

    reqs = []
    for i in range(n):
        deadline = arrivals[i] + slo_scale * (t_p + t_g * float(rl[i]))
        reqs.append(Request(rid=i, prompt_len=int(plen[i]),
                            true_rl=int(rl[i]), arrival=float(arrivals[i]),
                            slo_deadline=float(deadline)))
    return reqs


@dataclass(frozen=True)
class DiurnalSpec:
    """Inhomogeneous-Poisson arrival schedule for the trace replayer:
    a sinusoidal day/night ramp with superimposed Poisson burst windows.

    The instantaneous rate at time ``t`` is

        rate(t) = base_rate * (1 + diurnal_amp * sin(2*pi*t/period - pi/2))
                  * (burst_mult if t is inside a burst window else 1)

    — the phase shift puts the trough at t=0 (replays start at "night"),
    the peak at period/2. Burst windows themselves arrive as a Poisson
    process with rate ``burst_rate`` and exponential durations, modelling
    flash crowds on top of the daily cycle.
    """
    period: float = 600.0            # one synthetic "day", in trace time
    diurnal_amp: float = 0.6         # peak/trough swing (0 => homogeneous)
    burst_rate: float = 1 / 120.0    # burst windows per unit time
    burst_duration: float = 15.0     # mean burst length (exponential)
    burst_mult: float = 3.0          # rate multiplier inside a burst


def diurnal_arrivals(n: int, base_rate: float, spec: DiurnalSpec,
                     rng: np.random.Generator) -> np.ndarray:
    """``n`` arrival times from the inhomogeneous Poisson process above,
    via thinning (Lewis & Shedler): draw candidates at the peak rate
    ``base_rate * (1 + amp) * burst_mult`` and accept each with
    probability rate(t)/peak — exact for any bounded rate function."""
    if spec.diurnal_amp < 0 or spec.diurnal_amp > 1:
        raise ValueError("diurnal_amp must be in [0, 1]")
    peak = base_rate * (1 + spec.diurnal_amp) * max(1.0, spec.burst_mult)
    arrivals = np.empty(n)
    got = 0
    t = 0.0
    burst_until = -1.0
    next_burst = rng.exponential(1.0 / spec.burst_rate) \
        if spec.burst_rate > 0 else float("inf")
    while got < n:
        t += rng.exponential(1.0 / peak)
        while t >= next_burst:               # open burst windows in order
            burst_until = next_burst + rng.exponential(spec.burst_duration)
            next_burst += rng.exponential(1.0 / spec.burst_rate)
        lam = base_rate * (1 + spec.diurnal_amp
                           * math.sin(2 * math.pi * t / spec.period
                                      - math.pi / 2))
        if t <= burst_until:
            lam *= spec.burst_mult
        if rng.uniform() * peak <= lam:
            arrivals[got] = t
            got += 1
    return arrivals


def generate_diurnal(spec: TraceSpec, n: int, seed: int = 0,
                     rate: Optional[float] = None,
                     diurnal: Optional[DiurnalSpec] = None,
                     slo_scale: float = 2.0,
                     t_p: float = 0.06, t_g: float = 0.04) -> List[Request]:
    """Like ``generate`` but with diurnal-ramp + Poisson-burst arrivals
    (heavy-tailed lengths come from the lognormal spec as before). Used
    by ``benchmarks/trace_replay.py`` for the 100k-request replays."""
    base = generate(spec, n, seed=seed, rate=rate, slo_scale=slo_scale,
                    t_p=t_p, t_g=t_g)
    rng = np.random.default_rng(seed + 1)
    arrivals = diurnal_arrivals(
        n, rate if rate is not None else spec.rate,
        diurnal or DiurnalSpec(), rng)
    reqs = []
    for r, t in zip(base, arrivals):
        deadline = float(t) + slo_scale * (t_p + t_g * float(r.true_rl))
        reqs.append(Request(rid=r.rid, prompt_len=r.prompt_len,
                            true_rl=r.true_rl, arrival=float(t),
                            slo_deadline=deadline))
    return reqs
