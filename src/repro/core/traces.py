"""Synthetic trace generators calibrated to the paper's Table 2.

The real Alpaca / ShareGPT / BookCorpus request logs are not available in
this offline environment, so we generate lognormal prompt/response lengths
clipped to the table's min/max, with Poisson arrivals at the table's rate.
True response length is correlated with prompt length through a latent
factor so that a learned predictor has signal (and a noisy-oracle predictor
can be calibrated to the paper's reported accuracies).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from .request import Request


@dataclass(frozen=True)
class TraceSpec:
    name: str
    in_mean: float
    in_min: int
    in_max: int
    out_mean: float
    out_min: int
    out_max: int
    rate: float                      # requests / s (Poisson)
    in_sigma: float = 0.9            # lognormal shape
    out_sigma: float = 0.7
    rl_corr: float = 0.45            # prompt→response latent correlation


ALPACA = TraceSpec("alpaca", 19.31, 9, 2470, 58.41, 13, 292, 36.0,
                   in_sigma=0.6)
SHAREGPT = TraceSpec("sharegpt", 161.31, 16, 3200, 337.99, 19, 991, 28.0)
BOOKCORPUS = TraceSpec("bookcorpus", 1952.11, 18, 2048, 681.2, 32, 1041, 1.2,
                       in_sigma=0.35)

TRACES = {t.name: t for t in (ALPACA, SHAREGPT, BOOKCORPUS)}


def _lognormal_mean(mean: float, sigma: float, rng: np.random.Generator,
                    n: int) -> np.ndarray:
    mu = math.log(mean) - 0.5 * sigma * sigma
    return rng.lognormal(mu, sigma, size=n)


def generate(spec: TraceSpec, n: int, seed: int = 0,
             rate: Optional[float] = None,
             slo_scale: float = 2.0,
             t_p: float = 0.06, t_g: float = 0.04) -> List[Request]:
    """Generate ``n`` requests. SLO deadline follows §4:
    arrival + slo_scale * (t_p + t_g * RL)."""
    rng = np.random.default_rng(seed)
    rate = rate if rate is not None else spec.rate

    plen = np.clip(_lognormal_mean(spec.in_mean, spec.in_sigma, rng, n),
                   spec.in_min, spec.in_max).astype(int)
    # correlated latent: z shared between prompt and response
    z = (np.log(plen) - np.mean(np.log(plen))) / (np.std(np.log(plen)) + 1e-9)
    eps = rng.normal(size=n)
    mix = spec.rl_corr * z + math.sqrt(1 - spec.rl_corr ** 2) * eps
    mu = math.log(spec.out_mean) - 0.5 * spec.out_sigma ** 2
    rl = np.clip(np.exp(mu + spec.out_sigma * mix),
                 spec.out_min, spec.out_max).astype(int)

    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)

    reqs = []
    for i in range(n):
        deadline = arrivals[i] + slo_scale * (t_p + t_g * float(rl[i]))
        reqs.append(Request(rid=i, prompt_len=int(plen[i]),
                            true_rl=int(rl[i]), arrival=float(arrivals[i]),
                            slo_deadline=float(deadline)))
    return reqs
