"""Prompt and Generation Task Ordering (§3.4).

Three factors, in order:
  1. JCT-SLO deadline  — ascending, bucketed into magnitude ranges;
  2. occupied KVC      — descending, bucketed (release KVC earlier, O5);
  3. predicted RL (GTs) / prompt length (PTs) — descending (fast near-exact
     fits when filling KVC / TFS via binary search).
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Sequence, Tuple

from .request import Request

DEADLINE_EDGES = (0.2, 0.5, 2.0)          # s, paper's example ranges
KVC_BUCKET = 128                          # tokens per occupied-KVC range
LEN_BUCKET = 128                          # tokens per RL/prompt-length range


def deadline_bucket(req: Request, now: float) -> int:
    slack = req.slo_deadline - now
    return bisect.bisect_left(DEADLINE_EDGES, slack)


def order_key(req: Request, now: float, is_gt: bool) -> Tuple[int, int, int]:
    length = req.remaining_predicted if is_gt else req.prompt_len
    return (deadline_bucket(req, now),
            -(req.occupied_kvc // KVC_BUCKET),
            -length)


def sort_queue(queue: List[Request], now: float, is_gt: bool) -> List[Request]:
    return sorted(queue, key=lambda r: order_key(r, now, is_gt))


def pick_fit(sorted_reqs: Sequence[Request], budget: int, now: float,
             is_gt: bool) -> Optional[int]:
    """Within the highest-priority (deadline, kvc) range, binary-search the
    task whose length best fits ``budget`` (§3.4 'binary search to find a
    task ... close to the required length'). Returns an index or None."""
    if not sorted_reqs:
        return None
    head = sorted_reqs[0]
    hk = order_key(head, now, is_gt)[:2]
    # the slice sharing the head's (deadline, kvc) buckets, ordered by
    # descending length -> find first entry with length <= budget
    lo, hi = 0, len(sorted_reqs)
    while lo < hi:
        mid = (lo + hi) // 2
        r = sorted_reqs[mid]
        if order_key(r, now, is_gt)[:2] != hk:
            hi = mid
            continue
        length = r.remaining_predicted if is_gt else r.prompt_len
        if length > budget:
            lo = mid + 1
        else:
            hi = mid
    if lo < len(sorted_reqs):
        r = sorted_reqs[lo]
        length = r.remaining_predicted if is_gt else r.prompt_len
        if length <= budget:
            return lo
    return None
