"""Prompt and Generation Task Ordering (§3.4).

Three factors, in order:
  1. JCT-SLO deadline  — ascending, bucketed into magnitude ranges;
  2. occupied KVC      — descending, bucketed (release KVC earlier, O5);
  3. predicted RL (GTs) / prompt length (PTs) — descending (fast near-exact
     fits when filling KVC / TFS via binary search).

Two ways to consume the ordering:
  * ``sort_queue``   — full re-sort (reference semantics, O(n log n) per
    iteration with a Python key function on every element);
  * ``OrderedQueue`` — a drop-in queue replacement (append / remove / len /
    iteration) that maintains the same ordering incrementally: keys are
    computed once on append (insort), removal is O(1) via an rid index map,
    and only requests whose deadline bucket has actually rolled over are
    re-keyed (a time-ordered heap makes that O(log n) amortized).
    ``sorted_view(now)`` is guaranteed to return exactly what
    ``sort_queue(queue, now)`` would, including stable tie-breaking.
"""
from __future__ import annotations

import bisect
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from .request import Request

DEADLINE_EDGES = (0.2, 0.5, 2.0)          # s, paper's example ranges
KVC_BUCKET = 128                          # tokens per occupied-KVC range
LEN_BUCKET = 128                          # tokens per RL/prompt-length range


def deadline_bucket(req: Request, now: float) -> int:
    slack = req.slo_deadline - now
    return bisect.bisect_left(DEADLINE_EDGES, slack)


def order_key(req: Request, now: float, is_gt: bool) -> Tuple[int, int, int]:
    length = req.remaining_predicted if is_gt else req.prompt_len
    return (deadline_bucket(req, now),
            -(req.occupied_kvc // KVC_BUCKET),
            -length)


def sort_queue(queue: List[Request], now: float, is_gt: bool) -> List[Request]:
    return sorted(queue, key=lambda r: order_key(r, now, is_gt))


def _next_bucket_change(req: Request, bucket: int) -> float:
    """Time at which the request's deadline bucket next decrements: the
    moment its slack drops to the edge below its current bucket."""
    if bucket <= 0:
        return float("inf")
    return req.slo_deadline - DEADLINE_EDGES[bucket - 1]


class OrderedQueue:
    """A request queue that preserves append order (what FCFS paths and
    stable-sort tie-breaks see) and a priority index kept in ``sort_queue``
    order without per-iteration re-sorts.

    The append-order backing is an insertion-ordered dict keyed by rid, so
    ``remove`` is O(1) — the previous list-subclass representation paid an
    O(n) identity scan (``list.remove``) per removal, which dominated
    batch-formation time on large standing queues. Iteration, ``len`` and
    truthiness behave like the old list view. Keys are assigned lazily at
    the first ``sorted_view`` after an append (the key needs ``now``); each
    keyed entry carries a monotone sequence number so equal keys order
    exactly like Python's stable sort over append order.
    """

    def __init__(self, is_gt: bool):
        self.is_gt = is_gt
        self._seq = 0
        self._order: Dict[int, Request] = {}  # rid -> req, append order
        self._entries: List[list] = []    # sorted [key, seq, req]
        self._keyed: Dict[int, Tuple[Tuple, int]] = {}  # rid -> (key, seq)
        self._rekey: List[Tuple[float, int, int]] = []  # heap (t, seq, rid)
        self._pending: Dict[int, Request] = {}          # rid -> req
        self._view: Optional[List[Request]] = None

    # -- list-like interface -------------------------------------------- #
    def __iter__(self):
        return iter(self._order.values())

    def __len__(self) -> int:
        return len(self._order)

    def __contains__(self, req: Request) -> bool:
        return self._order.get(req.rid) is req

    def __repr__(self) -> str:
        return f"OrderedQueue({list(self._order.values())!r})"

    def append(self, req: Request) -> None:
        self._order[req.rid] = req
        self._pending[req.rid] = req

    def remove(self, req: Request) -> None:
        del self._order[req.rid]           # O(1) index-map removal
        self._view = None
        if self._pending.pop(req.rid, None) is not None:
            return
        key, seq = self._keyed.pop(req.rid)
        # the stored key always matches the stored entry (written together
        # in _insert/_bulk_key), so the bisect is exact
        i = bisect.bisect_left(self._entries, [key, seq])
        assert self._entries[i][1] == seq, (req.rid, key, seq)
        del self._entries[i]

    # -- priority view -------------------------------------------------- #
    def _insert(self, req: Request, now: float,
                seq: Optional[int] = None) -> None:
        key = order_key(req, now, self.is_gt)
        if seq is None:                    # re-keys keep their seq so ties
            seq = self._seq                # still break by append order
            self._seq += 1
        bisect.insort(self._entries, [key, seq, req])
        self._keyed[req.rid] = (key, seq)
        t_next = _next_bucket_change(req, key[0])
        if t_next < float("inf"):
            heapq.heappush(self._rekey, (t_next, seq, req.rid))

    def _bulk_key(self, now: float) -> None:
        """Key a large pending batch with one sort + merge instead of
        per-element insort (Timsort gallops over the two sorted runs)."""
        new = []
        for req in self._pending.values():
            key = order_key(req, now, self.is_gt)
            seq = self._seq
            self._seq += 1
            new.append([key, seq, req])
            self._keyed[req.rid] = (key, seq)
            t_next = _next_bucket_change(req, key[0])
            if t_next < float("inf"):
                heapq.heappush(self._rekey, (t_next, seq, req.rid))
        new.sort(key=lambda e: (e[0], e[1]))
        self._entries = list(heapq.merge(self._entries, new,
                                         key=lambda e: (e[0], e[1])))
        self._pending.clear()

    def sorted_view(self, now: float) -> List[Request]:
        """The queue in ``sort_queue(queue, now)`` order (a fresh list —
        callers mutate their copy)."""
        if self._pending:
            self._view = None
            if len(self._pending) > 64:
                self._bulk_key(now)
            else:
                for req in self._pending.values():
                    self._insert(req, now)
                self._pending.clear()
        while self._rekey and self._rekey[0][0] <= now:
            _, seq, rid = heapq.heappop(self._rekey)
            cur = self._keyed.get(rid)
            if cur is None or cur[1] != seq:
                continue                   # removed or re-appended since
            key = cur[0]
            i = bisect.bisect_left(self._entries, [key, seq])
            req = self._entries[i][2]
            del self._entries[i]
            del self._keyed[rid]
            self._insert(req, now, seq=seq)
            self._view = None
        if self._view is None:
            self._view = [e[2] for e in self._entries]
        return list(self._view)


def pick_fit(sorted_reqs: Sequence[Request], budget: int, now: float,
             is_gt: bool) -> Optional[int]:
    """Within the highest-priority (deadline, kvc) range, binary-search the
    task whose length best fits ``budget`` (§3.4 'binary search to find a
    task ... close to the required length'). Returns an index or None."""
    if not sorted_reqs:
        return None
    head = sorted_reqs[0]
    hk = order_key(head, now, is_gt)[:2]
    # the slice sharing the head's (deadline, kvc) buckets, ordered by
    # descending length -> find first entry with length <= budget
    lo, hi = 0, len(sorted_reqs)
    while lo < hi:
        mid = (lo + hi) // 2
        r = sorted_reqs[mid]
        if order_key(r, now, is_gt)[:2] != hk:
            hi = mid
            continue
        length = r.remaining_predicted if is_gt else r.prompt_len
        if length > budget:
            lo = mid + 1
        else:
            hi = mid
    if lo < len(sorted_reqs):
        r = sorted_reqs[lo]
        length = r.remaining_predicted if is_gt else r.prompt_len
        if length <= budget:
            return lo
    return None
